"""Online federation runtime: serve → harvest → federate → hot-swap.

The paper's premise is that query–model evaluations are born at clients,
during serving. This subsystem closes that loop around the serving stack:

  * ``aggregators`` — pluggable server-side aggregation strategies for the
    FedAvg round (plain weighted FedAvg, pairwise-masked secure
    aggregation, central-DP noise) — ``core/federated.py`` dispatches
    every fit path through them.
  * ``harvest``     — bounded per-client ``EvalBuffer``s fed by live
    serving: every routed request appends (query embedding, chosen model,
    outcome, cost) to the submitting client's local log, producing exactly
    the sparse, non-uniform-coverage evaluation matrices the paper assumes.
  * ``faults``      — deterministic fault injection: a seeded ``FaultPlan``
    (dropout, stale updates, corrupted updates, lost outcomes, backend
    failures) plus the ``CorruptUpdates`` aggregator wrapper that applies
    Byzantine corruption inside the cached fit paths.
  * ``loop``        — the ``FedLoop`` scheduler: federated refits over the
    harvested buffers interleaved with engine decode chunks, hot-swapping
    versioned router state into the route path with zero retraces;
    ``save()``/``restore()`` checkpoint the whole loop for bit-identical
    crash recovery.
  * ``scenarios``   — traffic simulators (client heterogeneity, drift,
    stragglers, mid-run model onboarding) and the online-vs-frozen
    comparison behind ``BENCH_fedloop.json``.

``loop`` and ``scenarios`` import the serving stack, so they are exposed
lazily — ``core/federated.py`` importing ``repro.fed.aggregators`` for its
default strategy stays cycle-free.
"""
from repro.fed.aggregators import (Aggregator, BufferedAsyncAggregator,
                                   FedAvgAggregator, GaussianDPAggregator,
                                   MedianAggregator, NormClipAggregator,
                                   SecureAggAggregator,
                                   TrimmedMeanAggregator)
from repro.fed.faults import CorruptUpdates, FaultPlan
from repro.fed.harvest import EvalBuffer, HarvestStore

__all__ = [
    "Aggregator", "FedAvgAggregator", "GaussianDPAggregator",
    "SecureAggAggregator", "TrimmedMeanAggregator", "MedianAggregator",
    "NormClipAggregator", "BufferedAsyncAggregator",
    "FaultPlan", "CorruptUpdates", "EvalBuffer", "HarvestStore",
    "FedLoop", "FedLoopConfig", "personalize_client",
    "ScenarioConfig", "TrafficScenario", "run_online_vs_frozen",
    "PowerLawScenario",
]

_LAZY = {
    "FedLoop": "loop", "FedLoopConfig": "loop", "personalize_client": "loop",
    "ScenarioConfig": "scenarios", "TrafficScenario": "scenarios",
    "run_online_vs_frozen": "scenarios", "PowerLawScenario": "scenarios",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.fed' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.fed.{mod}"), name)
