"""Pluggable server-side aggregation strategies (Alg. 1 line 11).

``core/federated.fedavg_round`` dispatches its aggregation step through one
of these instead of a hard-coded branch, so secure aggregation, DP noise,
and the Byzantine-robust/buffered-async strategies below all ride the same
scan-fused/cached fit paths as plain FedAvg. Every strategy implements

    aggregator(client_params, wts, key) -> new_params

where ``client_params`` is the stacked (N-leading) client-update pytree,
``wts`` the raw per-client aggregation weights (dataset sizes × the round's
active mask — zero for inactive clients), and ``key`` the round's
aggregation PRNG key (the same stream the legacy ``dp_sigma`` path drew
noise from).

Strategies that need more than the stacked updates *declare* it instead of
changing the call signature: ``needs_prev = True`` makes ``fedavg_round``
pass ``prev=`` (the round's input server params — delta-based strategies),
``needs_staleness = True`` passes ``staleness=`` (per-client rounds since
last contribution — buffered-async strategies). Plain 3-arg strategies,
including arbitrary custom callables, keep their exact legacy call.

Composition rules: ``GaussianDPAggregator`` wraps any inner strategy (DP is
server-side noise on the aggregate, so it composes with everything and
forwards the inner strategy's declared extras). Secure aggregation does
NOT compose with the coordinate-wise robust strategies — the server only
ever learns the masked *sum*, so it cannot sort/trim/median individual
updates; that composition is structurally inexpressible here (``SecureAgg``
has no inner slot) rather than silently wrong.

Strategies are frozen dataclasses: hashable, so the compiled-fit caches in
``core/federated.py`` can key on them — a fit with the same aggregator
reuses its compiled scan. An unhashable custom strategy still works; it
just gets a fresh jit per fit.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core import secure_agg as SA


def _normalize(wts: jnp.ndarray) -> jnp.ndarray:
    """The legacy fedavg weight normalization, verbatim — every strategy
    shares it so the plain path stays bit-for-bit the pre-refactor code."""
    return wts / jnp.maximum(jnp.sum(wts), 1e-12)


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """Base strategy; subclass and implement ``__call__``."""

    def __call__(self, client_params, wts, key):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FedAvgAggregator(Aggregator):
    """Plain weighted FedAvg — bit-for-bit the pre-refactor aggregation
    (normalize, f32 tensordot over the client axis, cast back)."""

    def __call__(self, client_params, wts, key):
        wn = _normalize(wts)
        return jax.tree.map(
            lambda s: jnp.tensordot(wn, s.astype(jnp.float32),
                                    axes=1).astype(s.dtype),
            client_params)


@dataclasses.dataclass(frozen=True)
class SecureAggAggregator(Aggregator):
    """Pairwise-masked FedAvg (Bonawitz et al. 2016, via
    ``core/secure_agg``): every pair of round participants derives a shared
    mask from the round key; each client folds its pair masks (+ below the
    partner id, − above) into its upload, so the server's weighted sum
    carries every mask once with each sign and learns only the aggregate.

    Simulation notes: masks are gated by the round's participant set
    (``wts > 0`` — in the real protocol the key-agreement round fixes the
    participants before masking, so a dropped client's masks are never
    sent), and each client folds its net mask into the update it uploads so
    the server-side reduction is the *same tensordot* as plain FedAvg. That
    makes cancellation structural: with ``scale=0`` the masks are exact
    zeros and the result is bit-identical to ``FedAvgAggregator``
    (test-enforced); with ``scale>0`` the masks cancel to float rounding
    (~1e-6·scale per parameter).

    Mask generation is O(N²) in the client count — fine for the simulated
    cohorts this repo runs; the real protocol's key agreement amortizes it.
    """

    scale: float = 10.0

    def __call__(self, client_params, wts, key):
        N = int(wts.shape[0])
        wn = _normalize(wts)
        active = (wts > 0).astype(jnp.float32)       # the participant set
        unit = jax.tree.map(lambda s: s[0], client_params)
        nets = []
        for i in range(N):
            net = jax.tree.map(
                lambda a: jnp.zeros(a.shape, jnp.float32), unit)
            for j in range(N):
                if j == i:
                    continue
                m = SA._mask_like(SA._pair_key(key, i, j), unit, self.scale)
                sign = 1.0 if i < j else -1.0
                net = jax.tree.map(
                    lambda n, mm: n + sign * active[j] * mm, net, m)
            nets.append(net)
        net_stack = jax.tree.map(lambda *ls: jnp.stack(ls), *nets)
        # Client i uploads θ_i + net_i/w̃_i (it knows its own round weight),
        # so the server's weighted tensordot carries exactly w̃_i·θ_i +
        # net_i — the masked weighted sum — through the identical reduction
        # the plain path uses. Inactive clients (w̃ = 0) upload nothing.
        inv = jnp.where(wn > 0, 1.0 / jnp.maximum(wn, 1e-30), 0.0)

        def leaf(s, m):
            shape = (N,) + (1,) * (s.ndim - 1)
            upload = s.astype(jnp.float32) + inv.reshape(shape) * m
            return jnp.tensordot(wn, upload, axes=1).astype(s.dtype)

        return jax.tree.map(leaf, client_params, net_stack)


@dataclasses.dataclass(frozen=True)
class GaussianDPAggregator(Aggregator):
    """Server-side Gaussian noise on the aggregate (the central-DP flavour
    of the paper's privacy motivation), composing over any inner strategy.
    With the default FedAvg inner this is bit-for-bit the legacy
    ``fedavg(dp_sigma=...)`` path: the noise is keyed by the round's
    aggregation key exactly as before, and the inner strategy receives a
    folded key so its own randomness (e.g. secure-agg masks) never
    correlates with the noise."""

    sigma: float = 0.0
    inner: Aggregator = FedAvgAggregator()

    @property
    def needs_prev(self) -> bool:  # forward the inner strategy's extras
        return getattr(self.inner, "needs_prev", False)

    @property
    def needs_staleness(self) -> bool:
        return getattr(self.inner, "needs_staleness", False)

    def __call__(self, client_params, wts, key, **extras):
        out = self.inner(client_params, wts, jax.random.fold_in(key, 1),
                         **extras)
        if self.sigma <= 0.0:
            return out
        leaves, treedef = jax.tree.flatten(out)
        keys = jax.random.split(key, len(leaves))
        leaves = [l + self.sigma * jax.random.normal(k, l.shape, l.dtype)
                  for l, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Byzantine-robust strategies (coordinate-wise, unweighted over the round's
# active clients — the classical robust estimators deliberately ignore the
# self-reported dataset-size weights, since a corrupted client could inflate
# its weight as easily as its update).
# ---------------------------------------------------------------------------


def _sorted_active(leaf, active):
    """Sort a stacked leaf along the client axis with inactive rows pushed
    to +inf: the round's ``n_act`` real updates occupy ranks [0, n_act) in
    ascending coordinate order, for any traced active count."""
    shape = (leaf.shape[0],) + (1,) * (leaf.ndim - 1)
    masked = jnp.where(active.reshape(shape) > 0,
                       leaf.astype(jnp.float32), jnp.inf)
    return jnp.sort(masked, axis=0)


def _ranks_like(leaf):
    shape = (leaf.shape[0],) + (1,) * (leaf.ndim - 1)
    return jnp.arange(leaf.shape[0]).reshape(shape)


@dataclasses.dataclass(frozen=True)
class TrimmedMeanAggregator(Aggregator):
    """Coordinate-wise trimmed mean (Yin et al. 2018): per coordinate, sort
    the active clients' values, drop the ⌊trim_frac·n_act⌋ smallest and
    largest, average the rest. Tolerates up to trim_frac corrupted clients
    per round regardless of what they upload. ``trim_frac`` is clamped so
    at least one value always survives (n_act − 2k ≥ 1)."""

    trim_frac: float = 0.25

    def __call__(self, client_params, wts, key):
        active = (wts > 0).astype(jnp.float32)
        n_act = jnp.maximum(jnp.sum(active), 1.0)
        k = jnp.minimum(jnp.floor(self.trim_frac * n_act),
                        jnp.ceil(n_act / 2.0) - 1.0)

        def leaf(s):
            srt = _sorted_active(s, active)
            r = _ranks_like(s)
            keep = ((r >= k) & (r < n_act - k)).astype(jnp.float32)
            total = jnp.sum(jnp.where(keep > 0, srt, 0.0), axis=0)
            return (total / jnp.maximum(n_act - 2.0 * k, 1.0)).astype(s.dtype)

        return jax.tree.map(leaf, client_params)


@dataclasses.dataclass(frozen=True)
class MedianAggregator(Aggregator):
    """Coordinate-wise median over the round's active clients — the
    trim_frac → 0.5 limit of the trimmed mean; maximal per-round breakdown
    tolerance (< n_act/2 corrupted clients) at the cost of discarding the
    most averaging."""

    def __call__(self, client_params, wts, key):
        active = (wts > 0).astype(jnp.float32)
        n_act = jnp.maximum(jnp.sum(active), 1.0).astype(jnp.int32)
        lo = (n_act - 1) // 2
        hi = n_act // 2

        def leaf(s):
            srt = _sorted_active(s, active)
            med = 0.5 * (jnp.take(srt, lo, axis=0) +
                         jnp.take(srt, hi, axis=0))
            return med.astype(s.dtype)

        return jax.tree.map(leaf, client_params)


@dataclasses.dataclass(frozen=True)
class NormClipAggregator(Aggregator):
    """Weighted FedAvg over norm-clipped client *deltas*: each client's
    update is re-expressed as θ_i − θ_prev, clipped to global L2 norm
    ≤ ``clip``, then averaged and re-applied to θ_prev. Bounds any single
    client's pull on the aggregate (the standard defense against
    scaled/boosted updates; also the DP-FedAvg sensitivity bound, so it
    composes naturally under ``GaussianDPAggregator``)."""

    clip: float = 1.0
    needs_prev: ClassVar[bool] = True

    def __call__(self, client_params, wts, key, *, prev):
        wn = _normalize(wts)
        deltas = jax.tree.map(
            lambda s, p: s.astype(jnp.float32) - p.astype(jnp.float32)[None],
            client_params, prev)
        sq = sum(jnp.sum(d * d, axis=tuple(range(1, d.ndim)))
                 for d in jax.tree.leaves(deltas))  # (N,) per-client ‖δ‖²
        scale = jnp.minimum(1.0, self.clip / jnp.maximum(jnp.sqrt(sq), 1e-12))

        def leaf(d, p):
            shape = (d.shape[0],) + (1,) * (d.ndim - 1)
            agg = jnp.tensordot(wn, d * scale.reshape(shape), axes=1)
            return (p.astype(jnp.float32) + agg).astype(p.dtype)

        return jax.tree.map(leaf, deltas, prev)


@dataclasses.dataclass(frozen=True)
class BufferedAsyncAggregator(Aggregator):
    """FedBuffer-style buffered-async aggregation (Nguyen et al. 2022):
    clients report whenever they finish, the server buffers their deltas
    and applies one decayed server step per sync instead of gating on the
    slowest silo. Each contribution is down-weighted by a polynomial
    staleness discount (1 + s_i)^(−staleness_alpha), where s_i counts the
    syncs since client i's data was fresh; ``server_lr`` scales the
    aggregate step. With all-zero staleness and server_lr=1 this reduces
    to weighted FedAvg expressed in delta form. ``clip > 0`` additionally
    norm-clips each delta (compose robustness with asynchrony)."""

    server_lr: float = 1.0
    staleness_alpha: float = 0.5
    clip: float = 0.0
    needs_prev: ClassVar[bool] = True
    needs_staleness: ClassVar[bool] = True

    def __call__(self, client_params, wts, key, *, prev, staleness):
        decay = (1.0 + jnp.maximum(staleness, 0.0)) ** (-self.staleness_alpha)
        wn = _normalize(wts * decay)
        deltas = jax.tree.map(
            lambda s, p: s.astype(jnp.float32) - p.astype(jnp.float32)[None],
            client_params, prev)
        if self.clip > 0.0:
            sq = sum(jnp.sum(d * d, axis=tuple(range(1, d.ndim)))
                     for d in jax.tree.leaves(deltas))
            scale = jnp.minimum(1.0,
                                self.clip / jnp.maximum(jnp.sqrt(sq), 1e-12))
        else:
            scale = jnp.ones_like(wn)

        def leaf(d, p):
            shape = (d.shape[0],) + (1,) * (d.ndim - 1)
            agg = jnp.tensordot(wn, d * scale.reshape(shape), axes=1)
            return (p.astype(jnp.float32)
                    + self.server_lr * agg).astype(p.dtype)

        return jax.tree.map(leaf, deltas, prev)
