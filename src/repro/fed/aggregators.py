"""Pluggable server-side aggregation strategies (Alg. 1 line 11).

``core/federated.fedavg_round`` dispatches its aggregation step through one
of these instead of a hard-coded branch, so secure aggregation and DP noise
ride the same scan-fused/cached fit paths as plain FedAvg. Every strategy
implements

    aggregator(client_params, wts, key) -> new_params

where ``client_params`` is the stacked (N-leading) client-update pytree,
``wts`` the raw per-client aggregation weights (dataset sizes × the round's
active mask — zero for inactive clients), and ``key`` the round's
aggregation PRNG key (the same stream the legacy ``dp_sigma`` path drew
noise from).

Strategies are frozen dataclasses: hashable, so the compiled-fit caches in
``core/federated.py`` can key on them — a fit with the same aggregator
reuses its compiled scan. An unhashable custom strategy still works; it
just gets a fresh jit per fit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import secure_agg as SA


def _normalize(wts: jnp.ndarray) -> jnp.ndarray:
    """The legacy fedavg weight normalization, verbatim — every strategy
    shares it so the plain path stays bit-for-bit the pre-refactor code."""
    return wts / jnp.maximum(jnp.sum(wts), 1e-12)


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """Base strategy; subclass and implement ``__call__``."""

    def __call__(self, client_params, wts, key):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FedAvgAggregator(Aggregator):
    """Plain weighted FedAvg — bit-for-bit the pre-refactor aggregation
    (normalize, f32 tensordot over the client axis, cast back)."""

    def __call__(self, client_params, wts, key):
        wn = _normalize(wts)
        return jax.tree.map(
            lambda s: jnp.tensordot(wn, s.astype(jnp.float32),
                                    axes=1).astype(s.dtype),
            client_params)


@dataclasses.dataclass(frozen=True)
class SecureAggAggregator(Aggregator):
    """Pairwise-masked FedAvg (Bonawitz et al. 2016, via
    ``core/secure_agg``): every pair of round participants derives a shared
    mask from the round key; each client folds its pair masks (+ below the
    partner id, − above) into its upload, so the server's weighted sum
    carries every mask once with each sign and learns only the aggregate.

    Simulation notes: masks are gated by the round's participant set
    (``wts > 0`` — in the real protocol the key-agreement round fixes the
    participants before masking, so a dropped client's masks are never
    sent), and each client folds its net mask into the update it uploads so
    the server-side reduction is the *same tensordot* as plain FedAvg. That
    makes cancellation structural: with ``scale=0`` the masks are exact
    zeros and the result is bit-identical to ``FedAvgAggregator``
    (test-enforced); with ``scale>0`` the masks cancel to float rounding
    (~1e-6·scale per parameter).

    Mask generation is O(N²) in the client count — fine for the simulated
    cohorts this repo runs; the real protocol's key agreement amortizes it.
    """

    scale: float = 10.0

    def __call__(self, client_params, wts, key):
        N = int(wts.shape[0])
        wn = _normalize(wts)
        active = (wts > 0).astype(jnp.float32)       # the participant set
        unit = jax.tree.map(lambda s: s[0], client_params)
        nets = []
        for i in range(N):
            net = jax.tree.map(
                lambda a: jnp.zeros(a.shape, jnp.float32), unit)
            for j in range(N):
                if j == i:
                    continue
                m = SA._mask_like(SA._pair_key(key, i, j), unit, self.scale)
                sign = 1.0 if i < j else -1.0
                net = jax.tree.map(
                    lambda n, mm: n + sign * active[j] * mm, net, m)
            nets.append(net)
        net_stack = jax.tree.map(lambda *ls: jnp.stack(ls), *nets)
        # Client i uploads θ_i + net_i/w̃_i (it knows its own round weight),
        # so the server's weighted tensordot carries exactly w̃_i·θ_i +
        # net_i — the masked weighted sum — through the identical reduction
        # the plain path uses. Inactive clients (w̃ = 0) upload nothing.
        inv = jnp.where(wn > 0, 1.0 / jnp.maximum(wn, 1e-30), 0.0)

        def leaf(s, m):
            shape = (N,) + (1,) * (s.ndim - 1)
            upload = s.astype(jnp.float32) + inv.reshape(shape) * m
            return jnp.tensordot(wn, upload, axes=1).astype(s.dtype)

        return jax.tree.map(leaf, client_params, net_stack)


@dataclasses.dataclass(frozen=True)
class GaussianDPAggregator(Aggregator):
    """Server-side Gaussian noise on the aggregate (the central-DP flavour
    of the paper's privacy motivation), composing over any inner strategy.
    With the default FedAvg inner this is bit-for-bit the legacy
    ``fedavg(dp_sigma=...)`` path: the noise is keyed by the round's
    aggregation key exactly as before, and the inner strategy receives a
    folded key so its own randomness (e.g. secure-agg masks) never
    correlates with the noise."""

    sigma: float = 0.0
    inner: Aggregator = FedAvgAggregator()

    def __call__(self, client_params, wts, key):
        out = self.inner(client_params, wts, jax.random.fold_in(key, 1))
        if self.sigma <= 0.0:
            return out
        leaves, treedef = jax.tree.flatten(out)
        keys = jax.random.split(key, len(leaves))
        leaves = [l + self.sigma * jax.random.normal(k, l.shape, l.dtype)
                  for l, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, leaves)
