"""Deterministic fault injection for the federation runtime.

A ``FaultPlan`` is a frozen, seeded description of everything that can go
wrong between the clients and the server: clients dropping out of sync
rounds, straggling silos reporting stale updates, Byzantine clients
uploading corrupted updates or flipping their harvested outcome labels,
``report_outcome`` calls that never arrive, and pool-model backends that
fail a request. Every draw is a pure function of ``(seed, tags)`` — no
global RNG state, no wall clock — so a faulted run is exactly
reproducible, a killed-and-restored run replays the same faults, and CI
floors are deterministic accounting rather than flaky thresholds.

Consumers:
  * scenario / bench drivers call the predicate methods per event
    (``client_drops``, ``flip_label``, ``lose_outcome``);
  * ``RoutedServer(fault_plan=...)`` consults ``backend_fails`` per submit
    attempt and retries / re-routes (see ``serve/gateway.py``);
  * the fit path takes faults as an *aggregator wrapper*:
    ``CorruptUpdates`` applies sign-flip / scaled-noise corruption to the
    stacked client updates before delegating to any inner strategy, so
    Byzantine rounds ride the cached scan-fused fits untouched (the
    wrapper is hashable — same compiled-fit caches as everything else).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.aggregators import Aggregator, FedAvgAggregator


def _unit(seed: int, *tags) -> float:
    """Deterministic uniform in [0, 1) from (seed, tags): crc32 of the
    repr — stable across processes and runs (unlike builtin ``hash``)."""
    h = zlib.crc32(repr((seed,) + tags).encode("utf-8"))
    return h / 2.0 ** 32


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded description of injected faults. All probabilities in [0, 1];
    the zero plan (all defaults) injects nothing."""

    seed: int = 0
    #: P(a client misses a given sync round) — participation churn.
    dropout: float = 0.0
    #: fraction of clients that straggle (their updates arrive stale).
    delay_frac: float = 0.0
    #: stragglers report 1..max_staleness syncs late.
    max_staleness: int = 4
    #: fraction of clients whose uploads are corrupted (stable identities —
    #: Byzantine clients stay Byzantine).
    corrupt_frac: float = 0.0
    #: P(a corrupted client flips one harvested outcome label).
    label_flip: float = 0.0
    #: P(a report_outcome call is lost in transit).
    lose_outcomes: float = 0.0
    #: P(a pool backend fails one submit attempt).
    backend_fail: float = 0.0
    #: backends that are hard-down (every attempt fails).
    fail_models: Tuple[int, ...] = ()
    # --- engine-level chaos (serve/engine.py overload faults) ---
    #: P(a traffic tick is a burst tick — burst_max arrivals at once).
    burst_rate: float = 0.0
    #: arrivals on a burst tick (non-burst ticks follow the driver's own
    #: arrival process).
    burst_max: int = 8
    #: P(a tick window of storm_len ticks is a DEADLINE STORM — every
    #: arrival in the window carries storm_deadline).
    storm_rate: float = 0.0
    storm_len: int = 8
    #: deadline (engine steps) attached to storm-window arrivals.
    storm_deadline: int = 4
    #: P(a given request is cancelled mid-flight — a cancel storm is a
    #: high cancel_rate).
    cancel_rate: float = 0.0
    #: P(a tick suffers a page-pressure spike: the driver scales that
    #: tick's arrivals' decode budgets by spike_scale, stressing the pool).
    spike_rate: float = 0.0
    spike_scale: int = 4

    # ------------------------------------------------- client-side faults

    def client_drops(self, client: int, rnd: int) -> bool:
        """Does ``client`` miss sync round ``rnd``?"""
        return _unit(self.seed, "drop", int(client), int(rnd)) < self.dropout

    def corrupted_clients(self, n_clients: int) -> np.ndarray:
        """(n_clients,) bool — the stable Byzantine identity set:
        ⌊corrupt_frac·n⌋ clients drawn once per plan."""
        k = int(np.floor(self.corrupt_frac * n_clients))
        mask = np.zeros(n_clients, bool)
        if k > 0:
            rng = np.random.default_rng(self.seed * 1_000_003 + 0xBAD)
            mask[rng.choice(n_clients, size=k, replace=False)] = True
        return mask

    def straggler_clients(self, n_clients: int) -> np.ndarray:
        """(n_clients,) bool — the stable straggling-silo set."""
        k = int(np.floor(self.delay_frac * n_clients))
        mask = np.zeros(n_clients, bool)
        if k > 0:
            rng = np.random.default_rng(self.seed * 1_000_003 + 0x51_0)
            mask[rng.choice(n_clients, size=k, replace=False)] = True
        return mask

    def staleness(self, n_clients: int, sync: int) -> np.ndarray:
        """(n_clients,) int — syncs each client's update is late by at
        sync index ``sync``: 0 for healthy silos, 1..max_staleness for
        stragglers (per-sync draw, stable identities)."""
        out = np.zeros(n_clients, np.int64)
        for c in np.flatnonzero(self.straggler_clients(n_clients)):
            u = _unit(self.seed, "stale", int(c), int(sync))
            out[c] = 1 + int(u * self.max_staleness)
        return out

    def flip_label(self, client: int, event: int) -> bool:
        """Does a corrupted client flip the outcome label of its
        ``event``-th harvested observation? (Callers gate on membership in
        ``corrupted_clients`` — identity and per-event draws separate.)"""
        return _unit(self.seed, "flip", int(client),
                     int(event)) < self.label_flip

    def lose_outcome(self, rid: int) -> bool:
        """Is the report_outcome call for request ``rid`` lost?"""
        return _unit(self.seed, "lost", int(rid)) < self.lose_outcomes

    # ------------------------------------------------ server-side faults

    def backend_fails(self, m_idx: int, seq: int, attempt: int) -> bool:
        """Does backend ``m_idx`` fail attempt ``attempt`` of submission
        ``seq``? Hard-down backends (``fail_models``) always fail; others
        fail each attempt independently with ``backend_fail`` probability,
        so retries of transient faults can succeed."""
        if int(m_idx) in self.fail_models:
            return True
        return _unit(self.seed, "backend", int(m_idx), int(seq),
                     int(attempt)) < self.backend_fail

    # ------------------------------------------------ engine-level faults
    # Overload chaos for the serving layer. Every draw is the same pure
    # (seed, tags) scheme as above, so a chaos schedule — bursts, deadline
    # storms, cancel storms, page-pressure spikes — is exactly reproducible
    # from the plan alone (fed/scenarios.engine_chaos_schedule consumes
    # these; bench_preempt and the chaos property tests replay them).

    def burst_size(self, tick: int) -> int:
        """Arrivals injected at traffic tick ``tick`` on top of the
        driver's own process: ``burst_max`` on a burst tick, else 0."""
        if _unit(self.seed, "burst", int(tick)) < self.burst_rate:
            return int(self.burst_max)
        return 0

    def deadline_storm(self, tick: int) -> bool:
        """Is ``tick`` inside a deadline-storm window? Windows cover
        ``storm_len`` consecutive ticks (one draw per window), so a storm
        is a sustained front of deadline-carrying arrivals, not isolated
        ticks."""
        window = int(tick) // max(int(self.storm_len), 1)
        return _unit(self.seed, "storm", window) < self.storm_rate

    def cancels_request(self, rid: int) -> bool:
        """Is request ``rid`` fated to be cancelled mid-flight?"""
        return _unit(self.seed, "cancel", int(rid)) < self.cancel_rate

    def cancel_after(self, rid: int, horizon: int) -> int:
        """Engine steps a fated request lives before its cancel lands:
        1..horizon, deterministic per rid."""
        u = _unit(self.seed, "cancel_at", int(rid))
        return 1 + int(u * max(int(horizon), 1))

    def page_spike(self, tick: int) -> int:
        """Decode-budget multiplier for arrivals at ``tick``:
        ``spike_scale`` on a page-pressure spike tick (long generations
        squeeze the page pool), else 1."""
        if _unit(self.seed, "spike", int(tick)) < self.spike_rate:
            return int(self.spike_scale)
        return 1

    # ------------------------------------------------------- fit wrapper

    def corrupt_updates(self, n_clients: int, inner: Aggregator = None, *,
                        mode: str = "sign_flip",
                        scale: float = 10.0) -> "CorruptUpdates":
        """Build the aggregator wrapper applying this plan's Byzantine set
        to a fit over ``n_clients`` stacked clients."""
        return CorruptUpdates(
            mask=tuple(bool(b) for b in self.corrupted_clients(n_clients)),
            inner=inner if inner is not None else FedAvgAggregator(),
            mode=mode, scale=scale)


@dataclasses.dataclass(frozen=True)
class CorruptUpdates(Aggregator):
    """Byzantine clients as an aggregator wrapper: corrupt the masked
    rows of the stacked client-update slab *before* the inner strategy
    aggregates — exactly what the server would receive from malicious
    participants, with zero changes to the fit machinery.

    Modes: ``"sign_flip"`` uploads θ_prev − scale·(θ_i − θ_prev) (the
    classic scaled sign-flipping attack — the honest delta reversed and
    amplified; ``scale=1`` is the pure reflection); ``"scaled_noise"``
    adds ``scale``·N(0,1) noise to the corrupted rows (a blown-up/garbage
    update). The mask is a tuple, so the wrapper is hashable and rides the
    compiled-fit caches; it indexes the *stacked* client axis (when used
    with ``cohort=`` sampling the mask applies post-gather, so corrupt
    fractions — not identities — are what you control there).
    """

    mask: Tuple[bool, ...] = ()
    inner: Aggregator = FedAvgAggregator()
    mode: str = "sign_flip"
    scale: float = 10.0

    @property
    def needs_prev(self) -> bool:
        # sign_flip reverses deltas, which needs the round's input params;
        # declared unconditionally so the wrapper's traced signature
        # doesn't depend on the mode.
        return True

    @property
    def needs_staleness(self) -> bool:
        return getattr(self.inner, "needs_staleness", False)

    def __call__(self, client_params, wts, key, *, prev, staleness=None):
        if self.mode not in ("sign_flip", "scaled_noise"):
            raise ValueError(f"unknown corruption mode {self.mode!r}")
        n = jax.tree.leaves(client_params)[0].shape[0]
        if len(self.mask) != n:
            raise ValueError(
                f"CorruptUpdates mask covers {len(self.mask)} clients but "
                f"the stacked update slab has {n} — build the wrapper with "
                f"corrupt_updates(n_clients={n})")
        m = jnp.asarray(self.mask, jnp.float32)
        leaves, treedef = jax.tree.flatten(client_params)
        prev_leaves = jax.tree.leaves(prev)
        noise_key = jax.random.fold_in(key, zlib.crc32(b"corrupt"))

        def corrupt(i, s, p):
            shape = (s.shape[0],) + (1,) * (s.ndim - 1)
            mm = m.reshape(shape)
            s32 = s.astype(jnp.float32)
            if self.mode == "sign_flip":
                p32 = p.astype(jnp.float32)[None]
                bad = p32 - self.scale * (s32 - p32)
            else:
                k = jax.random.fold_in(noise_key, i)
                bad = s32 + self.scale * jax.random.normal(k, s.shape)
            return (mm * bad + (1.0 - mm) * s32).astype(s.dtype)

        corrupted = jax.tree.unflatten(
            treedef, [corrupt(i, s, p) for i, (s, p)
                      in enumerate(zip(leaves, prev_leaves))])
        extras = {}
        if getattr(self.inner, "needs_prev", False):
            extras["prev"] = prev
        if getattr(self.inner, "needs_staleness", False):
            extras["staleness"] = (staleness if staleness is not None
                                   else jnp.zeros_like(wts))
        return self.inner(corrupted, wts, jax.random.fold_in(key, 2),
                          **extras)
