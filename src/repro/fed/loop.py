"""Loop layer: the FedLoop scheduler — serve → harvest → federate → swap.

A ``FedLoop`` wraps a ``RoutedServer`` (with a ``HarvestStore`` attached)
and interleaves federated refits over the harvested client buffers with the
engine's decode chunks:

  * ``step()`` advances every busy engine lane one chunk (exactly
    ``RoutedServer.step``) and, at ``sync_every``-chunk boundaries with
    enough harvested samples, runs a federated sync.
  * ``sync()`` is literally ``routers.fit_federated`` over
    ``harvest.as_federated_data()`` starting from the live router's state —
    so an offline fit over the same buffers with the same key reproduces an
    online sync bit-for-bit (test-enforced) — followed by
    ``server.swap_router_state``: the refit state enters the cached route
    jit as a traced argument, ZERO retraces, while decode keeps running.
  * ``onboard_model()`` admits a new ``PoolModel`` mid-run (§6.3): new head
    columns trained on calibration evals, pool extended, expanded router
    installed (one route retrace for the new head shape — decode programs
    untouched).

Padding harvested data to the buffer capacity (``pad_to_capacity``, the
default) keeps the federated stack's shapes static across syncs, so the
compiled scan fit from ``core/federated.py`` is built once and every later
sync is a pure cache hit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

import repro.sharding as shd
from repro import routers
from repro.config import FedConfig
from repro.core import federated as F
from repro.train import checkpoint as ckpt

#: FedLoop.save() payload format version (bumped on layout changes).
CHECKPOINT_FORMAT = 1


@dataclasses.dataclass(frozen=True)
class FedLoopConfig:
    sync_every: int = 16      #: engine chunks between federated syncs
    rounds_per_sync: int = 4  #: FedAvg rounds per sync (ignored by one-shot
    #: families, which refit from scratch each sync)
    min_samples: int = 16     #: total harvested samples required to sync
    pad_to_capacity: bool = True  #: pad the federated stack to the buffer
    #: capacity — static shapes, one compile for every sync
    cohort: Optional[int] = None  #: per-round client sampling inside each
    #: sync's fit (parametric families; see core/federated.fedavg)
    mesh: Optional[Mesh] = None  #: cross-silo mesh for the sync fits: the
    #: harvested client stack is padded to the "clients" axis (zero-weight
    #: rows — they never move the params), sharded across devices, and the
    #: whole fit runs under shard_map (core/federated.fedavg mesh path) —
    #: bit-for-bit the in-process fit on the same padded stack. The padded
    #: slab is donated into the compiled fit on parametric families. The
    #: hot-swap after each sync is mesh-agnostic (state enters the route
    #: jit as a traced argument either way)


class FedLoop:
    """Online federation runtime over one ``RoutedServer``.

    Owns the PRNG stream for the online refits, so a loop run is exactly
    reproducible from its seed; ``history`` records one entry per sync
    (router version, per-round losses, harvested sample count).
    """

    def __init__(self, server, fcfg: FedConfig, *, key,
                 aggregator=None, cfg: Optional[FedLoopConfig] = None):
        if server.harvest is None:
            raise ValueError("FedLoop needs a RoutedServer with a "
                             "HarvestStore attached (harvest=...)")
        self.server = server
        self.fcfg = fcfg
        self.aggregator = aggregator
        self.cfg = cfg or FedLoopConfig()
        self._key = key
        self._chunks = 0
        self.history: List[Dict[str, Any]] = []
        # Staleness bookkeeping for buffered-async aggregators: per client,
        # the lifetime sample count at the previous sync and the sync index
        # at which it last contributed fresh data.
        self._syncs = 0
        self._seen_at_sync: Dict[int, int] = {}
        self._fresh_at_sync: Dict[int, int] = {}

    @property
    def version(self) -> int:
        """The served router version (bumped by syncs and onboarding)."""
        return self.server.router_version

    # ------------------------------------------------------------- serving
    def step(self) -> List[Tuple[int, np.ndarray]]:
        """One engine chunk on every busy lane; a federated sync fires at
        ``sync_every`` boundaries once ``min_samples`` are harvested.
        Returns the requests finished this chunk, like ``server.step``."""
        finished = self.server.step()
        self._chunks += 1
        if self._chunks % self.cfg.sync_every == 0:
            self.maybe_sync()
        return finished

    def drain(self) -> Dict[int, np.ndarray]:
        """Step (with sync boundaries) until every lane is idle."""
        out: Dict[int, np.ndarray] = {}
        while self.server.engine.busy:
            out.update(self.step())
        return out

    # ---------------------------------------------------------- federation
    def maybe_sync(self):
        """Sync iff enough evaluations are harvested; None otherwise."""
        if len(self.server.harvest) < self.cfg.min_samples:
            return None
        return self.sync()

    def sync(self, *, key=None) -> dict:
        """One federated refit over the harvested buffers + hot-swap.

        Exactly ``routers.fit_federated(server.router, harvested, fcfg)``
        from the live router's state — deterministically harvested buffers
        therefore reproduce an offline fit bit-for-bit (test-enforced).
        Returns the fit history."""
        harvest = self.server.harvest
        if len(harvest) == 0:
            raise ValueError("sync() with empty harvest buffers would "
                             "aggregate zero-weight clients — serve some "
                             "traffic first (maybe_sync gates on "
                             "min_samples)")
        if key is None:
            self._key, key = jax.random.split(self._key)
        data = harvest.as_federated_data(
            pad_to=harvest.capacity if self.cfg.pad_to_capacity else None)
        kw = {} if self.aggregator is None else {
            "aggregator": self.aggregator}
        if self.cfg.cohort is not None:
            kw["cohort"] = self.cfg.cohort
        if getattr(self.aggregator, "needs_staleness", False):
            ids = harvest.client_ids()
            if not self.cfg.pad_to_capacity:  # unpadded stacks skip empties
                ids = [c for c in ids if len(harvest.buffer(c)) > 0]
            kw["staleness"] = self._staleness_vector(ids)
        if self.cfg.mesh is not None:
            # pad the stack to the clients axis (zero-weight rows), place
            # it sharded, and run the whole sync fit on the mesh; the slab
            # is freshly built each sync, so parametric fits may donate it
            data, stal = F.pad_client_axis(
                data, self.cfg.mesh.shape["clients"], kw.get("staleness"))
            if stal is not None:
                kw["staleness"] = stal
            data = shd.shard_clients(data, self.cfg.mesh)
            kw["mesh"] = self.cfg.mesh
            if self.server.router.parametric:
                kw["donate_data"] = True
        new_router, hist = routers.fit_federated(
            self.server.router, data, self.fcfg, key=key,
            rounds=self.cfg.rounds_per_sync, **kw)
        self.server.swap_router_state(new_router.state)
        self._note_sync()
        # snapshot the engine's resilience counters alongside each sync so
        # a history trace shows how much shedding/preemption/expiry the
        # serving layer absorbed while this router version was learned
        self.history.append({"version": self.version,
                             "loss": hist["loss"],
                             "samples": len(harvest),
                             "engine": self.server.engine.counters()})
        return hist

    def _staleness_vector(self, ids) -> np.ndarray:
        """(N,) syncs since each stacked client (sorted ids — the
        ``as_federated_data`` order) last contributed fresh samples; 0 for
        clients with new data since the previous sync."""
        out = []
        for c in ids:
            seen = self.server.harvest.buffer(c).total_seen
            if seen > self._seen_at_sync.get(c, 0):
                out.append(0)
            else:
                out.append(self._syncs - self._fresh_at_sync.get(c, 0))
        return np.asarray(out, np.float32)

    def _note_sync(self) -> None:
        """Advance the staleness bookkeeping after a completed sync."""
        for c in self.server.harvest.client_ids():
            seen = self.server.harvest.buffer(c).total_seen
            if seen > self._seen_at_sync.get(c, 0):
                self._fresh_at_sync[c] = self._syncs
            self._seen_at_sync[c] = seen
        self._syncs += 1

    # -------------------------------------------------- checkpoint / resume
    def save(self, path) -> None:
        """Checkpoint the WHOLE loop — router state + version, every
        harvest ring (verbatim: write heads, lifetime counters, LRU
        order), the loop's PRNG key, chunk counter, staleness bookkeeping,
        sync history, pending evaluations, and the engine's rid counter —
        via ``train/checkpoint`` (msgpack, atomic write). A loop restored
        from this file continues BIT-IDENTICALLY to one that was never
        interrupted (test-enforced).

        Requires an idle engine: in-flight KV state is not checkpointable,
        so ``drain()`` first. Pending evaluations (submitted, outcome not
        yet reported) survive: they are host-side tuples."""
        if self.server.engine.busy:
            raise ValueError("save() needs an idle engine — drain() "
                             "in-flight requests first (decode KV state "
                             "is not checkpointable; queued, active, and "
                             "preempted-awaiting-resume requests all count "
                             "as in-flight)")
        srv = self.server
        payload = {
            "format": CHECKPOINT_FORMAT,
            "family": srv.router.name,
            "router_state": srv.router.state,
            "router_version": int(srv.router_version),
            "key": self._key,
            "chunks": int(self._chunks),
            "syncs": int(self._syncs),
            "seen_at_sync": [[int(c), int(v)]
                             for c, v in self._seen_at_sync.items()],
            "fresh_at_sync": [[int(c), int(v)]
                              for c, v in self._fresh_at_sync.items()],
            "history": self.history,
            "harvest": srv.harvest.state(),
            "pending": [[int(rid), int(c), x, int(m), float(co)]
                        for rid, (c, x, m, co)
                        in srv._pending_evals.items()],
            "next_rid": int(srv.engine._next_rid),
        }
        ckpt.save(path, payload)

    def restore(self, path) -> "FedLoop":
        """Load a ``save()`` checkpoint into this (freshly constructed,
        structurally identical) loop: same pool, same router family/config,
        same harvest d_emb/capacity. Returns self. The restored loop's
        subsequent routes, syncs, and history are bit-identical to the
        uninterrupted run's."""
        blob = ckpt.restore(path)
        fmt = blob.get("format")
        if fmt != CHECKPOINT_FORMAT:
            raise ValueError(f"unsupported FedLoop checkpoint format {fmt} "
                             f"(this build reads {CHECKPOINT_FORMAT})")
        srv = self.server
        if blob["family"] != srv.router.name:
            raise ValueError(
                f"checkpoint holds a {blob['family']!r} router, this loop "
                f"serves {srv.router.name!r} — construct the server with "
                "the matching family")
        if srv.engine.busy:
            raise ValueError("restore() into a server with in-flight "
                             "requests — use a freshly built server")
        srv.router = srv.router.with_state(blob["router_state"])
        # keep the cached route jit: with_state rebuilds by class + rcfg,
        # identical for the same family (mirrors swap_router_state)
        srv._route_fn_router = srv.router
        srv.router_version = int(blob["router_version"])
        srv.harvest.load_state(blob["harvest"])
        srv._pending_evals = {
            int(rid): (int(c), np.asarray(x, np.float32), int(m), float(co))
            for rid, c, x, m, co in blob["pending"]}
        srv.engine._next_rid = int(blob["next_rid"])
        self._key = blob["key"]
        self._chunks = int(blob["chunks"])
        self._syncs = int(blob["syncs"])
        self._seen_at_sync = {int(c): int(v)
                              for c, v in blob["seen_at_sync"]}
        self._fresh_at_sync = {int(c): int(v)
                               for c, v in blob["fresh_at_sync"]}
        self.history = [dict(h) for h in blob["history"]]
        return self

    def onboard_model(self, pm, calib: dict, *, key,
                      steps: int = 100) -> None:
        """Mid-run pool expansion (§6.3): train the new model's head
        column on the calibration evals, then install model + expanded
        router. One model per call — ``server.add_model`` admits exactly
        one PoolModel."""
        router = self.server.router.onboard_model(
            calib, key=key, fcfg=self.fcfg, n_new=1, steps=steps)
        self.server.add_model(pm, router)


def personalize_client(fed_router, local_router, data_i: dict):
    """§6.4 composed with the loop: mix the FedLoop-produced global router
    with a client's locally fitted router, weighted per model by
    calibration errors on the client's own harvested samples
    (``EvalBuffer.as_client_data()``). Returns (predict_fn, (w_acc,
    w_cost)) exactly like ``core.personalization.make_personalized``."""
    from repro.core import personalization as P
    return P.make_personalized(fed_router.predict, local_router.predict,
                               data_i, fed_router.num_models)
