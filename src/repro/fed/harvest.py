"""Harvest layer: bounded per-client evaluation logs fed by live serving.

Every routed request appends (query embedding, chosen model id, outcome
score, cost) to the submitting client's ``EvalBuffer`` — producing exactly
the sparse, non-uniform-coverage evaluation matrices the paper assumes
(clients only ever observe the models they were routed to). The
``HarvestStore`` groups buffers by client and exposes the stacked, padded
federated view ``core/federated.py`` trains on.

Memory discipline: an ``EvalBuffer`` is a fixed-capacity numpy ring (the
deque-style cap the engine's ``TRACE_LOG`` uses) — sustained traffic
overwrites the oldest entries and host memory stays constant, test-pinned
in tests/test_fedloop.py.
"""
from __future__ import annotations

from typing import Dict, Iterable

import jax
import jax.numpy as jnp
import numpy as np


class EvalBuffer:
    """One client's bounded local (x, m, acc, cost) log, oldest-evicting."""

    def __init__(self, d_emb: int, capacity: int = 1024):
        if capacity < 1:
            raise ValueError("EvalBuffer capacity must be >= 1")
        self.d_emb = int(d_emb)
        self.capacity = int(capacity)
        self._x = np.zeros((self.capacity, self.d_emb), np.float32)
        self._m = np.zeros((self.capacity,), np.int32)
        self._acc = np.zeros((self.capacity,), np.float32)
        self._cost = np.zeros((self.capacity,), np.float32)
        self._total = 0  # lifetime appends; write head is _total % capacity

    def append(self, x, m: int, acc: float, cost: float) -> None:
        i = self._total % self.capacity
        self._x[i] = np.asarray(x, np.float32).reshape(self.d_emb)
        self._m[i] = int(m)
        self._acc[i] = float(acc)
        self._cost[i] = float(cost)
        self._total += 1

    def __len__(self) -> int:
        return min(self._total, self.capacity)

    @property
    def total_seen(self) -> int:
        """Lifetime appends (>= len once the ring has wrapped)."""
        return self._total

    @property
    def nbytes(self) -> int:
        """Host bytes held — constant for the buffer's lifetime."""
        return (self._x.nbytes + self._m.nbytes + self._acc.nbytes
                + self._cost.nbytes)

    def _order(self) -> np.ndarray:
        """Row indices in chronological (oldest → newest) order."""
        n = len(self)
        if self._total <= self.capacity:
            return np.arange(n)
        return (np.arange(n) + self._total) % self.capacity

    def as_client_data(self, pad_to: int | None = None) -> Dict[str, np.ndarray]:
        """Flat ``{"x","m","acc","cost","w"}`` in chronological order,
        zero-padded to ``pad_to`` rows (w marks real rows) — the layout
        ``fit_local`` and one client row of the federated stack expect."""
        n = len(self)
        D = int(pad_to) if pad_to is not None else max(n, 1)
        if n > D:
            raise ValueError(f"buffer holds {n} rows > pad_to={D}")
        order = self._order()
        out = {"x": np.zeros((D, self.d_emb), np.float32),
               "m": np.zeros((D,), np.int32),
               "acc": np.zeros((D,), np.float32),
               "cost": np.zeros((D,), np.float32),
               "w": np.zeros((D,), np.float32)}
        out["x"][:n] = self._x[order]
        out["m"][:n] = self._m[order]
        out["acc"][:n] = self._acc[order]
        out["cost"][:n] = self._cost[order]
        out["w"][:n] = 1.0
        return out

    # ------------------------------------------------------- checkpointing
    def state(self) -> Dict[str, np.ndarray]:
        """Raw ring contents + lifetime counter — restoring reproduces the
        buffer exactly (write head, wrap state, chronological order)."""
        return {"x": self._x.copy(), "m": self._m.copy(),
                "acc": self._acc.copy(), "cost": self._cost.copy(),
                "total": self._total}

    def load_state(self, state: Dict) -> None:
        x = np.asarray(state["x"], np.float32)
        if x.shape != (self.capacity, self.d_emb):
            raise ValueError(
                f"EvalBuffer state has ring shape {x.shape}, this buffer "
                f"is ({self.capacity}, {self.d_emb}) — construct the "
                "store with the checkpoint's d_emb/capacity")
        self._x = x.copy()
        self._m = np.asarray(state["m"], np.int32).copy()
        self._acc = np.asarray(state["acc"], np.float32).copy()
        self._cost = np.asarray(state["cost"], np.float32).copy()
        self._total = int(state["total"])


class HarvestStore:
    """client id → ``EvalBuffer``, plus the stacked federated view.

    Pre-registering the expected clients (``clients=range(N)``) keeps the
    federated stack's client dimension — and therefore the compiled scan
    fit's shapes — stable from the very first sync.

    ``max_clients`` bounds the number of LIVE buffers: when traffic spans
    more distinct clients than that (1k+ clients with power-law traffic
    and churn), the least-recently-written client's buffer is evicted, so
    harvest memory is O(max_clients) — O(cohort), not O(clients). Pair it
    with ``as_federated_data(client_ids=...)`` to fit on a sampled cohort
    slab of the warm clients."""

    def __init__(self, d_emb: int, capacity: int = 1024,
                 clients: Iterable[int] = (),
                 max_clients: int | None = None):
        self.d_emb = int(d_emb)
        self.capacity = int(capacity)
        self.max_clients = None if max_clients is None else int(max_clients)
        if self.max_clients is not None and self.max_clients < 1:
            raise ValueError("max_clients must be >= 1")
        self.evicted_clients = 0  #: lifetime LRU evictions (observability)
        # insertion order doubles as the LRU order: record() re-inserts
        self._buffers: Dict[int, EvalBuffer] = {}
        for c in clients:
            self.buffer(c)

    def buffer(self, client_id: int) -> EvalBuffer:
        b = self._buffers.get(int(client_id))
        if b is None:
            b = self._buffers[int(client_id)] = EvalBuffer(self.d_emb,
                                                           self.capacity)
            self._evict_cold()
        return b

    def _evict_cold(self) -> None:
        while (self.max_clients is not None
               and len(self._buffers) > self.max_clients):
            coldest = next(iter(self._buffers))
            del self._buffers[coldest]
            self.evicted_clients += 1

    def record(self, client_id: int, x, m: int, acc: float,
               cost: float) -> None:
        cid = int(client_id)
        b = self.buffer(cid)
        b.append(x, m, acc, cost)
        # move-to-end: this client is now the warmest in the LRU order
        del self._buffers[cid]
        self._buffers[cid] = b

    def client_ids(self) -> list[int]:
        return sorted(self._buffers)

    def __len__(self) -> int:
        """Samples currently held across every client buffer."""
        return sum(len(b) for b in self._buffers.values())

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._buffers.values())

    def as_federated_data(self, pad_to: int | None = None,
                          client_ids: Iterable[int] | None = None,
                          ) -> Dict[str, jnp.ndarray]:
        """Stacked, padded ``(N, D, ...)`` arrays over sorted client ids —
        exactly ``core/federated.py``'s client dataset layout, in
        deterministic (client id, chronological) order so an offline
        ``fit_federated`` over the same buffers reproduces an online sync
        bit-for-bit. ``pad_to=None`` pads to the fullest buffer;
        ``pad_to=capacity`` keeps D static so the compiled scan fit never
        retraces across syncs.

        Zero-sample clients (freshly registered, nothing harvested yet):
        the unpadded path SKIPS them — their buffers contribute no rows,
        so they cannot dilute the federated average with all-zero data —
        while the padded path KEEPS them as all-zero rows with a zero
        weight mask (``w = 0``), preserving the static client dimension;
        ``dataset_sizes`` then gives them zero aggregation weight, which
        is the same exclusion expressed shape-stably.

        ``client_ids`` restricts the stack to a subset (e.g. a sampled
        cohort of the warm clients under ``max_clients`` churn): the slab
        is (len(client_ids), D, ...) — O(cohort) device memory no matter
        how many clients the store has seen."""
        ids = (self.client_ids() if client_ids is None
               else sorted(int(c) for c in client_ids))
        if not ids:
            raise ValueError("no harvested clients — nothing to federate")
        missing = [c for c in ids if c not in self._buffers]
        if missing:
            raise ValueError(
                f"client_ids {missing} have no live buffer (never seen, or "
                "evicted by max_clients) — sample the cohort from "
                "client_ids()")
        if pad_to is None:
            ids = [c for c in ids if len(self._buffers[c]) > 0]
        if not ids or all(len(self._buffers[c]) == 0 for c in ids):
            raise ValueError("no harvested samples — every requested "
                             "client's buffer is empty; serve some traffic "
                             "first")
        D = (int(pad_to) if pad_to is not None
             else max(max(len(self._buffers[c]) for c in ids), 1))
        rows = [self._buffers[c].as_client_data(D) for c in ids]
        stacked = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
        return jax.tree.map(jnp.asarray, stacked)

    # ------------------------------------------------------- checkpointing
    def state(self) -> dict:
        """Serializable snapshot: every ring verbatim, in LRU order."""
        return {"d_emb": self.d_emb, "capacity": self.capacity,
                "clients": [[int(c), b.state()]
                            for c, b in self._buffers.items()]}

    def load_state(self, state: dict) -> None:
        """Restore a ``state()`` snapshot exactly (rings, lifetime
        counters, LRU order). The store must be constructed with the same
        d_emb/capacity."""
        if (int(state["d_emb"]) != self.d_emb
                or int(state["capacity"]) != self.capacity):
            raise ValueError(
                f"checkpoint is d_emb={int(state['d_emb'])}, capacity="
                f"{int(state['capacity'])}; this store is d_emb="
                f"{self.d_emb}, capacity={self.capacity}")
        self._buffers = {}
        for c, bstate in state["clients"]:
            b = EvalBuffer(self.d_emb, self.capacity)
            b.load_state(bstate)
            self._buffers[int(c)] = b
        self._evict_cold()
