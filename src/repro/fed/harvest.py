"""Harvest layer: bounded per-client evaluation logs fed by live serving.

Every routed request appends (query embedding, chosen model id, outcome
score, cost) to the submitting client's ``EvalBuffer`` — producing exactly
the sparse, non-uniform-coverage evaluation matrices the paper assumes
(clients only ever observe the models they were routed to). The
``HarvestStore`` groups buffers by client and exposes the stacked, padded
federated view ``core/federated.py`` trains on.

Memory discipline: an ``EvalBuffer`` is a fixed-capacity numpy ring (the
deque-style cap the engine's ``TRACE_LOG`` uses) — sustained traffic
overwrites the oldest entries and host memory stays constant, test-pinned
in tests/test_fedloop.py.
"""
from __future__ import annotations

from typing import Dict, Iterable

import jax
import jax.numpy as jnp
import numpy as np


class EvalBuffer:
    """One client's bounded local (x, m, acc, cost) log, oldest-evicting."""

    def __init__(self, d_emb: int, capacity: int = 1024):
        if capacity < 1:
            raise ValueError("EvalBuffer capacity must be >= 1")
        self.d_emb = int(d_emb)
        self.capacity = int(capacity)
        self._x = np.zeros((self.capacity, self.d_emb), np.float32)
        self._m = np.zeros((self.capacity,), np.int32)
        self._acc = np.zeros((self.capacity,), np.float32)
        self._cost = np.zeros((self.capacity,), np.float32)
        self._total = 0  # lifetime appends; write head is _total % capacity

    def append(self, x, m: int, acc: float, cost: float) -> None:
        i = self._total % self.capacity
        self._x[i] = np.asarray(x, np.float32).reshape(self.d_emb)
        self._m[i] = int(m)
        self._acc[i] = float(acc)
        self._cost[i] = float(cost)
        self._total += 1

    def __len__(self) -> int:
        return min(self._total, self.capacity)

    @property
    def total_seen(self) -> int:
        """Lifetime appends (>= len once the ring has wrapped)."""
        return self._total

    @property
    def nbytes(self) -> int:
        """Host bytes held — constant for the buffer's lifetime."""
        return (self._x.nbytes + self._m.nbytes + self._acc.nbytes
                + self._cost.nbytes)

    def _order(self) -> np.ndarray:
        """Row indices in chronological (oldest → newest) order."""
        n = len(self)
        if self._total <= self.capacity:
            return np.arange(n)
        return (np.arange(n) + self._total) % self.capacity

    def as_client_data(self, pad_to: int | None = None) -> Dict[str, np.ndarray]:
        """Flat ``{"x","m","acc","cost","w"}`` in chronological order,
        zero-padded to ``pad_to`` rows (w marks real rows) — the layout
        ``fit_local`` and one client row of the federated stack expect."""
        n = len(self)
        D = int(pad_to) if pad_to is not None else max(n, 1)
        if n > D:
            raise ValueError(f"buffer holds {n} rows > pad_to={D}")
        order = self._order()
        out = {"x": np.zeros((D, self.d_emb), np.float32),
               "m": np.zeros((D,), np.int32),
               "acc": np.zeros((D,), np.float32),
               "cost": np.zeros((D,), np.float32),
               "w": np.zeros((D,), np.float32)}
        out["x"][:n] = self._x[order]
        out["m"][:n] = self._m[order]
        out["acc"][:n] = self._acc[order]
        out["cost"][:n] = self._cost[order]
        out["w"][:n] = 1.0
        return out


class HarvestStore:
    """client id → ``EvalBuffer``, plus the stacked federated view.

    Pre-registering the expected clients (``clients=range(N)``) keeps the
    federated stack's client dimension — and therefore the compiled scan
    fit's shapes — stable from the very first sync."""

    def __init__(self, d_emb: int, capacity: int = 1024,
                 clients: Iterable[int] = ()):
        self.d_emb = int(d_emb)
        self.capacity = int(capacity)
        self._buffers: Dict[int, EvalBuffer] = {}
        for c in clients:
            self.buffer(c)

    def buffer(self, client_id: int) -> EvalBuffer:
        b = self._buffers.get(int(client_id))
        if b is None:
            b = self._buffers[int(client_id)] = EvalBuffer(self.d_emb,
                                                           self.capacity)
        return b

    def record(self, client_id: int, x, m: int, acc: float,
               cost: float) -> None:
        self.buffer(client_id).append(x, m, acc, cost)

    def client_ids(self) -> list[int]:
        return sorted(self._buffers)

    def __len__(self) -> int:
        """Samples currently held across every client buffer."""
        return sum(len(b) for b in self._buffers.values())

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._buffers.values())

    def as_federated_data(self, pad_to: int | None = None) -> Dict[str, jnp.ndarray]:
        """Stacked, padded ``(N, D, ...)`` arrays over sorted client ids —
        exactly ``core/federated.py``'s client dataset layout, in
        deterministic (client id, chronological) order so an offline
        ``fit_federated`` over the same buffers reproduces an online sync
        bit-for-bit. ``pad_to=None`` pads to the fullest buffer;
        ``pad_to=capacity`` keeps D static so the compiled scan fit never
        retraces across syncs."""
        ids = self.client_ids()
        if not ids:
            raise ValueError("no harvested clients — nothing to federate")
        D = (int(pad_to) if pad_to is not None
             else max(max(len(self._buffers[c]) for c in ids), 1))
        rows = [self._buffers[c].as_client_data(D) for c in ids]
        stacked = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
        return jax.tree.map(jnp.asarray, stacked)
