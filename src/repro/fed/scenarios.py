"""Scenario layer: traffic simulators driving the online federation runtime.

A ``TrafficScenario`` owns a synthetic evaluation corpus (the RouterBench
anatomy from ``data/synthetic.py``) and generates deterministic arrival
schedules over heterogeneous clients:

  * **query heterogeneity** — per-client Dirichlet mixtures over the
    corpus task clusters (the paper's §6 partition, but arriving live);
  * **distribution drift** — client mixtures re-drawn (interpolated by
    ``drift``) at every phase boundary, so a frozen router's world moves
    from under it;
  * **stragglers / partial participation** — a fraction of clients submits
    only a fraction of its turns, so its buffers stay thin and its
    federated weight small;
  * **mid-run model onboarding** — a reserved corpus model column joins
    the pool mid-run (§6.3) through ``FedLoop.onboard_model``;
  * **embedding-perturbation drift** — with ``embed_sigma > 0`` every
    phase after the first re-draws a Gaussian perturbation of the corpus
    embeddings (the encoder-space effect of paraphrased queries / an
    encoder update): routing and harvesting see the perturbed vectors
    while outcomes keep following the *true* per-query tables, so a
    frozen router degrades and an online one re-fits to the moved
    representation (the evalbench robustness scenario, run live).

Everything is seed-deterministic: arrivals, outcomes and test sets never
consult the wall clock, so ``run_online_vs_frozen`` produces identical
metrics on every run — CI can enforce the online-vs-frozen AUC floor
(``BENCH_fedloop.json``) without a statistical fudge factor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import routers
from repro.config import FedConfig, ModelConfig, RouterConfig
from repro.core import policy
from repro.data.synthetic import make_eval_corpus
from repro.fed.harvest import HarvestStore
from repro.fed.loop import FedLoop, FedLoopConfig

_WORDS = ("route the query to a model that answers well and cheaply "
          "summarize prove draft review plan code data chart essay").split()

#: tiny attention arch shared by every simulated pool entry — one compiled
#: program set serves the whole pool (names/costs differ per PoolModel).
SIM_MODEL = ModelConfig(name="sim-tiny", arch_type="dense", n_layers=2,
                        d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                        vocab=101, head_dim=16, dtype="float32")


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    n_clients: int = 6
    n_tasks: int = 6
    n_models: int = 3          #: pool size at t=0
    d_emb: int = 32
    n_queries: int = 1500      #: corpus size the traffic samples from
    queries_per_phase: int = 96
    phases: int = 2
    dirichlet_alpha: float = 0.35  #: client task concentration (lower =
    #: more heterogeneous)
    drift: float = 1.0         #: 0 = static mixtures, 1 = fully re-drawn
    straggler_frac: float = 0.34   #: fraction of clients that straggle
    straggler_rate: float = 0.25   #: a straggler submits this share of turns
    lam_choices: Tuple[float, ...] = (0.2, 0.5, 2.0)
    max_new: int = 4
    test_queries: int = 64     #: per (client, phase) evaluation draw
    embed_sigma: float = 0.0   #: phase ≥ 1 embedding perturbation scale
    seed: int = 0


class TrafficScenario:
    """Deterministic heterogeneous traffic over a synthetic eval corpus."""

    def __init__(self, cfg: ScenarioConfig, *, n_reserved_models: int = 0):
        self.cfg = cfg
        self.n_reserved = int(n_reserved_models)
        m_total = cfg.n_models + self.n_reserved
        self.corpus = make_eval_corpus(
            jax.random.PRNGKey(cfg.seed), n_queries=cfg.n_queries,
            n_tasks=cfg.n_tasks, n_models=m_total, d_emb=cfg.d_emb)
        task = np.asarray(self.corpus["task"])
        self._task_idx = [np.where(task == t)[0] for t in range(cfg.n_tasks)]
        rng = np.random.default_rng(cfg.seed)
        mix = rng.dirichlet(np.full(cfg.n_tasks, cfg.dirichlet_alpha),
                            size=cfg.n_clients)
        self.mixtures = [mix]
        for _ in range(1, cfg.phases):
            fresh = rng.dirichlet(np.full(cfg.n_tasks, cfg.dirichlet_alpha),
                                  size=cfg.n_clients)
            mix = (1.0 - cfg.drift) * mix + cfg.drift * fresh
            mix = mix / mix.sum(axis=1, keepdims=True)
            self.mixtures.append(mix)
        n_strag = int(round(cfg.straggler_frac * cfg.n_clients))
        self.stragglers = set(
            rng.choice(cfg.n_clients, size=n_strag, replace=False).tolist())
        self._outcome_rng = np.random.default_rng(cfg.seed + 7919)
        # per-phase (possibly perturbed) embedding views: phase 0 is the
        # clean corpus; later phases add a fresh seed-deterministic
        # Gaussian perturbation when embed_sigma > 0 (paraphrase /
        # encoder-update drift). Outcomes still key on the query index, so
        # only the *representation* moves, not the ground truth.
        x0 = np.asarray(self.corpus["x"], np.float32)
        self._x_phase = [x0]
        for p in range(1, cfg.phases):
            if cfg.embed_sigma > 0.0:
                prng = np.random.default_rng(cfg.seed * 7717 + p)
                noise = prng.standard_normal(x0.shape).astype(np.float32)
                self._x_phase.append(x0 + cfg.embed_sigma * noise)
            else:
                self._x_phase.append(x0)

    # ------------------------------------------------------------- traffic
    def events(self, phase: int) -> List[Tuple[int, int, float]]:
        """Deterministic arrival list for one phase: (client, query idx,
        λ). Stragglers skip most of their turns — their buffers stay thin
        and their federated weight small (the paper's partial-coverage
        clients)."""
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1000 + 13 * phase + 1)
        out = []
        for _ in range(cfg.queries_per_phase):
            c = int(rng.integers(cfg.n_clients))
            if c in self.stragglers and rng.random() > cfg.straggler_rate:
                continue
            t = int(rng.choice(cfg.n_tasks, p=self.mixtures[phase][c]))
            q = int(rng.choice(self._task_idx[t]))
            lam = float(rng.choice(np.asarray(cfg.lam_choices)))
            out.append((c, q, lam))
        return out

    def x(self, q: int, phase: int = 0) -> np.ndarray:
        """The query embedding as phase ``phase`` observes it (perturbed
        for phases ≥ 1 when ``embed_sigma`` > 0)."""
        return self._x_phase[phase][q]

    def prompt(self, q: int) -> str:
        """Deterministic filler text (the routing decision rides the
        embedding passed via submit(x=...); the prompt only feeds the stub
        tokenizer)."""
        return " ".join(_WORDS[(q + i) % len(_WORDS)]
                        for i in range(3 + q % 5))

    def observe(self, q: int, m: int) -> Tuple[float, float]:
        """The (acc, cost) the client logs for its routed model — a
        Bernoulli draw of the latent success probability plus the true
        cost, like ``data/synthetic.observe`` but host-side and sequential
        (deterministic given the arrival order)."""
        p = float(self.corpus["acc_table"][q, m])
        acc = float(self._outcome_rng.random() < p)
        return acc, float(self.corpus["cost_table"][q, m])

    # ----------------------------------------------------------- pool/eval
    def make_pool(self, n_models: Optional[int] = None) -> list:
        """PoolModels for the first ``n_models`` corpus columns — one
        shared tiny arch (single compiled program set), per-model costs
        from the corpus economics."""
        from repro.models import init_params
        from repro.serve.gateway import PoolModel
        n = self.cfg.n_models if n_models is None else n_models
        params = init_params(jax.random.PRNGKey(self.cfg.seed + 1),
                             SIM_MODEL)
        return [PoolModel(f"sim-m{i}", SIM_MODEL, params,
                          float(self.corpus["model_cost"][i]))
                for i in range(n)]

    def pool_model(self, m_idx: int):
        """One more PoolModel (a reserved corpus column) for onboarding."""
        return self.make_pool(n_models=m_idx + 1)[m_idx]

    def calib_for_model(self, m_idx: int, n: int = 128) -> Dict[str, np.ndarray]:
        """Calibration evals for onboarding model ``m_idx``: n corpus
        queries scored against that model — flat {"x","m","acc","cost","w"}
        with m == m_idx (the expanded pool's new column)."""
        rng = np.random.default_rng(self.cfg.seed * 31 + m_idx)
        qs = rng.integers(0, self.cfg.n_queries, size=n)
        acc = (rng.random(n) < np.asarray(self.corpus["acc_table"])[qs, m_idx])
        return {"x": np.asarray(self.corpus["x"])[qs].astype(np.float32),
                "m": np.full((n,), m_idx, np.int32),
                "acc": acc.astype(np.float32),
                "cost": np.asarray(self.corpus["cost_table"])[qs, m_idx]
                .astype(np.float32),
                "w": np.ones((n,), np.float32)}

    def test_set(self, phase: int, client: int) -> Dict[str, np.ndarray]:
        """Held-out queries drawn from the client's CURRENT (phase)
        mixture, with the true acc/cost tables for frontier scoring."""
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 4931 + 97 * phase + client)
        tasks = rng.choice(cfg.n_tasks, size=cfg.test_queries,
                           p=self.mixtures[phase][client])
        qs = np.array([rng.choice(self._task_idx[t]) for t in tasks])
        return {"x": self._x_phase[phase][qs],
                "acc_table": np.asarray(self.corpus["acc_table"])[qs],
                "cost_table": np.asarray(self.corpus["cost_table"])[qs]}


class PowerLawScenario:
    """Population-scale arrival generator: 1k+ clients, Zipf traffic, churn.

    The paper's deployment regime has far more clients than any round can
    hold — most clients are cold, a Zipf head carries the traffic, and the
    head itself drifts as clients churn in and out. This generator produces
    exactly that arrival structure, deterministically:

      * **power-law popularity** — client ranks carry Zipf(``zipf_a``)
        weight, so a handful of head clients dominate arrivals while the
        long tail appears rarely or never;
      * **churn** — every phase re-deals a ``churn`` fraction of the ranks
        among their holders, so yesterday's hot clients go cold (and their
        harvest buffers deserve eviction);
      * **O(cohort) harvest** — pair the arrivals with a
        ``HarvestStore(max_clients=...)`` and memory stays proportional to
        the warm set, not the population (test-pinned); sample fit cohorts
        from ``HarvestStore.client_ids()`` + ``fedavg(cohort=...)``.

    Arrivals are client ids only — compose with any corpus/outcome model
    (``TrafficScenario`` owns those concerns for the small-population
    benchmark). ``coverage_clients`` reports how many warm clients carry a
    target traffic share: the natural ``max_clients``/``cohort`` choice.
    """

    def __init__(self, n_clients: int = 1200, *, zipf_a: float = 1.1,
                 churn: float = 0.15, queries_per_phase: int = 512,
                 phases: int = 3, seed: int = 0):
        if n_clients < 2:
            raise ValueError("PowerLawScenario needs n_clients >= 2")
        if zipf_a <= 0:
            raise ValueError("zipf_a must be > 0")
        if not 0.0 <= churn <= 1.0:
            raise ValueError("churn must be in [0, 1]")
        self.n_clients = int(n_clients)
        self.zipf_a = float(zipf_a)
        self.churn = float(churn)
        self.queries_per_phase = int(queries_per_phase)
        self.phases = int(phases)
        self.seed = int(seed)
        rng = np.random.default_rng(seed * 611953 + 29)
        # rank r -> client id holding it; rank 0 is the traffic head
        holders = rng.permutation(self.n_clients)
        self._holders = [holders.copy()]
        n_churn = int(round(self.churn * self.n_clients))
        for _ in range(1, self.phases):
            holders = holders.copy()
            if n_churn >= 2:
                ranks = rng.choice(self.n_clients, size=n_churn,
                                   replace=False)
                holders[ranks] = holders[np.roll(ranks, 1)]
            self._holders.append(holders.copy())
        w = (1.0 + np.arange(self.n_clients)) ** (-self.zipf_a)
        self._rank_p = w / w.sum()

    def popularity(self, phase: int) -> np.ndarray:
        """(n_clients,) arrival probability per client id at ``phase``."""
        p = np.zeros(self.n_clients)
        p[self._holders[phase]] = self._rank_p
        return p

    def events(self, phase: int) -> np.ndarray:
        """Deterministic client-id arrival stream for one phase."""
        rng = np.random.default_rng(self.seed * 1000 + 13 * phase + 5)
        return rng.choice(self.n_clients, size=self.queries_per_phase,
                          p=self.popularity(phase))

    def coverage_clients(self, coverage: float = 0.9) -> int:
        """Smallest warm-client count carrying ``coverage`` of the traffic
        (phase-independent: churn moves which clients are warm, not how
        concentrated the traffic is)."""
        if not 0.0 < coverage <= 1.0:
            raise ValueError("coverage must be in (0, 1]")
        return int(np.searchsorted(np.cumsum(self._rank_p), coverage) + 1)


def engine_chaos_schedule(plan, *, ticks: int = 64,
                          arrivals_per_tick: int = 1,
                          prompt_lens: Tuple[int, int] = (3, 9),
                          max_new: int = 4, vocab: int = 97,
                          default_deadline: Optional[int] = None,
                          cancel_horizon: int = 12) -> List[dict]:
    """Deterministic engine-chaos schedule from a ``FaultPlan``: the one
    arrival stream both the chaos property tests and ``bench_preempt``
    replay, so a failure reproduces from (plan, kwargs) alone.

    Each tick carries ``arrivals_per_tick`` baseline arrivals plus the
    plan's burst (``burst_size``); arrivals in a deadline-storm window get
    ``plan.storm_deadline`` (others ``default_deadline``); page-pressure
    spike ticks scale ``max_new`` by ``plan.spike_scale``; cancel-fated
    arrivals (``cancels_request`` keyed on the submission ordinal) carry
    the step offset at which the driver should land their cancel. Every
    event dict: ``tick``, ``toks`` (int32 prompt), ``max_new``,
    ``deadline`` (engine steps from submit, or None), ``cancel_after``
    (engine steps from submit, or None)."""
    events: List[dict] = []
    ordinal = 0
    lo, hi = prompt_lens
    for t in range(int(ticks)):
        n = int(arrivals_per_tick) + plan.burst_size(t)
        storm = plan.deadline_storm(t)
        scale = plan.page_spike(t)
        for i in range(n):
            rng = np.random.default_rng(
                plan.seed * 9_176_941 + 131 * t + i)
            S = int(rng.integers(lo, hi + 1))
            toks = rng.integers(1, vocab, size=S).astype(np.int32)
            deadline = (plan.storm_deadline if storm else default_deadline)
            cancel_after = (plan.cancel_after(ordinal, cancel_horizon)
                            if plan.cancels_request(ordinal) else None)
            events.append({"tick": t, "toks": toks,
                           "max_new": int(max_new) * scale,
                           "deadline": deadline,
                           "cancel_after": cancel_after})
            ordinal += 1
    return events


def _frontier_auc(predict_fn, test: Dict[str, np.ndarray],
                  n_models: int) -> float:
    """Frontier AUC of a router on one test draw, scored on the true
    tables restricted to the models that router can actually route to
    (a frozen pre-onboarding router never uses a later-joined model)."""
    *_, auc = policy.eval_router(predict_fn, test["x"],
                                 test["acc_table"][:, :n_models],
                                 test["cost_table"][:, :n_models])
    return float(auc)


def run_online_vs_frozen(cfg: ScenarioConfig = ScenarioConfig(), *,
                         fcfg: Optional[FedConfig] = None,
                         lcfg: Optional[FedLoopConfig] = None,
                         engine_cfg=None, rcfg: Optional[RouterConfig] = None,
                         aggregator=None, onboard_phase: Optional[int] = None,
                         family: str = "mlp", local_steps: int = 200,
                         capacity: int = 256, seed: int = 0) -> dict:
    """The headline experiment behind ``BENCH_fedloop.json``: live traffic
    through the serving engine, evaluations harvested per client, and two
    deployments compared under drift —

      * **online**: one global router maintained by the ``FedLoop``
        (federated syncs over the harvested buffers, hot-swapped under
        traffic);
      * **frozen client-local**: each client fits its own router on its
        phase-0 harvest and never updates it (the no-federation baseline).

    Both are scored at every phase end as the mean frontier AUC over the
    clients' current (drifted) query mixtures. Returns the per-phase AUC
    curves plus loop/serving accounting. Fully deterministic in its seeds.

    ``family`` picks the router family from the zoo; it must cold-start —
    ``init(key)`` has to produce a servable state (parametric families and
    "elo"; "kmeans" cannot, its init is a no-op).
    """
    from repro.serve.engine import EngineConfig
    from repro.serve.gateway import RoutedServer

    scenario = TrafficScenario(
        cfg, n_reserved_models=1 if onboard_phase is not None else 0)
    fcfg = fcfg or FedConfig(num_clients=cfg.n_clients, participation=0.75,
                             batch_size=32, lr=3e-3)
    lcfg = lcfg or FedLoopConfig(sync_every=16, rounds_per_sync=4,
                                 min_samples=24)
    rcfg = rcfg or RouterConfig(d_emb=cfg.d_emb, num_models=cfg.n_models,
                                hidden=(32, 32), dropout=0.0)
    engine_cfg = engine_cfg or EngineConfig(slots=8, max_seq=32, chunk=4,
                                            page_size=8)

    pool = scenario.make_pool()
    router0 = routers.make(family, rcfg).init(jax.random.PRNGKey(seed + 11))
    if router0.state is None:
        raise ValueError(
            f"router family {family!r} cannot cold-start a live service: "
            "init() produced no state (one-shot families other than 'elo' "
            "need a pre-fitted router)")
    harvest = HarvestStore(cfg.d_emb, capacity=capacity,
                           clients=range(cfg.n_clients))
    srv = RoutedServer(pool, router0, engine_cfg=engine_cfg,
                       harvest=harvest)
    loop = FedLoop(srv, fcfg, key=jax.random.PRNGKey(seed + 13),
                   aggregator=aggregator, cfg=lcfg)

    frozen: List = []
    auc_online: List[float] = []
    auc_frozen: List[float] = []
    served = 0
    for phase in range(cfg.phases):
        if onboard_phase is not None and phase == onboard_phase:
            new_idx = cfg.n_models  # the reserved corpus column joins
            loop.onboard_model(scenario.pool_model(new_idx),
                               scenario.calib_for_model(new_idx),
                               key=jax.random.PRNGKey(seed + 17),
                               steps=150)
        for (c, q, lam) in scenario.events(phase):
            rid = srv.submit(scenario.prompt(q), lam=lam,
                             max_new_tokens=cfg.max_new, client_id=c,
                             x=scenario.x(q, phase))
            m = srv.routed_model(rid)
            srv.report_outcome(rid, *scenario.observe(q, m))
            loop.step()
            served += 1
        loop.drain()
        loop.maybe_sync()  # absorb the phase tail before scoring
        if phase == 0:
            # the no-federation deployment: client-local fits on exactly
            # what each client harvested in phase 0, frozen forever after.
            # A straggler with (almost) no data keeps the cold-start
            # router — the same init both deployments began serving with —
            # so both AUC means always average the SAME client population.
            for c in range(cfg.n_clients):
                data_c = harvest.buffer(c).as_client_data()
                if float(data_c["w"].sum()) < 2:
                    frozen.append(router0)
                    continue
                local_kw = ({"steps": local_steps}
                            if routers.get(family).parametric else {})
                r, _ = routers.fit_local(
                    routers.make(family, rcfg), data_c, fcfg,
                    key=jax.random.PRNGKey(seed + 100 + c), **local_kw)
                frozen.append(r)
        on, fr = [], []
        for c in range(cfg.n_clients):
            test = scenario.test_set(phase, c)
            on.append(_frontier_auc(srv.router.predict, test,
                                    srv.router.num_models))
            fr.append(_frontier_auc(frozen[c].predict, test,
                                    frozen[c].num_models))
        auc_online.append(float(np.mean(on)))
        auc_frozen.append(float(np.mean(fr)))

    return {
        "auc_online": auc_online,
        "auc_frozen_local": auc_frozen,
        "auc_online_final": auc_online[-1],
        "auc_frozen_local_final": auc_frozen[-1],
        "auc_gap_final": auc_online[-1] - auc_frozen[-1],
        "syncs": len(loop.history),
        "router_version": srv.router_version,
        "requests_served": served,
        "harvested_samples": len(harvest),
        "harvest_bytes": harvest.nbytes,
        "num_models_final": srv.router.num_models,
    }
