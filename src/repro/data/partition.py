"""Federated partition of the evaluation corpus (paper §6 + App. B).

  * query heterogeneity — Dirichlet(α) over task labels per client
    (Yurochkin et al. 2019), α = 0.6 main / 0.03 extreme;
  * model heterogeneity — a client-specific Dirichlet(0.45) distribution
    over the model pool; each training query logs exactly ONE model drawn
    from it (App. B.2);
  * per-client 0.75/0.25 train/test split; the global test set is the union
    of client test splits (App. C).

Outputs stacked, padded arrays ready for vmap/shard_map (federated.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig
from repro.data.synthetic import observe


def federated_split(key, corpus: dict, fcfg: FedConfig, *,
                    model_subset=None) -> dict:
    """Returns {"train": stacked padded client data, "test": per-client test
    dicts (x, acc_table, cost_table), "test_global": merged test dict}."""
    N = fcfg.num_clients
    Q = corpus["x"].shape[0]
    M = corpus["n_models"]
    T = corpus["n_tasks"]
    rng = np.random.default_rng(fcfg.seed)
    key, k_obs = jax.random.split(key)

    task = np.asarray(corpus["task"])
    # Dirichlet over clients per task
    client_of = np.zeros(Q, dtype=np.int64)
    for t in range(T):
        idx = np.where(task == t)[0]
        p = rng.dirichlet(np.full(N, fcfg.dirichlet_alpha))
        client_of[idx] = rng.choice(N, size=len(idx), p=p)

    # per-client model-logging distribution (App. B.2). ProxRouter-Data
    # uses UNIFORM logging (model_alpha = inf → uniform rows).
    if np.isinf(fcfg.model_alpha):
        logging_p = np.full((N, M), 1.0 / M)
    else:
        logging_p = rng.dirichlet(np.full(M, fcfg.model_alpha), size=N)
    if model_subset is not None:  # withheld-model experiments (§6.3)
        mask = np.zeros(M)
        mask[np.asarray(model_subset)] = 1.0
        logging_p = logging_p * mask[None, :]
        logging_p /= logging_p.sum(axis=1, keepdims=True)

    train_idx, test_idx = [], []
    model_of = np.zeros(Q, dtype=np.int64)
    for i in range(N):
        idx = np.where(client_of == i)[0]
        rng.shuffle(idx)
        n_tr = int(len(idx) * fcfg.train_frac)
        tr, te = idx[:n_tr], idx[n_tr:]
        train_idx.append(tr)
        test_idx.append(te)
        model_of[tr] = rng.choice(M, size=len(tr), p=logging_p[i])

    # observed (acc, cost) for each training sample's single logged model
    all_tr = np.concatenate(train_idx) if train_idx else np.zeros(0, np.int64)
    acc_obs, cost_obs = observe(k_obs, corpus, jnp.asarray(all_tr),
                                jnp.asarray(model_of[all_tr]))
    acc_obs = np.asarray(acc_obs)
    cost_obs = np.asarray(cost_obs)
    obs_of = {int(q): (acc_obs[j], cost_obs[j]) for j, q in enumerate(all_tr)}

    D_max = max(1, max(len(t) for t in train_idx))
    d = corpus["x"].shape[1]
    x_np = np.asarray(corpus["x"])
    train = {
        "x": np.zeros((N, D_max, d), np.float32),
        "m": np.zeros((N, D_max), np.int32),
        "acc": np.zeros((N, D_max), np.float32),
        "cost": np.zeros((N, D_max), np.float32),
        "w": np.zeros((N, D_max), np.float32),
    }
    for i, tr in enumerate(train_idx):
        n = len(tr)
        train["x"][i, :n] = x_np[tr]
        train["m"][i, :n] = model_of[tr]
        train["acc"][i, :n] = [obs_of[int(q)][0] for q in tr]
        train["cost"][i, :n] = [obs_of[int(q)][1] for q in tr]
        train["w"][i, :n] = 1.0

    acc_t = np.asarray(corpus["acc_table"])
    cost_t = np.asarray(corpus["cost_table"])
    tests = []
    for te in test_idx:
        tests.append({"x": jnp.asarray(x_np[te]),
                      "acc_table": jnp.asarray(acc_t[te]),
                      "cost_table": jnp.asarray(cost_t[te])})
    all_te = np.concatenate(test_idx)
    test_global = {"x": jnp.asarray(x_np[all_te]),
                   "acc_table": jnp.asarray(acc_t[all_te]),
                   "cost_table": jnp.asarray(cost_t[all_te])}

    return {
        "train": jax.tree.map(jnp.asarray, train),
        "test": tests,
        "test_global": test_global,
        "train_idx": train_idx,
        "logging_p": logging_p,
    }


def flatten_clients(train: dict) -> dict:
    """Stacked (N, D, ...) client data → pooled flat dataset (centralized
    baseline, App. D.1). Padding rows keep w = 0."""
    return jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), train)


def client_slice(train: dict, i: int) -> dict:
    return jax.tree.map(lambda a: a[i], train)
