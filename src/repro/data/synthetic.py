"""Synthetic RouterBench-like query–model evaluation corpus.

The real RouterBench/ProxRouter parquet dumps and the pretrained sentence
encoders are unavailable offline (the repro≤2 data gate), so we generate a
corpus with the same *statistical anatomy* the paper relies on:

  * T task clusters in embedding space (RouterBench = 8 public datasets) —
    queries are noisy samples around task centroids (what a sentence encoder
    produces for semantically grouped prompts);
  * M models with cost-correlated base quality plus per-task affinities —
    so no model dominates at every price point and the accuracy–cost
    frontier is non-trivial (RouterBench = 11 LLMs);
  * observed accuracy is a Bernoulli draw of the latent per-(query, model)
    success probability; observed cost is the latent cost + noise — matching
    the paper's noisy-evaluation model (§3).

Ground-truth acc/cost *tables* for every (query, model) pair are kept for
test-time frontier scoring (the synthetic analogue of RouterBench's
exhaustive evaluation grid).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import RouterConfig


def make_eval_corpus(key, *, n_queries: int = 8000, n_tasks: int = 8,
                     n_models: int = 11, d_emb: int = 64,
                     cluster_noise: float = 0.45, sharpness: float = 3.0,
                     affinity: float = 0.35) -> dict:
    keys = jax.random.split(key, 8)

    # task geometry: well-separated centroids on the unit sphere × radius
    mu = jax.random.normal(keys[0], (n_tasks, d_emb))
    mu = 2.5 * mu / jnp.linalg.norm(mu, axis=1, keepdims=True)
    task = jax.random.randint(keys[1], (n_queries,), 0, n_tasks)
    x = mu[task] + cluster_noise * jax.random.normal(keys[2],
                                                     (n_queries, d_emb))

    # model economics: log-spaced price, quality correlated with price
    cost_base = jnp.logspace(jnp.log10(0.02), jnp.log10(1.0), n_models)
    quality = 0.15 + 0.55 * cost_base ** 0.3 + 0.08 * jax.random.normal(
        keys[3], (n_models,))
    task_affinity = affinity * jax.random.normal(keys[4],
                                                 (n_models, n_tasks))

    difficulty = 0.25 * jax.random.normal(keys[5], (n_queries,))
    logits = sharpness * (quality[None, :] + task_affinity[:, task].T
                          - 0.55 - difficulty[:, None])
    acc_table = jax.nn.sigmoid(logits)                       # (Q, M)

    length_factor = 0.8 + 0.4 * jax.random.uniform(keys[6], (n_queries,))
    cost_table = jnp.clip(cost_base[None, :] * length_factor[:, None], 0, 1.0)

    return {
        "x": x, "task": task,
        "acc_table": acc_table, "cost_table": cost_table,
        "model_cost": cost_base, "model_quality": quality,
        "n_tasks": n_tasks, "n_models": n_models,
    }


def observe(key, corpus: dict, q_idx: jnp.ndarray, m_idx: jnp.ndarray,
            cost_noise: float = 0.02):
    """Sample the (acc, cost) a client actually logs for (query, model)."""
    ka, kc = jax.random.split(key)
    p = corpus["acc_table"][q_idx, m_idx]
    acc = jax.random.bernoulli(ka, p).astype(jnp.float32)
    cost = corpus["cost_table"][q_idx, m_idx]
    cost = jnp.clip(cost + cost_noise * jax.random.normal(kc, cost.shape),
                    0.0, 1.0)
    return acc, cost
