"""Sentence-encoder STUB (the one allowed frontend stub — DESIGN.md §3).

The paper uses frozen pretrained encoders (all-mpnet-base-v2 etc.) purely as
a fixed featurizer Enc(s) → R^d. Offline we replace it with a deterministic
hashed bag-of-ngrams random projection: semantically similar strings (shared
tokens) land near each other, and the map is stable across processes —
which is all the routing stack requires of Enc(·).
"""
from __future__ import annotations

import hashlib

import numpy as np

_BUCKETS = 4096


def _tokens(text: str):
    toks = text.lower().split()
    return toks + [" ".join(p) for p in zip(toks, toks[1:])]  # uni+bi-grams


def _bucket(tok: str) -> int:
    return int.from_bytes(hashlib.md5(tok.encode()).digest()[:4], "little") % _BUCKETS


def _projection(d_emb: int) -> np.ndarray:
    rng = np.random.default_rng(1234)  # fixed: Enc is frozen
    return rng.standard_normal((_BUCKETS, d_emb)).astype(np.float32) / np.sqrt(d_emb)


def encode(texts, d_emb: int = 64) -> np.ndarray:
    """texts: list[str] → (len(texts), d_emb) float32, unit-normalized."""
    proj = _projection(d_emb)
    out = np.zeros((len(texts), d_emb), np.float32)
    for i, t in enumerate(texts):
        counts = np.zeros(_BUCKETS, np.float32)
        for tok in _tokens(t):
            counts[_bucket(tok)] += 1.0
        v = counts @ proj
        n = np.linalg.norm(v)
        out[i] = v / n if n > 0 else v
    return out
