from repro.data.synthetic import make_eval_corpus  # noqa: F401
from repro.data.partition import federated_split   # noqa: F401
