"""Pallas TPU kernel: K-means nearest-centroid assignment.

The K-means router's hot loop (paper Alg. 2 lines 3/9) is a pairwise-distance
argmin. TPU mapping: query rows are tiled into VMEM blocks; the centroid
table (K ≤ a few hundred) stays VMEM-resident; −2·x·μᵀ runs on the MXU and
the rank-1 ‖μ‖² correction + argmin run on the VPU. ‖x‖² is dropped
(argmin-invariant), so the kernel is one matmul + a lane reduction.

Block shapes are padded by the ops wrapper to (8, 128) multiples; padded
centroids carry +inf bias so they are never selected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, bias_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)          # (BN, D)
    c = c_ref[...].astype(jnp.float32)          # (K, D)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (BN, K) — MXU
    c2 = jnp.sum(c * c, axis=1)                 # (K,)
    dist = c2[None, :] - 2.0 * xc + bias_ref[...]  # (BN, K)
    out_ref[...] = jnp.argmin(dist, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_pallas(x: jnp.ndarray, cents: jnp.ndarray, *,
                         block_n: int = 256, interpret: bool = True):
    """x: (n, d), cents: (K, d) → (n,) int32."""
    n, d = x.shape
    K = cents.shape[0]

    def rup(v, m):
        return (v + m - 1) // m * m

    n_p, d_p, k_p = rup(n, block_n), rup(d, 128), rup(max(K, 8), 128)
    x_p = jnp.zeros((n_p, d_p), x.dtype).at[:n, :d].set(x)
    c_p = jnp.zeros((k_p, d_p), cents.dtype).at[:K, :d].set(cents)
    bias = jnp.where(jnp.arange(k_p) < K, 0.0, jnp.inf)[None, :]  # (1, k_p)

    grid = (n_p // block_n,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_p), lambda i: (i, 0)),
            pl.BlockSpec((k_p, d_p), lambda i: (0, 0)),
            pl.BlockSpec((1, k_p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_p,), jnp.int32),
        interpret=interpret,
    )(x_p, c_p, bias)
    return out[:n]
