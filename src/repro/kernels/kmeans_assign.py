"""Pallas TPU kernels: K-means nearest-centroid assignment (+ fused reduce).

The K-means router's hot loop (paper Alg. 2 lines 3/9) is a pairwise-distance
argmin. TPU mapping: query rows are tiled into VMEM blocks; the centroid
table is tiled along K into ``block_k`` VMEM blocks (so K in the thousands
never overflows VMEM); −2·x·μᵀ runs on the MXU and the rank-1 ‖μ‖²
correction + argmin run on the VPU. ‖x‖² is dropped (argmin-invariant), so
assignment is one matmul + a lane reduction per (query, centroid) tile.

Very wide embeddings additionally tile the feature dimension: beyond
``block_d`` columns (default 2048 — full rows of d ≈ 8k would blow VMEM on
real hardware) the grid grows an innermost d axis that accumulates the
x·μᵀ partials and ‖μ‖² in VMEM scratch, deferring the argmin merge to the
last d tile. d ≤ block_d keeps the original single-pass kernels.

``kmeans_assign_reduce_pallas`` additionally fuses the Lloyd's-step update
into the same pass: the per-tile one-hot of the argmin feeds a second MXU
matmul that accumulates per-cluster weighted coordinate sums and counts
across query tiles, so a full Lloyd iteration is one kernel launch instead
of assign + host-visible one-hot scatter.

Inputs are only padded when their shapes are not already (8, 128)-aligned;
padded centroids carry +inf bias so they are never selected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rup(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pad2(a, rows: int, cols: int):
    """Zero-pad a 2-D array up to (rows, cols) — no-op when already there."""
    if a.shape == (rows, cols):
        return a
    return jnp.zeros((rows, cols), a.dtype).at[:a.shape[0], :a.shape[1]].set(a)


def _assign_kernel(x_ref, c_ref, bias_ref, out_ref, min_s):
    """One (query tile, centroid tile) step: block argmin merged into the
    running (min distance, argmin). The min carry lives in VMEM scratch
    (persists across the inner centroid-tile grid steps) — only the
    argmin itself ever reaches HBM."""
    k = pl.program_id(1)
    bk = c_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)          # (BN, D)
    c = c_ref[...].astype(jnp.float32)          # (BK, D)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (BN, BK) — MXU
    c2 = jnp.sum(c * c, axis=1)                 # (BK,)
    dist = c2[None, :] - 2.0 * xc + bias_ref[...]  # (BN, BK)
    blk_min = jnp.min(dist, axis=1)
    blk_arg = jnp.argmin(dist, axis=1).astype(jnp.int32) + k * bk

    @pl.when(k == 0)
    def _():
        out_ref[...] = blk_arg
        min_s[...] = blk_min[:, None]

    @pl.when(k > 0)
    def _():
        # strict < keeps the earlier tile on ties — global argmin semantics
        better = blk_min < min_s[..., 0]
        out_ref[...] = jnp.where(better, blk_arg, out_ref[...])
        min_s[...] = jnp.minimum(blk_min[:, None], min_s[...])


def _assign_kernel_dtiled(x_ref, c_ref, bias_ref, out_ref, min_s, xc_s,
                          c2_s, *, nd: int):
    """d-tiled variant: grid (query tile, centroid tile, d tile) with d
    innermost. Each d step accumulates this (query, centroid) pair's x·μᵀ
    partial and the centroid-norm partial into VMEM scratch; the last d
    step forms the distances and merges the block argmin into the running
    (min, argmin) exactly like the single-pass kernel."""
    k = pl.program_id(1)
    dt = pl.program_id(2)
    bk = c_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)          # (BN, BD)
    c = c_ref[...].astype(jnp.float32)          # (BK, BD)
    part = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (BN, BK) — MXU
    pc2 = jnp.sum(c * c, axis=1)[None, :]       # (1, BK)

    @pl.when(dt == 0)
    def _():
        xc_s[...] = part
        c2_s[...] = pc2

    @pl.when(dt > 0)
    def _():
        xc_s[...] += part
        c2_s[...] += pc2

    # merge only once the full-d distance is assembled (the reduction work
    # is gated on the last d tile — earlier tiles only accumulate); the
    # block stays VMEM-resident across its consecutive (k, d) revisits
    @pl.when(dt == nd - 1)
    def _():
        dist = c2_s[...] - 2.0 * xc_s[...] + bias_ref[...]
        blk_min = jnp.min(dist, axis=1)
        blk_arg = jnp.argmin(dist, axis=1).astype(jnp.int32) + k * bk
        # strict < keeps the earlier tile on ties — global argmin
        # semantics; the first centroid tile takes unconditionally (the
        # carry holds the previous query block's leftovers)
        better = (blk_min < min_s[..., 0]) | (k == 0)
        out_ref[...] = jnp.where(better, blk_arg, out_ref[...])
        min_s[...] = jnp.where(better[:, None], blk_min[:, None],
                               min_s[...])


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "block_d",
                                    "interpret"))
def kmeans_assign_pallas(x: jnp.ndarray, cents: jnp.ndarray, *,
                         block_n: int = 256, block_k: int = 512,
                         block_d: int = 2048, interpret: bool = True):
    """x: (n, d), cents: (K, d) → (n,) int32."""
    n, d = x.shape
    K = cents.shape[0]
    assert block_k % 128 == 0, "block_k must be lane-aligned (multiple of 128)"
    assert block_d % 128 == 0, "block_d must be lane-aligned (multiple of 128)"

    n_p, d_p = _rup(n, block_n), _rup(d, 128)
    bk = min(block_k, _rup(max(K, 8), 128))
    k_p = _rup(max(K, 8), bk)
    bias = jnp.where(jnp.arange(k_p) < K, 0.0, jnp.inf)[None, :]  # (1, k_p)

    if d_p > block_d:                           # wide-d: tile the features
        d_p = _rup(d, block_d)
        nd = d_p // block_d
        x_p = _pad2(x, n_p, d_p)
        c_p = _pad2(cents, k_p, d_p)
        out = pl.pallas_call(
            functools.partial(_assign_kernel_dtiled, nd=nd),
            grid=(n_p // block_n, k_p // bk, nd),   # d innermost
            in_specs=[
                pl.BlockSpec((block_n, block_d), lambda i, k, dt: (i, dt)),
                pl.BlockSpec((bk, block_d), lambda i, k, dt: (k, dt)),
                pl.BlockSpec((1, bk), lambda i, k, dt: (0, k)),
            ],
            out_specs=pl.BlockSpec((block_n,), lambda i, k, dt: (i,)),
            out_shape=jax.ShapeDtypeStruct((n_p,), jnp.int32),
            scratch_shapes=[
                pltpu.VMEM((block_n, 1), jnp.float32),   # running min carry
                pltpu.VMEM((block_n, bk), jnp.float32),  # x·μᵀ accumulator
                pltpu.VMEM((1, bk), jnp.float32),        # ‖μ‖² accumulator
            ],
            interpret=interpret,
        )(x_p, c_p, bias)
        return out[:n]

    x_p = _pad2(x, n_p, d_p)
    c_p = _pad2(cents, k_p, d_p)
    grid = (n_p // block_n, k_p // bk)  # centroid tiles innermost
    out = pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_p), lambda i, k: (i, 0)),
            pl.BlockSpec((bk, d_p), lambda i, k: (k, 0)),
            pl.BlockSpec((1, bk), lambda i, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_p,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((block_n, 1), jnp.float32),  # running min carry
        ],
        interpret=interpret,
    )(x_p, c_p, bias)
    return out[:n]


def _assign_reduce_kernel(x_ref, c_ref, bias_ref, w_ref, assign_ref,
                          sums_ref, cnts_ref):
    """One query tile, whole centroid table resident: nearest-centroid
    argmin AND its weighted one-hot reduction (per-cluster coordinate sums
    + counts), sharing the x·μᵀ MXU pass. sums/cnts blocks are
    grid-invariant → VMEM accumulation across consecutive grid steps (the
    only revisit pattern Pallas TPU guarantees)."""
    i = pl.program_id(0)
    kk = c_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)          # (BN, D)
    c = c_ref[...].astype(jnp.float32)          # (K, D)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (BN, K) — MXU
    c2 = jnp.sum(c * c, axis=1)
    dist = c2[None, :] - 2.0 * xc + bias_ref[...]
    assign = jnp.argmin(dist, axis=1).astype(jnp.int32)
    assign_ref[...] = assign

    onehot = (jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], kk), 1)
              == assign[:, None]).astype(jnp.float32)
    wv = onehot * w_ref[...][:, None]           # (BN, K) — pad rows have w=0
    part_sums = jax.lax.dot_general(
        wv, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (K, D) — MXU
    part_cnts = jnp.sum(wv, axis=0)             # (K,)

    @pl.when(i == 0)
    def _():
        sums_ref[...] = part_sums
        cnts_ref[...] = part_cnts

    @pl.when(i > 0)
    def _():
        sums_ref[...] += part_sums
        cnts_ref[...] += part_cnts


def _reduce_tiled_kernel(x_ref, w_ref, assign_ref, sums_ref, cnts_ref, *,
                         bk: int):
    """Weighted one-hot reduction for ONE centroid tile, streaming query
    tiles innermost: grid (nk, nq) keeps each (bk, D) sums block resident
    in VMEM across all its consecutive query-tile steps — no
    non-consecutive output revisits (which compiled Pallas TPU does not
    support). Rows assigned outside this tile fall out of the iota
    comparison; padded rows carry w=0."""
    kt = pl.program_id(0)
    i = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)          # (BN, D)
    local = assign_ref[...] - kt * bk           # in [0, bk) iff in this tile
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], bk), 1)
              == local[:, None]).astype(jnp.float32)
    wv = onehot * w_ref[...][:, None]           # (BN, BK)
    part_sums = jax.lax.dot_general(
        wv, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (BK, D) — MXU
    part_cnts = jnp.sum(wv, axis=0)             # (BK,)

    @pl.when(i == 0)
    def _():
        sums_ref[...] = part_sums
        cnts_ref[...] = part_cnts

    @pl.when(i > 0)
    def _():
        sums_ref[...] += part_sums
        cnts_ref[...] += part_cnts


def _reduce_tiled_kernel_d(x_ref, w_ref, assign_ref, sums_ref, cnts_ref, *,
                           bk: int):
    """Weighted one-hot reduction for one (centroid tile, d tile) output
    block, streaming query tiles innermost: grid (nk, nd, nq). The sums
    block stays VMEM-resident across its consecutive query steps; counts
    are d-independent, so only the dt == 0 sweep accumulates them (their
    block is resident across the whole (dt, nq) revisit run)."""
    kt = pl.program_id(0)
    dt = pl.program_id(1)
    i = pl.program_id(2)
    x = x_ref[...].astype(jnp.float32)          # (BN, BD)
    local = assign_ref[...] - kt * bk           # in [0, bk) iff in this tile
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], bk), 1)
              == local[:, None]).astype(jnp.float32)
    wv = onehot * w_ref[...][:, None]           # (BN, BK)
    part_sums = jax.lax.dot_general(
        wv, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (BK, BD) — MXU
    part_cnts = jnp.sum(wv, axis=0)             # (BK,)

    @pl.when(i == 0)
    def _():
        sums_ref[...] = part_sums

    @pl.when(i > 0)
    def _():
        sums_ref[...] += part_sums

    @pl.when((dt == 0) & (i == 0))
    def _():
        cnts_ref[...] = part_cnts

    @pl.when((dt == 0) & (i > 0))
    def _():
        cnts_ref[...] += part_cnts


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "block_d",
                                    "interpret"))
def kmeans_assign_reduce_pallas(x: jnp.ndarray, cents: jnp.ndarray,
                                w: jnp.ndarray, *, block_n: int = 256,
                                block_k: int = 512, block_d: int = 2048,
                                interpret: bool = True):
    """x: (n, d), cents: (K, d), w: (n,) →
    (assign (n,) int32, sums (K, d) f32, counts (K,) f32) where
    sums[k] = Σ_{i: assign_i=k} w_i·x_i and counts[k] = Σ w_i.

    When the centroid table fits one ``block_k`` tile (Lloyd's usual K)
    and the rows fit one ``block_d`` tile, assignment and reduction run as
    ONE fused pass sharing the x·μᵀ matmul. Larger tables tile along K:
    the shared ``_assign_kernel`` block_k loop produces the global argmin,
    then a reduction kernel with query tiles innermost accumulates each
    centroid tile's sums/counts. Rows wider than ``block_d`` additionally
    tile the feature dimension in both phases (d-tiled assign, then a
    (centroid, d, query) reduction grid). All variants only ever
    accumulate into VMEM-resident blocks across consecutive grid steps
    (compiled Pallas TPU does not support non-consecutive output
    revisits), at the cost of streaming x twice in the tiled regimes.
    """
    n, d = x.shape
    K = cents.shape[0]
    assert block_k % 128 == 0, "block_k must be lane-aligned (multiple of 128)"
    assert block_d % 128 == 0, "block_d must be lane-aligned (multiple of 128)"

    n_p, d_p = _rup(n, block_n), _rup(d, 128)
    bk = min(block_k, _rup(max(K, 8), 128))
    k_p = _rup(max(K, 8), bk)
    nk = k_p // bk
    nq = n_p // block_n

    if d_p > block_d:                   # wide-d: d-tiled assign + reduce
        assign = kmeans_assign_pallas(x, cents, block_n=block_n,
                                      block_k=block_k, block_d=block_d,
                                      interpret=interpret)
        d_p = _rup(d, block_d)
        nd = d_p // block_d
        x_p = _pad2(x, n_p, d_p)
        w_p = (jnp.asarray(w, jnp.float32) if n_p == n
               else jnp.zeros((n_p,), jnp.float32).at[:n].set(w))
        assign_p = (assign if n_p == n
                    else jnp.zeros((n_p,), jnp.int32).at[:n].set(assign))
        sums, cnts = pl.pallas_call(
            functools.partial(_reduce_tiled_kernel_d, bk=bk),
            grid=(nk, nd, nq),                  # query tiles innermost
            in_specs=[
                pl.BlockSpec((block_n, block_d),
                             lambda kt, dt, i: (i, dt)),
                pl.BlockSpec((block_n,), lambda kt, dt, i: (i,)),
                pl.BlockSpec((block_n,), lambda kt, dt, i: (i,)),
            ],
            out_specs=[
                pl.BlockSpec((bk, block_d), lambda kt, dt, i: (kt, dt)),
                pl.BlockSpec((bk,), lambda kt, dt, i: (kt,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((k_p, d_p), jnp.float32),
                jax.ShapeDtypeStruct((k_p,), jnp.float32),
            ],
            interpret=interpret,
        )(x_p, w_p, assign_p)
        return assign, sums[:K, :d], cnts[:K]

    x_p = _pad2(x, n_p, d_p)
    w_p = (jnp.asarray(w, jnp.float32) if n_p == n
           else jnp.zeros((n_p,), jnp.float32).at[:n].set(w))
    bias = jnp.where(jnp.arange(k_p) < K, 0.0, jnp.inf)[None, :]

    if nk == 1:                                 # fused single pass
        c_p = _pad2(cents, k_p, d_p)
        whole = lambda i: (0, 0)
        assign, sums, cnts = pl.pallas_call(
            _assign_reduce_kernel,
            grid=(nq,),
            in_specs=[
                pl.BlockSpec((block_n, d_p), lambda i: (i, 0)),
                pl.BlockSpec((k_p, d_p), whole),
                pl.BlockSpec((1, k_p), whole),
                pl.BlockSpec((block_n,), lambda i: (i,)),
            ],
            out_specs=[
                pl.BlockSpec((block_n,), lambda i: (i,)),
                pl.BlockSpec((k_p, d_p), whole),
                pl.BlockSpec((k_p,), lambda i: (0,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((n_p,), jnp.int32),
                jax.ShapeDtypeStruct((k_p, d_p), jnp.float32),
                jax.ShapeDtypeStruct((k_p,), jnp.float32),
            ],
            interpret=interpret,
        )(x_p, c_p, bias, w_p)
        return assign[:n], sums[:K, :d], cnts[:K]

    # tiled: global argmin via the shared block_k assign kernel, then the
    # per-tile reduction (query tiles innermost — consecutive accumulation)
    assign = kmeans_assign_pallas(x, cents, block_n=block_n,
                                  block_k=block_k, interpret=interpret)
    assign_p = (assign if n_p == n
                else jnp.zeros((n_p,), jnp.int32).at[:n].set(assign))
    sums, cnts = pl.pallas_call(
        functools.partial(_reduce_tiled_kernel, bk=bk),
        grid=(nk, nq),                          # query tiles innermost
        in_specs=[
            pl.BlockSpec((block_n, d_p), lambda kt, i: (i, 0)),
            pl.BlockSpec((block_n,), lambda kt, i: (i,)),
            pl.BlockSpec((block_n,), lambda kt, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bk, d_p), lambda kt, i: (kt, 0)),
            pl.BlockSpec((bk,), lambda kt, i: (kt,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_p, d_p), jnp.float32),
            jax.ShapeDtypeStruct((k_p,), jnp.float32),
        ],
        interpret=interpret,
    )(x_p, w_p, assign_p)
    return assign, sums[:K, :d], cnts[:K]
