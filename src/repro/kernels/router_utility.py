"""Pallas TPU kernel: fused routing decision.

Serving-gateway hot spot: given trunk features h, compute per-model
accuracy/cost head projections, the utility U_λ = σ(h·Wa+ba) − λ(h·Wc+bc),
and its argmax — in one VMEM-resident pass, so the (n, M) accuracy/cost
tensors never round-trip to HBM. Both head matmuls hit the MXU; sigmoid,
the λ-combine and the argmax/max reductions run on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, aw_ref, ab_ref, cw_ref, cb_ref, lam_ref, mask_ref,
            choice_ref, best_ref):
    h = h_ref[...].astype(jnp.float32)                       # (BN, dh)
    A = jax.nn.sigmoid(
        jax.lax.dot(h, aw_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32) + ab_ref[...])
    C = jax.lax.dot(h, cw_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32) + cb_ref[...]
    U = A - lam_ref[0, 0] * C + mask_ref[...]                # (BN, M)
    choice_ref[...] = jnp.argmax(U, axis=1).astype(jnp.int32)
    best_ref[...] = jnp.max(U, axis=1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def router_utility_pallas(h, acc_w, acc_b, cost_w, cost_b, lam, *,
                          block_n: int = 256, interpret: bool = True):
    """h: (n, dh); heads (dh, M)/(M,); lam scalar → (choice (n,), best (n,))."""
    n, dh = h.shape
    M = acc_w.shape[1]

    def rup(v, m):
        return (v + m - 1) // m * m

    n_p, dh_p, m_p = rup(n, block_n), rup(dh, 128), rup(max(M, 8), 128)
    h_p = jnp.zeros((n_p, dh_p), h.dtype).at[:n, :dh].set(h)

    def pad_w(w):
        return jnp.zeros((dh_p, m_p), jnp.float32).at[:dh, :M].set(
            w.astype(jnp.float32))

    def pad_b(b):
        return jnp.zeros((1, m_p), jnp.float32).at[0, :M].set(
            b.astype(jnp.float32))

    mask = jnp.where(jnp.arange(m_p) < M, 0.0, -jnp.inf)[None, :]
    lam_arr = jnp.full((1, 1), lam, jnp.float32)

    grid = (n_p // block_n,)
    whole = lambda i: (0, 0)
    choice, best = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, dh_p), lambda i: (i, 0)),
            pl.BlockSpec((dh_p, m_p), whole),
            pl.BlockSpec((1, m_p), whole),
            pl.BlockSpec((dh_p, m_p), whole),
            pl.BlockSpec((1, m_p), whole),
            pl.BlockSpec((1, 1), whole),
            pl.BlockSpec((1, m_p), whole),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_p,), jnp.int32),
            jax.ShapeDtypeStruct((n_p,), jnp.float32),
        ],
        interpret=interpret,
    )(h_p, pad_w(acc_w), pad_b(acc_b), pad_w(cost_w), pad_b(cost_b),
      lam_arr, mask)
    return choice[:n], best[:n]
