"""jit'd dispatch wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; everywhere else (this CPU
container) the pure-jnp oracles from ``ref.py`` are the default and the
kernels execute under ``interpret=True`` only in tests. Select with
``impl="ref" | "pallas"`` or the ``REPRO_KERNELS`` env var.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention_pallas,
                                            paged_decode_attention_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.kmeans_assign import (kmeans_assign_pallas,
                                         kmeans_assign_reduce_pallas)
from repro.kernels.router_utility import router_utility_pallas


def _default_impl() -> str:
    env = os.environ.get("REPRO_KERNELS")
    if env in ("ref", "pallas"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def kmeans_assign(x, cents, *, impl: str | None = None,
                  block_d: int = 2048):
    impl = impl or _default_impl()
    if impl == "pallas":
        return kmeans_assign_pallas(x, cents, block_d=block_d,
                                    interpret=_interpret())
    return ref.kmeans_assign_ref(x, cents)


def kmeans_assign_reduce(x, cents, w, *, impl: str | None = None,
                         block_k: int = 512, block_d: int = 2048):
    """Fused Lloyd's-step op: nearest-centroid assignment + per-cluster
    weighted coordinate sums and counts in one pass over x. The centroid
    table is streamed through VMEM in ``block_k`` tiles (K in the
    thousands stays resident); rows wider than ``block_d`` stream their
    features in tiles too (very wide embeddings never hold a full row in
    VMEM)."""
    impl = impl or _default_impl()
    if impl == "pallas":
        return kmeans_assign_reduce_pallas(x, cents, w, block_k=block_k,
                                           block_d=block_d,
                                           interpret=_interpret())
    return ref.kmeans_assign_reduce_ref(x, cents, w)


def router_utility(h, acc_w, acc_b, cost_w, cost_b, lam, *,
                   impl: str | None = None):
    impl = impl or _default_impl()
    if impl == "pallas":
        return router_utility_pallas(h, acc_w, acc_b, cost_w, cost_b, lam,
                                     interpret=_interpret())
    return ref.router_utility_ref(h, acc_w, acc_b, cost_w, cost_b, lam)


def flash_attention(q, k, v, *, causal: bool = True, impl: str | None = None):
    impl = impl or _default_impl()
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal,
                                      interpret=_interpret())
    return ref.flash_attention_ref(q, k, v, causal=causal)


def decode_attention(q, k_cache, v_cache, n_valid, *, impl: str | None = None):
    impl = impl or _default_impl()
    if impl == "pallas":
        return decode_attention_pallas(q, k_cache, v_cache, n_valid,
                                       interpret=_interpret())
    return ref.decode_attention_ref(q, k_cache, v_cache, n_valid)


def paged_decode_attention(q, k_pool, v_pool, page_table, n_valid, *,
                           impl: str | None = None):
    """Decode attention against the paged KV pool (serve/kv_cache): each
    batch row attends the pages its page-table row names. On TPU the Pallas
    kernel DMAs pages via scalar prefetch; the CPU fallback runs the
    segment-summed formulation (ref.paged_decode_attention_seg_ref), which
    reads the pools in place instead of materializing each row's
    (B, Hkv, npg·ps, hd) gathered copy. The gather-based oracle
    (ref.paged_decode_attention_ref) stays the parity ground truth in
    tests for both this fallback and the Pallas kernel."""
    impl = impl or _default_impl()
    if impl == "pallas":
        return paged_decode_attention_pallas(q, k_pool, v_pool, page_table,
                                             n_valid, interpret=_interpret())
    return ref.paged_decode_attention_seg_ref(q, k_pool, v_pool, page_table,
                                              n_valid)
