"""Pallas TPU kernel: blockwise online-softmax (flash) attention — prefill.

Grid (B, H, nq, nk); the innermost nk dimension accumulates into VMEM
scratch (running max m, normalizer l, weighted accumulator acc) — the
classic flash schedule mapped to TPU: q/k/v tiles are DMA'd HBM→VMEM per
block, qkᵀ and p·v hit the MXU, the online-softmax rescale is VPU work.
Causal masking is computed from block indices; fully-masked k-blocks are
skipped via ``pl.when`` (the causal wedge does ~half the work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # python float: avoids capturing a traced constant

# jax 0.4.x names it TPUCompilerParams; 0.5+ renamed to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
            causal: bool, bq: int, bk: int, nk: int, scale: float):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    # Skip k-blocks strictly above the causal diagonal.
    run = (ik * bk <= iq * bq + bq - 1) if causal else (ik >= 0)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ik == nk - 1)
    def _final():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q,k,v: (B, S, H, hd) (equal head counts) → (B, S, H, hd)."""
    B, S, H, hd = q.shape
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, "seq must divide block sizes"
    nq, nk = S // bq, S // bk
    scale = hd ** -0.5

    # layout (B, H, S, hd) for clean per-(batch, head) tiling
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    kern = functools.partial(_kernel, causal=causal, bq=bq, bk=bk, nk=nk,
                             scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # normalizer
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
