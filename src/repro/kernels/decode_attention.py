"""Pallas TPU kernels: flash-decoding attention (one query token vs KV cache).

The §Perf H3 hot-spot: batched decode reads the whole (B,Hkv,S,hd) cache
every step. ``decode_attention_pallas`` streams the cache through VMEM in
seq blocks with online-softmax accumulation — the cache never materializes
in f32 and never needs a layout transpose (head-major storage, matching
models/attention.init_kv_cache). Both kernels follow the jnp reference
path's dtype discipline (``_masked_grouped_attn``): q·k dots in the cache
dtype, probs downcast to the value dtype before the p·v dot, f32
accumulators only — so scores and attention weights quantize identically
to the reference and greedy argmax tokens agree on bf16 caches. Grid (B, Hkv, nS); the innermost seq
dimension accumulates (m, l, acc) in VMEM scratch. A validity bound masks
unwritten cache slots (positions ≥ n_valid); it may be per-batch — a (B,)
vector — so a continuous-batching slot pool (serve/engine.py) can decode
requests sitting at different positions in one launch. A row whose bound
is 0 (fully-invalid slot — e.g. a drained pool row) returns exactly 0.

``paged_decode_attention_pallas`` is the vLLM-style variant for the paged
KV pool (serve/kv_cache.alloc_page_pool): the cache is a flat pool of
fixed-size pages shared by every request, and each batch row owns a list
of page indices (its *page table* row). The page table is scalar-prefetched
(``pltpu.PrefetchScalarGridSpec``) so the BlockSpec index map can DMA each
row's pages straight from the pool — the gather never materializes in HBM.
Grid (B, Hkv, n_pages) with the page dimension innermost, same
online-softmax accumulation as the contiguous kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax 0.4.x names it TPUCompilerParams; 0.5+ renamed to CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(nv_ref, q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
            bs: int, ns: int, scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    # Dtype discipline mirrors models/attention._masked_grouped_attn: dot
    # q·k in the CACHE dtype with f32 accumulation (never an f32 copy of
    # the cache tile), and downcast probs to the value dtype before the
    # p·v dot — so kernel and jnp scores/weights quantize identically and
    # argmax token parity holds on bf16 caches (tests/test_kernels.py
    # pins token equality; the online-softmax normalization order still
    # differs, so values match to tolerance, not bitwise).
    q = q_ref[0, 0].astype(k_ref.dtype)              # (g, hd)
    k = k_ref[0, 0]                                  # (bs, hd)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ik * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < nv_ref[0, 0]
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # re-mask after the exp: when a row has NO valid positions m_new stays
    # NEG_INF and exp(s - m_new) would be 1 everywhere — the row must
    # instead accumulate l = 0 and emit exactly 0 (see _final's guard)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc[...] = acc[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ik == ns - 1)
    def _final():
        # max(l, tiny) guard: a fully-invalid row has l = 0 → emits 0
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, n_valid, *,
                            block_s: int = 512, interpret: bool = True):
    """q: (B, Hkv, g, hd); caches: (B, Hkv, S, hd) head-major;
    n_valid: scalar int32 — number of filled cache slots — or a (B,)
    vector giving each batch row (pool slot) its own validity bound.
    Returns (B, Hkv, g, hd)."""
    B, Hkv, g, hd = q.shape
    S = k_cache.shape[2]
    bs = min(block_s, S)
    assert S % bs == 0
    ns = S // bs
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32).reshape(-1, 1),
                          (B, 1))

    kern = functools.partial(_kernel, bs=bs, ns=ns, scale=hd ** -0.5)
    out = pl.pallas_call(
        kern,
        grid=(B, Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, i: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, hd), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, i: (b, h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, i: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(nv, q, k_cache, v_cache)
    return out


# ---------------------------------------------------------------------------
# Paged variant: gather-by-page-table via scalar prefetch
# ---------------------------------------------------------------------------


def _paged_kernel(pt_ref, nv_ref, q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s,
                  *, ps: int, npg: int, scale: float):
    """One (batch row, kv head, page) step. The page table was consumed by
    the BlockSpec index maps (scalar prefetch) to DMA this row's i-th page
    out of the pool; here only the logical position bookkeeping remains:
    page i of a row covers absolute positions [i*ps, (i+1)*ps)."""
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    # same dtype discipline as _kernel (and therefore as the jnp
    # reference path): cache-dtype dots, f32 accumulation
    q = q_ref[0, 0].astype(k_ref.dtype)              # (g, hd)
    k = k_ref[0, 0]                                  # (ps, hd)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ip * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < nv_ref[b]
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc[...] = acc[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ip == npg - 1)
    def _final():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_pool, v_pool, page_table, n_valid, *,
                                  interpret: bool = True):
    """q: (B, Hkv, g, hd); pools: (P, Hkv, page_size, hd) page-major — one
    flat page pool shared by every batch row; page_table: (B, npg) int32 —
    row b's i-th entry is the pool page holding its logical positions
    [i*page_size, (i+1)*page_size); n_valid: (B,) int32 per-row validity
    bound (entries past it — including trash-page table entries — are
    masked; a 0 bound emits exactly 0). Returns (B, Hkv, g, hd).

    The page table and validity vector are scalar-prefetched so the k/v
    BlockSpec index maps can address the pool by page id — each (b, h, i)
    grid step DMAs exactly one (page_size, hd) page into VMEM; the gathered
    (B, npg*page_size) view never materializes.
    """
    B, Hkv, g, hd = q.shape
    ps = k_pool.shape[2]
    npg = page_table.shape[1]
    pt = jnp.asarray(page_table, jnp.int32)
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32).reshape(-1), (B,))

    kern = functools.partial(_paged_kernel, ps=ps, npg=npg, scale=hd ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # page table + n_valid
        grid=(B, Hkv, npg),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h, i, pt, nv: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, i, pt, nv: (pt[b, i], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, i, pt, nv: (pt[b, i], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, i, pt, nv: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pt, nv, q, k_pool, v_pool)
    return out
