"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are validated against in tests, and the default implementation on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x: jnp.ndarray, cents: jnp.ndarray) -> jnp.ndarray:
    """x: (n, d), cents: (K, d) → (n,) int32 nearest-centroid index.

    Distance via the expansion ‖x−μ‖² = ‖x‖² − 2xμᵀ + ‖μ‖²; the ‖x‖² term is
    constant per row and dropped (argmin-invariant).
    """
    xc = x.astype(jnp.float32) @ cents.astype(jnp.float32).T        # (n, K)
    c2 = jnp.sum(cents.astype(jnp.float32) ** 2, axis=-1)           # (K,)
    return jnp.argmin(c2[None, :] - 2.0 * xc, axis=-1).astype(jnp.int32)


def kmeans_assign_reduce_ref(x: jnp.ndarray, cents: jnp.ndarray,
                             w: jnp.ndarray):
    """x: (n, d), cents: (K, d), w: (n,) →
    (assign (n,) int32, sums (K, d) f32, counts (K,) f32): the
    nearest-centroid argmin plus the weighted one-hot reduction a Lloyd's
    step needs (sums[k] = Σ_{assign_i=k} w_i·x_i, counts[k] = Σ w_i).
    Accumulates in f32 like the Pallas kernel (and every other oracle
    here), so the two impls stay interchangeable for low-precision x."""
    K = cents.shape[0]
    assign = kmeans_assign_ref(x, cents)
    onehot = jax.nn.one_hot(assign, K, dtype=jnp.float32)           # (n, K)
    wv = onehot * w.astype(jnp.float32)[:, None]
    return assign, wv.T @ x.astype(jnp.float32), jnp.sum(wv, axis=0)


def router_utility_ref(h: jnp.ndarray, acc_w, acc_b, cost_w, cost_b,
                       lam) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused routing decision on trunk features.

    h: (n, dh) trunk hidden; heads (dh, M)/(M,).
    Returns (choice (n,) int32, best utility (n,) f32).
    """
    hf = h.astype(jnp.float32)
    A = jax.nn.sigmoid(hf @ acc_w.astype(jnp.float32) + acc_b)
    C = hf @ cost_w.astype(jnp.float32) + cost_b
    U = A - lam * C
    return jnp.argmax(U, axis=-1).astype(jnp.int32), jnp.max(U, axis=-1)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True) -> jnp.ndarray:
    """q,k,v: (B, S, H, hd) (same head count — GQA repeat done by caller).
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        m = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(m[None, None], scores, jnp.float32(-1e30))
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, n_valid):
    """q: (B,Hkv,g,hd); caches (B,Hkv,S,hd) head-major; n_valid scalar or
    (B,) per-row validity bound (continuous-batching slot pool). A row with
    bound 0 (fully-invalid slot) returns exactly 0, matching the kernel's
    l=0 guard. Returns (B,Hkv,g,hd)."""
    S = k_cache.shape[2]
    hd = q.shape[-1]
    s = jnp.einsum("bhgd,bhkd->bhgk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    nv = jnp.asarray(n_valid, jnp.int32).reshape(-1, 1, 1, 1)   # (B|1,1,1,1)
    valid = jnp.arange(S)[None, None, None, :] < nv
    s = jnp.where(valid, s, jnp.float32(-1e30))
    # explicit masked softmax (not jax.nn.softmax): zero the exp under the
    # mask so a fully-invalid row accumulates l = 0 and emits 0 instead of
    # a uniform average over garbage
    p = jnp.where(valid, jnp.exp(s - jnp.max(s, axis=-1, keepdims=True)), 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_gather_ref(pool, page_table):
    """pool: (P, Hkv, ps, hd) page-major; page_table: (B, npg) int32.
    Materializes each row's contiguous logical view — (B, Hkv, npg*ps, hd)
    — by gathering its pages out of the shared pool. This is the CPU
    fallback the Pallas kernel's scalar-prefetch DMA avoids on TPU."""
    B, npg = page_table.shape
    _, Hkv, ps, hd = pool.shape
    g = pool[page_table]                       # (B, npg, Hkv, ps, hd)
    g = jnp.moveaxis(g, 2, 1)                  # (B, Hkv, npg, ps, hd)
    return g.reshape(B, Hkv, npg * ps, hd)


def paged_decode_attention_ref(q, k_pool, v_pool, page_table, n_valid):
    """q: (B,Hkv,g,hd); pools (P,Hkv,ps,hd) page-major shared by all rows;
    page_table (B,npg) int32; n_valid (B,) per-row bound. Gathers each
    row's pages into a contiguous view and runs the contiguous oracle —
    positions past n_valid (including anything a trash-page table entry
    drags in) are masked. Returns (B,Hkv,g,hd)."""
    return decode_attention_ref(q, paged_gather_ref(k_pool, page_table),
                                paged_gather_ref(v_pool, page_table),
                                n_valid)


def paged_decode_attention_seg_ref(q, k_pool, v_pool, page_table, n_valid):
    """Segment-summed paged decode: same contract as
    ``paged_decode_attention_ref`` but WITHOUT the per-row K/V copy.

    The gather oracle materializes each row's contiguous logical view —
    (B, Hkv, npg·ps, hd) for both K and V, a full duplicate of every
    in-flight row's cache each step. Here the pools are only ever read in
    place: q scores against EVERY pool page in one einsum, a one-hot
    page-membership operator (count[b,p,k] = how many valid logical slots
    of row b live at pool slot (p,k)) masks and weights the exp terms, and
    the V contraction runs pool-major. Duplicate table entries are counted
    with multiplicity — exactly the weight they get in the gathered view —
    so the two formulations agree for any table, not just engine-shaped
    ones. The trade is compute for bandwidth: scores against all P pages
    instead of each row's npg; the win is that nothing hd-wide is copied.
    Matches the gather oracle to f32 reduction-order noise (the normalizer
    and V sums run pool-major rather than logical-major), NOT bitwise.
    """
    P, Hkv, ps, hd = k_pool.shape
    B, npg = page_table.shape
    s = jnp.einsum("bhgd,phkd->bhgpk", q.astype(jnp.float32),
                   k_pool.astype(jnp.float32)) * hd ** -0.5
    member = jax.nn.one_hot(page_table, P, dtype=jnp.float32)   # (B, npg, P)
    nv = jnp.asarray(n_valid, jnp.int32).reshape(-1)            # (B,)
    pos = jnp.arange(npg)[:, None] * ps + jnp.arange(ps)[None, :]
    valid = (pos[None] < nv[:, None, None]).astype(jnp.float32)  # (B,npg,ps)
    count = jnp.einsum("bip,bik->bpk", member, valid)           # (B, P, ps)
    cnt = count[:, None, None]                                  # (B,1,1,P,ps)
    s = jnp.where(cnt > 0, s, jnp.float32(-1e30))
    m = jnp.max(s, axis=(-2, -1), keepdims=True)
    p = cnt * jnp.exp(s - m)                                    # masked → 0
    p = p / jnp.maximum(jnp.sum(p, axis=(-2, -1), keepdims=True), 1e-30)
    out = jnp.einsum("bhgpk,phkd->bhgd", p, v_pool.astype(jnp.float32))
    return out.astype(q.dtype)
