"""internvl2-2b — VLM: InternViT + InternLM2 backbone [arXiv:2404.16821].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. The InternViT vision
encoder + projector is stubbed: ``input_specs`` provides precomputed patch
embeddings interleaved with text embeddings; we implement the LM backbone.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    head_dim=128,
    frontend="vision",
)
