"""kimi-k2-1t-a32b — trillion-parameter MoE [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per-expert) vocab=163840,
MoE 384 experts top-8. Expert-parallel over the "model" mesh axis
(24 experts/chip on a 16-way axis).
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048),
)
