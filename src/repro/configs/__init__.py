"""Architecture config registry.

Every assigned architecture is a selectable config (``--arch <id>``); each
cites its source in its module docstring. ``get_config`` returns the full
(production) config; ``get_config(id).reduced()`` is the smoke-test variant.
"""
from __future__ import annotations

from repro.config import ModelConfig

from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.yi_34b import CONFIG as _yi34
from repro.configs.phi3_5_moe_42b_a6_6b import CONFIG as _phi
from repro.configs.internvl2_2b import CONFIG as _internvl
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.yi_6b import CONFIG as _yi6
from repro.configs.qwen3_8b import CONFIG as _qwen3
from repro.configs.mamba2_370m import CONFIG as _mamba2
from repro.configs.qwen2_1_5b import CONFIG as _qwen2

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _hubert, _jamba, _yi34, _phi, _internvl,
        _kimi, _yi6, _qwen3, _mamba2, _qwen2,
    ]
}


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[arch]


def list_archs() -> list[str]:
    return sorted(REGISTRY)
