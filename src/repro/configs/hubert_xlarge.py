"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

48L d_model=1280 16H (GQA kv=16 ⇒ MHA) d_ff=5120 vocab=504 (codebook targets).
The conv/mel frontend is stubbed: ``input_specs`` provides precomputed frame
embeddings. Encoder-only ⇒ no decode shapes (see DESIGN.md §Arch-applicability).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    causal=False,
    encoder_only=True,
    frontend="audio",
    rope_theta=10_000.0,
)
