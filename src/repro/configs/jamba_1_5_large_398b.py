"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16 experts top-2.
One attention layer per 8 layers (rest Mamba2 blocks); MoE every other layer.
Native sub-quadratic ⇒ runs long_500k.
"""
from repro.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    hybrid_attn_period=8,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576),
    moe_period=2,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
)
