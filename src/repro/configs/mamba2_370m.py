"""mamba2-370m — attention-free SSM, SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024, ssm_state=128, vocab=50280. d_ff=0 (no MLP; the Mamba2
block's gated expansion x2 plays that role).
"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,        # SSD heads = expand*d_model / head_dim
    n_kv_heads=32,
    d_ff=0,
    vocab=50280,
    head_dim=64,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
)
