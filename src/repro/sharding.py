"""Logical-axis sharding: model code annotates activations with *logical*
axis names; a rule set maps them to mesh axes at launch time.

Outside any ``use_rules`` context (unit tests, CPU smoke runs) ``constrain``
is the identity, so the model code is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Sequence[str], None]

_CURRENT: Optional[tuple] = None  # (mesh, rules: dict[str, Axis])


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = (mesh, rules)
    try:
        yield
    finally:
        _CURRENT = prev


def active() -> bool:
    return _CURRENT is not None


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def resolve_spec(logical: Sequence, shape: Sequence[int]) -> Optional[P]:
    """Map logical axis names to a PartitionSpec under the active rules,
    dropping axes whose size does not divide the dimension. A trailing "!"
    on a logical name opts into uneven (GSPMD-padded) sharding — used e.g.
    to shard 56 attention heads over a 16-way axis (4 chips idle-padded)."""
    if _CURRENT is None:
        return None
    mesh, rules = _CURRENT
    out = []
    for dim, name in zip(shape, logical):
        uneven = isinstance(name, str) and name.endswith("!")
        key = name[:-1] if uneven else name
        axis = rules.get(key) if key is not None else None
        if axis is not None and not uneven \
                and dim % _axis_size(mesh, axis) != 0:
            axis = None  # non-divisible → replicate this dim
        out.append(axis)
    return P(*out)


def constrain(x: jax.Array, logical: Sequence) -> jax.Array:
    """Annotate x with the sharding implied by logical axis names."""
    if _CURRENT is None:
        return x
    mesh, _ = _CURRENT
    spec = resolve_spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
