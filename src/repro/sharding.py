"""Mesh construction + logical-axis sharding for the whole stack.

Two layers live here:

* **Logical-axis rules** (``use_rules``/``constrain``): model code annotates
  activations with *logical* axis names; a rule set maps them to mesh axes
  at launch time. Outside any ``use_rules`` context (unit tests, CPU smoke
  runs) ``constrain`` is the identity, so the model code is mesh-agnostic.
* **Mesh helpers** (``client_mesh``/``head_mesh``/``data_mesh`` +
  ``shard_clients``/``replicate``/``named``): the cross-silo execution
  layer. The federated fit shards the stacked ``(N, …)`` client slab over a
  1-D ``"clients"`` axis and runs under ``shard_map``
  (``core.federated.fedavg_round_sharded``); the serve engine shards its KV
  pools over ``"heads"`` (tensor-parallel attention) and/or ``"data"``
  (slot-parallel decode) via plain GSPMD propagation from the pool
  placement. ``ENGINE_RULES`` maps the logical names the attention code
  already annotates (``constrain`` calls in ``models/attention.py``) onto
  those mesh axes.
"""
from __future__ import annotations

import contextlib
import inspect
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # moved out of experimental in newer jax
    from jax import shard_map as _shard_map
except ImportError:  # jax<=0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# the "replication check" kwarg was renamed check_rep → check_vma
_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map).parameters else "check_rep")

Axis = Union[str, Sequence[str], None]


def shard_map(f, mesh: Mesh, in_specs, out_specs, *, check: bool = False):
    """Version-compat ``shard_map``: one call site for the
    check_rep→check_vma rename, shared by every sharded fit path."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})

_CURRENT: Optional[tuple] = None  # (mesh, rules: dict[str, Axis])


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = (mesh, rules)
    try:
        yield
    finally:
        _CURRENT = prev


def active() -> bool:
    return _CURRENT is not None


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def resolve_spec(logical: Sequence, shape: Sequence[int]) -> Optional[P]:
    """Map logical axis names to a PartitionSpec under the active rules,
    dropping axes whose size does not divide the dimension. A trailing "!"
    on a logical name opts into uneven (GSPMD-padded) sharding — used e.g.
    to shard 56 attention heads over a 16-way axis (4 chips idle-padded)."""
    if _CURRENT is None:
        return None
    mesh, rules = _CURRENT
    out = []
    for dim, name in zip(shape, logical):
        uneven = isinstance(name, str) and name.endswith("!")
        key = name[:-1] if uneven else name
        axis = rules.get(key) if key is not None else None
        if axis is not None:
            # a rule naming an axis the live mesh doesn't carry (e.g.
            # ENGINE_RULES' "heads" on a 1-D data mesh) replicates
            names = (axis,) if isinstance(axis, str) else tuple(axis)
            if any(a not in mesh.shape for a in names):
                axis = None
        if axis is not None and not uneven \
                and dim % _axis_size(mesh, axis) != 0:
            axis = None  # non-divisible → replicate this dim
        out.append(axis)
    return P(*out)


def constrain(x: jax.Array, logical: Sequence) -> jax.Array:
    """Annotate x with the sharding implied by logical axis names."""
    if _CURRENT is None:
        return x
    mesh, _ = _CURRENT
    spec = resolve_spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Mesh construction (the cross-silo execution layer)
# ---------------------------------------------------------------------------

#: default logical→mesh rules for the mesh-sharded serve engine: the
#: attention code's existing annotations map heads onto the "heads" axis
#: (tensor-parallel) and the batch/slot dim onto "data" (slot-parallel).
#: ``heads4d`` is the uneven-shardable 4-D head annotation attention uses.
ENGINE_RULES = {"heads": "heads", "heads4d": "heads", "batch": "data"}


def make_mesh(shape: dict, *, devices=None) -> Mesh:
    """Build a mesh from ``{axis_name: size}`` over the first
    ``prod(sizes)`` local devices (or an explicit device list). Raises a
    clear error when the host has too few devices — on CPU, force more
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set
    before jax initializes)."""
    names = tuple(shape)
    sizes = tuple(int(shape[n]) for n in names)
    need = int(np.prod(sizes))
    devices = list(jax.devices()) if devices is None else list(devices)
    if need > len(devices):
        raise ValueError(
            f"mesh {dict(zip(names, sizes))} needs {need} devices, host has "
            f"{len(devices)} — on CPU set XLA_FLAGS="
            f"'--xla_force_host_platform_device_count={need}' before jax "
            "initializes")
    arr = np.asarray(devices[:need]).reshape(sizes)
    return Mesh(arr, names)


def client_mesh(n_devices: Optional[int] = None, *, devices=None) -> Mesh:
    """1-D ``("clients",)`` mesh for the sharded federated fit — each
    device owns a contiguous block of the stacked client slab."""
    n = n_devices if n_devices is not None else len(
        devices if devices is not None else jax.devices())
    return make_mesh({"clients": n}, devices=devices)


def head_mesh(n_devices: Optional[int] = None, *, devices=None) -> Mesh:
    """1-D ``("heads",)`` mesh: tensor-parallel attention heads for the
    serve engine (KV pool leaves sharded over their Hkv dim)."""
    n = n_devices if n_devices is not None else len(
        devices if devices is not None else jax.devices())
    return make_mesh({"heads": n}, devices=devices)


def data_mesh(n_devices: Optional[int] = None, *, devices=None) -> Mesh:
    """1-D ``("data",)`` mesh: slot-parallel decode for the serve engine
    (pool leaves sharded over their slot/batch dim; per-slot math is
    untouched, so tokens stay bit-identical to the solo engine)."""
    n = n_devices if n_devices is not None else len(
        devices if devices is not None else jax.devices())
    return make_mesh({"data": n}, devices=devices)


def named(mesh: Mesh, *spec) -> NamedSharding:
    """Shorthand: ``named(mesh, None, "clients")`` ≡
    ``NamedSharding(mesh, P(None, "clients"))``."""
    return NamedSharding(mesh, P(*spec))


def replicate(tree, mesh: Mesh):
    """device_put every leaf fully replicated over ``mesh``."""
    return jax.device_put(tree, NamedSharding(mesh, P()))


def shard_leading(tree, mesh: Mesh, axis: str):
    """device_put every leaf with its leading dim sharded over mesh axis
    ``axis`` (replicated when the dim doesn't divide the axis — a clear
    error beats silent GSPMD padding for the client slab, so callers that
    require even sharding should check first)."""
    n = mesh.shape[axis]

    def put(a):
        a = jax.numpy.asarray(a) if not hasattr(a, "shape") else a
        spec = P(axis) if a.ndim and a.shape[0] % n == 0 else P()
        return jax.device_put(a, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree)


def shard_clients(data, mesh: Mesh):
    """Place a stacked federated dataset ``{"x": (N, D, d), ...}`` with the
    client axis sharded over the mesh's ``"clients"`` axis — each device
    holds N/n_dev clients, no full replication. Requires N divisible by the
    axis size (``pad_client_axis`` in ``core.federated`` pads a ragged
    stack up)."""
    N = jax.tree.leaves(data)[0].shape[0]
    n = mesh.shape["clients"]
    if N % n != 0:
        raise ValueError(
            f"client stack N={N} does not divide the clients mesh axis "
            f"({n}) — pad the stack (core.federated.pad_client_axis) or "
            "resize the mesh")
    return jax.tree.map(
        lambda a: jax.device_put(a, NamedSharding(mesh, P("clients"))),
        data)


def kv_pool_spec(leaf_ndim: int, mesh: Mesh, leaf_shape=None) -> P:
    """PartitionSpec for a KV-pool leaf: 5-D pool leaves are
    ``(n_units, slots|pages, Hkv, seq, hd)`` — shard the slot dim over
    ``"data"`` and/or the head dim over ``"heads"`` when the mesh carries
    those axes and the dim divides; everything else replicates. Non-5-D
    leaves (SSM states etc.) shard their dim-1 batch over ``"data"``
    only."""
    axes = dict(mesh.shape)

    def fits(dim_size, ax):
        return ax in axes and dim_size is not None \
            and dim_size % axes[ax] == 0

    shape = leaf_shape if leaf_shape is not None else [None] * leaf_ndim
    spec = [None] * leaf_ndim
    if leaf_ndim >= 2 and fits(shape[1], "data"):
        spec[1] = "data"
    if leaf_ndim == 5 and fits(shape[2], "heads"):
        spec[2] = "heads"
    return P(*spec)


def shard_kv_pool(pool, mesh: Mesh):
    """device_put a KV pool (slot or page regime) with each leaf sharded
    per ``kv_pool_spec`` — the engine's mesh placement."""
    return jax.tree.map(
        lambda a: jax.device_put(
            a, NamedSharding(mesh, kv_pool_spec(a.ndim, mesh, a.shape))),
        pool)
