"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

MUST be run as its own process: the first two lines force 512 host
placeholder devices before jax initializes. Results (memory analysis, HLO
FLOPs/bytes, parsed collective bytes, roofline terms) are appended to a
JSONL cache so reruns skip completed combos.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import functools
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.config import INPUT_SHAPES, ModelConfig
from repro.configs import get_config, list_archs
from repro.launch import hlo_analysis as H
from repro.launch import specs as SP
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import model as mdl
from repro.train.optim import AdamWState

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun.jsonl"

# Decode shapes are skipped for encoder-only archs; long_500k uses the
# sliding-window rolling cache for pure-attention archs (DESIGN.md §4).
PURE_ATTENTION = {"dense", "moe", "vlm"}


def combo_skip_reason(cfg: ModelConfig, shape_name: str):
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "decode" and not cfg.supports_decode:
        return "encoder-only: no decode step"
    return None


def _tree_size_bytes(tree):
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                moe_mode: str = "dense", q_chunk: int = 512,
                fsdp: bool = True, attn_layout: str = "grouped",
                kv_seq_axis: str | None = None, act_shard: bool = False,
                ssm_chunk: int | None = None):
    import dataclasses
    cfg = get_config(arch)
    if ssm_chunk and cfg.ssm is not None:  # §Perf lever: SSD chunk length
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=ssm_chunk))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = SP.activation_rules(mesh, shape, kv_seq_axis=kv_seq_axis,
                                act_shard=act_shard)

    params_shape = jax.eval_shape(
        functools.partial(mdl.init_params, cfg=cfg), jax.random.PRNGKey(0))
    pspecs = SP.param_specs(mesh, cfg, params_shape, fsdp=fsdp)
    rep = NamedSharding(mesh, P())

    rolling = (shape.name == "long_500k" and cfg.arch_type in PURE_ATTENTION)
    cache_len = cfg.sliding_window if rolling else shape.seq_len

    with mesh, shd.use_rules(mesh, rules):
        if shape.kind == "train":
            step, opt = make_train_step(cfg, moe_mode=moe_mode,
                                        q_chunk=q_chunk,
                                        attn_layout=attn_layout)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            ospecs = AdamWState(step=rep, m=pspecs, v=pspecs)
            batch, bspecs = SP.input_specs(cfg, shape, mesh)
            jitted = jax.jit(step,
                             in_shardings=(pspecs, ospecs, bspecs),
                             out_shardings=(pspecs, ospecs, rep),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shape, opt_shape, batch)
            state_bytes = (_tree_size_bytes(params_shape)
                           + _tree_size_bytes(opt_shape))
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, moe_mode=moe_mode, q_chunk=q_chunk,
                                     attn_layout=attn_layout)
            batch, bspecs = SP.input_specs(cfg, shape, mesh)
            jitted = jax.jit(step, in_shardings=(pspecs, bspecs))
            lowered = jitted.lower(params_shape, batch)
            state_bytes = _tree_size_bytes(params_shape)
        else:  # decode
            step = make_decode_step(cfg, rolling=rolling, moe_mode=moe_mode)
            dshape = type(shape)(shape.name, cache_len, shape.global_batch,
                                 "decode")
            args, aspecs = SP.input_specs(cfg, dshape, mesh,
                                          kv_seq_axis=kv_seq_axis)
            jitted = jax.jit(
                step,
                in_shardings=(pspecs, aspecs["cache"], aspecs["tokens"],
                              aspecs["pos"]),
                out_shardings=(NamedSharding(
                    mesh, P(None if shape.global_batch == 1
                            else SP.batch_axes(mesh), None, None)),
                    aspecs["cache"]),
                donate_argnums=(1,))
            lowered = jitted.lower(params_shape, args["cache"],
                                   args["tokens"], args["pos"])
            state_bytes = (_tree_size_bytes(params_shape)
                           + _tree_size_bytes(args["cache"]))
    return cfg, shape, mesh, lowered, state_bytes, rolling


def run_combo(arch: str, shape_name: str, *, multi_pod: bool,
              moe_mode: str = "dense", q_chunk: int = 512,
              fsdp: bool = True, tag: str = "baseline", verbose: bool = True,
              attn_layout: str = "grouped", kv_seq_axis: str | None = None,
              act_shard: bool = False, ssm_chunk: int | None = None):
    t0 = time.time()
    cfg = get_config(arch)
    skip = combo_skip_reason(cfg, shape_name)
    n_chips = 512 if multi_pod else 256
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "moe_mode": moe_mode, "q_chunk": q_chunk, "fsdp": fsdp,
           "attn_layout": attn_layout, "kv_seq_axis": kv_seq_axis,
           "tag": tag}
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec

    cfg, shape, mesh, lowered, state_bytes, rolling = lower_combo(
        arch, shape_name, multi_pod=multi_pod, moe_mode=moe_mode,
        q_chunk=q_chunk, fsdp=fsdp, attn_layout=attn_layout,
        kv_seq_axis=kv_seq_axis, act_shard=act_shard, ssm_chunk=ssm_chunk)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    terms = H.roofline_terms(hlo, n_chips=n_chips,
                             peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW,
                             ici_bw=ICI_BW)

    params_shape = jax.eval_shape(
        functools.partial(mdl.init_params, cfg=cfg), jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_shape))
    frac = (cfg.moe.top_k / cfg.moe.num_experts) if cfg.moe else 1.0
    n_active = sum(
        int(np.prod(x.shape) * (frac if len(x.shape) == 4 else 1.0))
        for x in jax.tree.leaves(params_shape))
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    flops_per_token = 6 if shape.kind == "train" else 2
    model_flops = flops_per_token * n_active * tokens

    mem_d = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem_d[attr] = getattr(mem, attr, None)

    hlo_flops_global = terms["hlo_flops_per_chip"] * n_chips
    rec.update(
        status="ok", rolling=rolling,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        n_params=n_params, n_active=n_active,
        state_bytes_global=state_bytes,
        state_bytes_per_chip=state_bytes // n_chips,
        memory_analysis=mem_d,
        xla_cost_flops=cost.get("flops"),
        hlo_flops_per_chip=terms["hlo_flops_per_chip"],
        hlo_bytes_per_chip=terms["hlo_bytes_per_chip"],
        collective_bytes_per_chip=terms["collective_bytes_per_chip"],
        collectives=terms["collectives"],
        compute_s=terms["compute_s"], memory_s=terms["memory_s"],
        collective_s=terms["collective_s"], dominant=terms["dominant"],
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / hlo_flops_global
                            if hlo_flops_global else None),
    )
    if verbose:
        print(f"[{arch} × {shape_name} × {rec['mesh']} × {tag}] "
              f"compile={t_compile:.0f}s dominant={rec['dominant']} "
              f"compute={rec['compute_s']*1e3:.2f}ms "
              f"memory={rec['memory_s']*1e3:.2f}ms "
              f"collective={rec['collective_s']*1e3:.2f}ms "
              f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)}")
        print("  memory_analysis:", mem_d)
    return rec


def load_done(path=RESULTS):
    done = {}
    if path.exists():
        for line in path.read_text().splitlines():
            if line.strip():
                r = json.loads(line)
                done[(r["arch"], r["shape"], r["mesh"], r.get("tag",
                                                              "baseline"))] = r
    return done


def append(rec, path=RESULTS):
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-mode", default="dense",
                    choices=["dense", "capacity"])
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--attn-layout", default="grouped",
                    choices=["grouped", "flat"])
    ap.add_argument("--act-shard", action="store_true")
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--kv-seq-axis", default=None,
                    choices=[None, "data", "model"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    combos = []
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    for a in archs:
        for s in shapes:
            combos.append((a, s))

    done = load_done()
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    for a, s in combos:
        key = (a, s, mesh_name, args.tag)
        if not args.force and key in done and done[key]["status"] != "error":
            print(f"skip cached {key}")
            continue
        try:
            rec = run_combo(a, s, multi_pod=args.multi_pod,
                            moe_mode=args.moe_mode, q_chunk=args.q_chunk,
                            fsdp=not args.no_fsdp, tag=args.tag,
                            attn_layout=args.attn_layout,
                            kv_seq_axis=args.kv_seq_axis,
                            act_shard=args.act_shard,
                            ssm_chunk=args.ssm_chunk)
        except Exception as e:  # record failures — they are bugs to fix
            rec = {"arch": a, "shape": s, "mesh": mesh_name, "tag": args.tag,
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            print(f"[{a} × {s}] ERROR {rec['error']}")
        append(rec)


if __name__ == "__main__":
    main()
