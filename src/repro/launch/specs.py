"""Parameter / input PartitionSpecs for the production meshes.

Layout policy (DESIGN.md §5):
  * batch            → ("pod", "data")          (training & batched decode)
  * tensor-parallel  → "model"  on head/ffn/expert/vocab dims
  * FSDP             → "data"   on the non-TP dim of large matrices
  * batch=1 decode   → KV-cache seq → "data"    (flash-decoding layout)
Dims that an axis does not divide are left replicated (GSPMD would pad, but
we prefer explicit, predictable layouts).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import InputShape, ModelConfig
from repro.models import model as mdl

IN_NAMES = {"wq", "wk", "wv", "wg", "wu", "wi", "in_proj"}
OUT_NAMES = {"wo", "wd", "out_proj"}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def _fit(mesh: Mesh, spec_dims, shape):
    """Drop axes that don't divide their dim."""
    out = []
    for dim, axis in zip(shape, spec_dims):
        out.append(axis if (axis is None or dim % _axis_size(mesh, axis) == 0)
                   else None)
    return P(*out)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_specs(mesh: Mesh, cfg: ModelConfig, params_shape, *,
                fsdp: bool = True):
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    fa = "data" if fsdp else None

    def leaf(path, x):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1]
        shape = x.shape
        nd = len(shape)
        dims = [None] * nd
        # MoE expert weights are the only 4-D leaves: (L, E, d_in, d_out)
        if nd == 4:
            dims[1] = "model"           # experts
            if name in IN_NAMES:
                dims[2] = fa            # d_model (FSDP)
            else:
                dims[3] = fa
        elif name in IN_NAMES and nd >= 2:
            dims[-2], dims[-1] = fa, "model"
        elif name in OUT_NAMES and nd >= 2:
            dims[-2], dims[-1] = "model", fa
        elif name == "tok":
            dims[0] = "model"           # vocab
        elif name == "unembed":
            dims[-2], dims[-1] = fa, "model"
        elif name == "conv_w":
            dims[-1] = "model"
        elif name == "router":
            pass                        # tiny — replicate
        return NamedSharding(mesh, _fit(mesh, dims, shape))

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def activation_rules(mesh: Mesh, shape: InputShape,
                     kv_seq_axis: str | None = None,
                     act_shard: bool = False) -> dict:
    """Logical-axis → mesh-axis rules fed to repro.sharding.use_rules.

    kv_seq_axis overrides the decode-cache seq sharding (§Perf lever:
    "model" shards the KV cache 16× instead of replicating it across the
    tensor-parallel columns)."""
    ba = batch_axes(mesh)
    b1 = shape.global_batch == 1
    return {
        "batch": None if b1 else ba,
        "tokens": None if b1 else ba,       # flattened (B·S) MoE token dim
        "seq": None,
        "heads": "model",
        "heads4d": "model",                 # 4-D head dim (uneven allowed)
        # residual-stream d_model sharding between layers (§Perf lever:
        # cuts scan-carry remat residuals by the TP width)
        "embed": "model" if act_shard else None,
        "kv_heads": "model",
        "ffn": "model",
        "experts": "model",
        "vocab": "model",
        # decode: default "data" only for batch=1 long-context
        "kv_seq": kv_seq_axis if kv_seq_axis else ("data" if b1 else None),
    }


def cache_specs(mesh: Mesh, cfg: ModelConfig, cache_shape, *,
                global_batch: int, kv_seq_axis: str | None = None):
    """Decode-cache PartitionSpecs. Leaves: k/v (L,B,Hkv,S,hd) head-major,
    conv (L,B,K,C), state (L,B,H,hd,st)."""
    ba = batch_axes(mesh)
    b1 = global_batch == 1
    seq_ax = kv_seq_axis if kv_seq_axis else ("data" if b1 else None)

    def leaf(path, x):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1]
        shape = x.shape
        if name in ("k", "v"):
            dims = [None, None if b1 else ba, None, seq_ax, None]
        elif name == "conv":
            dims = [None, None if b1 else ba, None, "model"]
        elif name == "state":
            dims = [None, None if b1 else ba, "model", None, None]
        else:
            dims = [None] * len(shape)
        return NamedSharding(mesh, _fit(mesh, dims, shape))

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# Input ShapeDtypeStructs per (arch × input shape)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                kv_seq_axis: str | None = None):
    """ShapeDtypeStruct stand-ins + NamedShardings for every model input of
    the lowered step (no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    ba = batch_axes(mesh)
    b_axis = ba if B % _axis_size(mesh, ba) == 0 else None
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if shape.kind in ("train", "prefill"):
        if cfg.frontend is not None:  # stubbed modality frontend
            batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            spec = {"embeds": NamedSharding(mesh, P(b_axis, None, None)),
                    "labels": NamedSharding(mesh, P(b_axis, None))}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            sh = NamedSharding(mesh, P(b_axis, None))
            spec = {"tokens": sh, "labels": sh}
        if shape.kind == "prefill":
            batch.pop("labels")
            spec.pop("labels")
        return batch, spec

    # decode: one new token against a seq_len cache
    cache_shape = jax.eval_shape(
        functools.partial(mdl.init_decode_cache, cfg, B, S))
    cspec = cache_specs(mesh, cfg, cache_shape, global_batch=B,
                        kv_seq_axis=kv_seq_axis)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_spec = NamedSharding(mesh, P(b_axis, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return ({"cache": cache_shape, "tokens": tok, "pos": pos},
            {"cache": cspec, "tokens": tok_spec,
             "pos": NamedSharding(mesh, P())})
