"""Production meshes (TPU v5e).

Single pod: (16, 16) = ("data", "model") — 256 chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips, the "pod"
axis crossing the inter-pod DCN/ICI boundary.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            "under launch/dryrun.py (it forces 512 host devices).")
    return jax.make_mesh(shape, axes, devices=devices[:n])


# Hardware constants for the roofline (TPU v5e per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
