"""Roofline terms derived from the compiled dry-run artifact.

``compiled.cost_analysis()`` on XLA:CPU counts while-loop bodies ONCE —
useless for scan-over-layers models (verified empirically: a 28-layer scan
reports ~1/28 of the matmul FLOPs). We therefore parse the post-SPMD,
post-optimization HLO text ourselves and propagate costs through the call
graph with loop-trip multipliers:

  * FLOPs       — every ``dot`` op: 2 · |out| · Π(lhs contracting dims)
                  (MXU work; elementwise FLOPs are ignored, as in MFU math);
  * HBM bytes   — per top-level op: |output| + Σ|operands| (fusion interiors
                  excluded — a fusion's HBM traffic is its operands/outputs;
                  free ops: parameter/constant/GTE/tuple/bitcast);
  * collectives — all-gather / all-reduce / reduce-scatter / all-to-all /
                  collective-permute output shard bytes, by kind.

Shapes in post-SPMD HLO are per-device ⇒ all sums are per-chip. While-loop
trip counts are parsed from the max integer constant in the loop's condition
computation (exact for lax.scan-generated loops).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "tuple-select"}
# Pure elementwise ops fuse into neighbours on TPU — the XLA:CPU HLO we parse
# keeps them unfused, so counting their traffic would badly overestimate a
# TPU memory term. They are skipped (their inputs/outputs are counted at the
# producing/consuming structural op).
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "negate",
    "convert", "select", "compare", "and", "or", "not", "xor", "power",
    "rsqrt", "sqrt", "cbrt", "tanh", "floor", "ceil", "sign", "clamp",
    "broadcast", "reshape", "map", "erf", "logistic", "atan2", "is-finite",
    "reduce-precision", "round-nearest-afz", "round-nearest-even",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "rem",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*(\(?[^){=]*\)?[^{=(]*)\s"
                     r"*([a-z][\w\-]*)\(")
_SYM_RE = re.compile(r"%([\w.\-]+)\s*=\s*([a-z0-9]+\[[\d,]*\])")
_PARAM_SYM_RE = re.compile(r"%?([\w.\-]+):\s*([a-z0-9]+\[[\d,]*\])")
_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls=|to_apply=|condition=|body=)%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT_RE = re.compile(r"=\s*[su](?:8|16|32|64)\[\]\s+constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPCODE_RE = re.compile(r"=\s*(\([^=]*?\)|[a-z0-9]+\[[\d,]*\]\S*|\S+)\s+"
                        r"([a-z][\w\-]*)\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


class HloCosts:
    def __init__(self, hlo: str):
        self.comps: Dict[str, list] = {}
        self.entry = None
        name, buf = None, []
        for ln in hlo.splitlines():
            m = _HEAD_RE.match(ln)
            if m:
                if name is not None:
                    self.comps[name] = buf
                name, buf = m.group(2), [ln]
                if m.group(1):
                    self.entry = name
            elif name is not None:
                buf.append(ln)
        if name is not None:
            self.comps[name] = buf

        # global symbol table name → shape string
        self.symtab: Dict[str, str] = {}
        for m in _SYM_RE.finditer(hlo):
            self.symtab.setdefault(m.group(1), m.group(2))
        for m in _PARAM_SYM_RE.finditer(hlo):
            self.symtab.setdefault(m.group(1), m.group(2))

        self._direct = {}
        self._edges = {}
        self._trip = {}
        for cname, lines in self.comps.items():
            self._analyze(cname, lines)
        self._memo = {}

    # ------------------------------------------------------------------
    def _analyze(self, cname: str, lines):
        flops = 0.0
        bytes_ = 0.0
        coll = defaultdict(float)
        edges = defaultdict(float)  # callee → multiplicity (trip-adjusted)
        body = "\n".join(lines)

        for m in _WHILE_RE.finditer(body):
            cond, loop = m.group(1), m.group(2)
            consts = [int(c) for c in _CONST_INT_RE.findall(
                "\n".join(self.comps.get(cond, [])))]
            trip = max(consts) if consts else 1
            self._trip[loop] = trip
            edges[loop] += trip
            edges[cond] += trip

        for ln in lines[1:]:
            mo = _OPCODE_RE.search(ln)
            if not mo:
                continue
            out_shape, op = mo.group(1), mo.group(2)
            close = ln.find(")", mo.end())
            operand_str = ln[mo.end():close if close != -1 else len(ln)]
            operands = _OPERAND_RE.findall(operand_str)

            if op in ("fusion", "call"):
                for cm in _CALL_RE.finditer(ln):
                    if cm.group(1) in self.comps:
                        edges[cm.group(1)] += 1
            if op == "conditional":
                bm = _BRANCH_RE.search(ln)
                if bm:
                    for c in _OPERAND_RE.findall(bm.group(1)):
                        edges[c] += 1

            if op == "dot":
                out_dims = _shape_dims(out_shape) or []
                n_out = 1
                for d in out_dims:
                    n_out *= d
                cd = _LHS_CDIMS_RE.search(ln)
                k = 1
                if cd and operands:
                    lhs_shape = self.symtab.get(operands[0])
                    ldims = _shape_dims(lhs_shape) if lhs_shape else None
                    if ldims is not None and cd.group(1):
                        for i in cd.group(1).split(","):
                            if int(i) < len(ldims):
                                k *= ldims[int(i)]
                flops += 2.0 * n_out * k

            for cop in COLLECTIVE_OPS:
                if op == cop or op == cop + "-start":
                    coll[cop] += _shape_bytes(out_shape)

            if op in _FREE_OPS or op in _ELEMENTWISE or \
                    op in ("while", "conditional") or op.endswith("-done"):
                continue
            out_b = _shape_bytes(out_shape)
            if op == "dynamic-update-slice":
                # in-place on TPU: traffic = read+write of the update slice
                upd = self.symtab.get(operands[1]) if len(operands) > 1 else None
                bytes_ += 2 * _shape_bytes(upd) if upd else out_b
                continue
            if op == "dynamic-slice" or op == "gather":
                bytes_ += 2 * out_b
                continue
            if op == "fusion":
                callee = None
                cm = _CALL_RE.search(ln)
                if cm:
                    callee = cm.group(1)
                body_txt = "\n".join(self.comps.get(callee, []))
                if "dynamic-update-slice(" in body_txt:
                    # in-place update fusion: skip pass-through buffer
                    # operands (those as large as the output)
                    b = 0
                    for opn in operands:
                        s = self.symtab.get(opn)
                        if s and _shape_bytes(s) < out_b:
                            b += _shape_bytes(s)
                    bytes_ += 2 * b
                    continue
            b = out_b
            for opn in operands:
                s = self.symtab.get(opn)
                if s:
                    b += _shape_bytes(s)
            bytes_ += b

        self._direct[cname] = (flops, bytes_, dict(coll))
        self._edges[cname] = dict(edges)

    # ------------------------------------------------------------------
    def _cost_of(self, cname: str):
        if cname in self._memo:
            return self._memo[cname]
        self._memo[cname] = (0.0, 0.0, {})  # cycle guard
        f, b, c = self._direct.get(cname, (0.0, 0.0, {}))
        c = dict(c)
        for callee, mult in self._edges.get(cname, {}).items():
            if callee == cname:
                continue
            cf, cb, cc = self._cost_of(callee)
            f += cf * mult
            b += cb * mult
            for k, v in cc.items():
                c[k] = c.get(k, 0.0) + v * mult
        self._memo[cname] = (f, b, c)
        return self._memo[cname]

    def totals(self) -> dict:
        entry = self.entry or (list(self.comps)[-1] if self.comps else None)
        if entry is None:
            return {"flops": 0.0, "bytes": 0.0, "collectives": {}}
        f, b, c = self._cost_of(entry)
        return {"flops": f, "bytes": b, "collectives": c,
                "collective_bytes": sum(c.values())}


def roofline_terms(hlo: str, *, n_chips: int, peak_flops: float,
                   hbm_bw: float, ici_bw: float) -> dict:
    """Three roofline terms (seconds) from per-chip parsed costs."""
    t = HloCosts(hlo).totals()
    compute_s = t["flops"] / peak_flops
    memory_s = t["bytes"] / hbm_bw
    coll_s = t["collective_bytes"] / ici_bw
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", coll_s)), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dom,
            "hlo_flops_per_chip": t["flops"],
            "hlo_bytes_per_chip": t["bytes"],
            "collective_bytes_per_chip": t["collective_bytes"],
            "collectives": t["collectives"]}
