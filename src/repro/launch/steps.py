"""Step builders lowered by the drivers and the multi-pod dry-run."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import model as mdl
from repro.train.optim import AdamW


def make_train_step(cfg: ModelConfig, *, moe_mode: str = "dense",
                    q_chunk: int = 512, lr: float = 3e-4,
                    attn_layout: str = "grouped"):
    """(params, opt_state, batch) → (params, opt_state, loss) —
    loss + grads + AdamW update, the full training memory footprint."""
    opt = AdamW(lr=lr, weight_decay=0.1, clip_norm=1.0)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(mdl.loss_fn)(
            params, cfg, batch, moe_mode=moe_mode, q_chunk=q_chunk,
            attn_layout=attn_layout)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step, opt


def make_prefill_step(cfg: ModelConfig, *, moe_mode: str = "dense",
                      q_chunk: int = 512, attn_layout: str = "grouped"):
    """(params, batch) → (last-token logits[, decode cache]) — serving
    prefill. Encoder-only archs score the batch (no cache)."""
    want_cache = cfg.supports_decode

    def prefill_step(params, batch):
        out = mdl.forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), moe_mode=moe_mode,
                          q_chunk=q_chunk, logits_last_only=True,
                          return_cache=want_cache, attn_layout=attn_layout)
        if want_cache:
            logits, _, cache = out
            return logits, cache
        logits, _ = out
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, rolling: bool = False,
                     moe_mode: str = "dense"):
    """(params, cache, tokens, pos) → (logits, new cache) — one token."""

    def serve_step(params, cache, tokens, pos):
        return mdl.decode_step(params, cache, cfg, tokens=tokens, pos=pos,
                               rolling=rolling, moe_mode=moe_mode)

    return serve_step
