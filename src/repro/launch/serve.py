"""Serving driver: prefill + batched decode for one pool model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as mdl
from repro.serve.kv_cache import extend_cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only — no decode path")

    key = jax.random.PRNGKey(0)
    params = mdl.init_params(key, cfg)
    B, S, T = args.batch, args.prompt_len, args.new_tokens
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    t0 = time.time()
    logits, _, cache = mdl.forward(params, cfg, tokens=toks,
                                   logits_last_only=True, return_cache=True,
                                   q_chunk=min(512, S))
    cache = extend_cache(cache, S + T)
    print(f"prefill {B}×{S}: {time.time()-t0:.2f}s")

    step = jax.jit(lambda p, c, t, pos: mdl.decode_step(p, c, cfg, tokens=t,
                                                        pos=pos))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for t in range(T):
        logits_t, cache = step(params, cache, tok, jnp.int32(S + t))
        tok = jnp.argmax(logits_t[:, 0], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    print(f"decode {T} tokens × {B} seqs: {dt:.2f}s "
          f"({B*T/dt:.1f} tok/s)")
    print("sample:", jnp.concatenate(out, 1)[0].tolist())


if __name__ == "__main__":
    main()
