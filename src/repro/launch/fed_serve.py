"""Online federation runtime driver: serve → harvest → federate → swap.

The deployment-shaped counterpart of ``launch/fed_train.py``: instead of
fitting offline over a pre-built split, this drives live heterogeneous
traffic through the continuous-batching engine while the ``FedLoop``
harvests per-client evaluations, refits the router federatedly over the
harvested buffers, and hot-swaps the new state under traffic — then
reports the online router's frontier AUC against per-client routers
frozen after the first phase (the no-federation deployment).

Run standalone on CPU:
  PYTHONPATH=src python -m repro.launch.fed_serve --clients 6 --phases 2
  PYTHONPATH=src python -m repro.launch.fed_serve --secure-agg --dp 0.01
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--phases", type=int, default=2)
    ap.add_argument("--queries-per-phase", type=int, default=96)
    ap.add_argument("--drift", type=float, default=1.0)
    ap.add_argument("--onboard-phase", type=int, default=None,
                    help="phase at which a reserved model joins the pool")
    ap.add_argument("--secure-agg", action="store_true",
                    help="aggregate syncs with pairwise secure-agg masking")
    ap.add_argument("--dp", type=float, default=0.0,
                    help="central-DP noise sigma on the aggregate")
    args = ap.parse_args()

    from repro.fed.aggregators import (FedAvgAggregator,
                                       GaussianDPAggregator,
                                       SecureAggAggregator)
    from repro.fed.scenarios import ScenarioConfig, run_online_vs_frozen

    agg = SecureAggAggregator() if args.secure_agg else None
    if args.dp > 0.0:
        agg = GaussianDPAggregator(sigma=args.dp,
                                   inner=agg or FedAvgAggregator())

    cfg = ScenarioConfig(n_clients=args.clients, phases=args.phases,
                         queries_per_phase=args.queries_per_phase,
                         drift=args.drift)
    m = run_online_vs_frozen(cfg, aggregator=agg,
                             onboard_phase=args.onboard_phase)
    print(f"served {m['requests_served']} requests, harvested "
          f"{m['harvested_samples']} evaluations "
          f"({m['harvest_bytes'] / 2 ** 10:.0f} KiB, bounded), "
          f"{m['syncs']} federated syncs → router v{m['router_version']}")
    for p, (on, fr) in enumerate(zip(m["auc_online"],
                                     m["auc_frozen_local"])):
        tag = " (drifted)" if p > 0 else ""
        print(f"phase {p}{tag}: frontier AUC online {on:.3f} vs "
              f"frozen client-local {fr:.3f}")
    print(f"final gap: {m['auc_gap_final']:+.3f} "
          f"({m['num_models_final']} pool models)")


if __name__ == "__main__":
    main()
