"""Distributed federated-router training driver.

Maps the paper's client/server communication pattern onto the TPU mesh
(DESIGN.md §3): clients are sharded over a 1-D "clients" mesh axis with
``shard_map``; each device runs its local clients' FedAvg updates (vmap);
the server aggregation (Alg. 1 line 11) becomes a weighted ``psum`` — the
TPU-idiomatic replacement for a parameter server. All of that now lives
behind ``repro.routers.fit_federated(..., mesh=...)``; this driver just
builds the mesh, the data, and the router.

Run standalone (simulates 8 devices on CPU):
  PYTHONPATH=src python -m repro.launch.fed_train --clients 16 --rounds 10
"""
import os

if __name__ == "__main__":  # only force fake devices when run as a driver
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

# ruff: noqa: E402
import argparse

import jax
from jax.sharding import Mesh

from repro import routers, sharding as shd
from repro.config import FedConfig, RouterConfig
from repro.core import policy
from repro.data.partition import federated_split
from repro.data.synthetic import make_eval_corpus


def make_client_mesh():
    return shd.client_mesh()


def fedavg_distributed(key, data, rcfg: RouterConfig, fcfg: FedConfig, *,
                       rounds: int, mesh: Mesh):
    """Sharded Alg. 1 through the unified entry point. Returns
    (fitted MLPRouter, per-round losses)."""
    router = routers.make("mlp", rcfg)
    router, hist = routers.fit_federated(router, data, fcfg, key=key,
                                         rounds=rounds, mesh=mesh)
    return router, hist["loss"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--queries", type=int, default=4000)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    corpus = make_eval_corpus(key, n_queries=args.queries, d_emb=64)
    rcfg = RouterConfig(d_emb=64, num_models=11)
    fcfg = FedConfig(num_clients=args.clients)
    split = federated_split(jax.random.PRNGKey(1), corpus, fcfg)

    mesh = make_client_mesh()
    print(f"devices: {len(jax.devices())}, clients: {args.clients}")
    # keep the slab distributed end to end: each device holds its own
    # block of clients, never the full stack
    train = shd.shard_clients(split["train"], mesh)
    router, losses = fedavg_distributed(jax.random.PRNGKey(2),
                                        train, rcfg, fcfg,
                                        rounds=args.rounds, mesh=mesh)
    tg = split["test_global"]
    *_, auc = policy.eval_router(router.predict, tg["x"], tg["acc_table"],
                                 tg["cost_table"])
    print(f"loss: {losses[0]:.4f} → {losses[-1]:.4f}; global-test AUC {auc:.3f}")


if __name__ == "__main__":
    main()
