"""Distributed federated-router training driver.

Maps the paper's client/server communication pattern onto the TPU mesh
(DESIGN.md §3): clients are sharded over a 1-D "clients" mesh axis with
``shard_map``; each device runs its local clients' FedAvg updates (vmap);
the server aggregation (Alg. 1 line 11) becomes a weighted ``psum`` — the
TPU-idiomatic replacement for a parameter server.

Run standalone (simulates 8 devices on CPU):
  PYTHONPATH=src python -m repro.launch.fed_train --clients 16 --rounds 10
"""
import os

if __name__ == "__main__":  # only force fake devices when run as a driver
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

# ruff: noqa: E402
import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map

from repro.config import FedConfig, RouterConfig
from repro.core import federated as F
from repro.core import mlp_router as R
from repro.core import policy
from repro.data.partition import federated_split
from repro.data.synthetic import make_eval_corpus


def make_client_mesh():
    devs = np.array(jax.devices())
    return Mesh(devs, ("clients",))


def fedavg_round_sharded(params, data, key, rcfg, fcfg, opt, max_steps,
                         mesh: Mesh):
    """One FedAvg round with clients sharded across devices."""
    N = data["x"].shape[0]
    n_dev = mesh.shape["clients"]
    assert N % n_dev == 0, "num_clients must divide the client-mesh size"
    key, k_sel, k_cli = jax.random.split(key, 3)
    n_active = max(1, int(round(fcfg.participation * N)))
    perm = jax.random.permutation(k_sel, N)
    active = jnp.zeros((N,)).at[perm[:n_active]].set(1.0)
    keys = jax.random.split(k_cli, N)

    upd = functools.partial(F.client_update, rcfg=rcfg, fcfg=fcfg, opt=opt,
                            max_steps=max_steps)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P("clients"), P("clients"), P("clients")),
        out_specs=(P(), P()),
        check_vma=False)
    def round_fn(params, data_shard, keys_shard, active_shard):
        # local clients on this device
        cp, closs = jax.vmap(lambda d, k: upd(params, d, k)[0:2],
                             in_axes=(0, 0))(data_shard, keys_shard)
        w = jnp.sum(data_shard["w"], axis=-1) * active_shard
        wsum = jax.lax.psum(jnp.sum(w), "clients")
        agg = jax.tree.map(
            lambda s: jax.lax.psum(
                jnp.tensordot(w, s.astype(jnp.float32), axes=1), "clients")
            / jnp.maximum(wsum, 1e-12),
            cp)
        loss = jax.lax.psum(jnp.sum(closs * w), "clients") / jnp.maximum(
            wsum, 1e-12)
        return agg, loss

    new_params, loss = round_fn(params, data, keys, active)
    return jax.tree.map(lambda a, b: a.astype(b.dtype), new_params,
                        params), loss


def fedavg_distributed(key, data, rcfg: RouterConfig, fcfg: FedConfig, *,
                       rounds: int, mesh: Mesh):
    opt = F._make_opt(fcfg, "adamw")
    D_max = data["x"].shape[1]
    max_steps = max(1, int(np.ceil(D_max / fcfg.batch_size)))
    key, k_init = jax.random.split(key)
    params = R.init_mlp_router(k_init, rcfg)
    losses = []
    step = jax.jit(functools.partial(
        fedavg_round_sharded, rcfg=rcfg, fcfg=fcfg, opt=opt,
        max_steps=max_steps, mesh=mesh))
    for _ in range(rounds):
        key, k_r = jax.random.split(key)
        params, loss = step(params, data, k_r)
        losses.append(float(loss))
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--queries", type=int, default=4000)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    corpus = make_eval_corpus(key, n_queries=args.queries, d_emb=64)
    rcfg = RouterConfig(d_emb=64, num_models=11)
    fcfg = FedConfig(num_clients=args.clients)
    split = federated_split(jax.random.PRNGKey(1), corpus, fcfg)

    mesh = make_client_mesh()
    print(f"devices: {len(jax.devices())}, clients: {args.clients}")
    params, losses = fedavg_distributed(jax.random.PRNGKey(2),
                                        split["train"], rcfg, fcfg,
                                        rounds=args.rounds, mesh=mesh)
    tg = split["test_global"]
    *_, auc = policy.eval_router(lambda x: R.apply_mlp_router(params, x),
                                 tg["x"], tg["acc_table"], tg["cost_table"])
    print(f"loss: {losses[0]:.4f} → {losses[-1]:.4f}; global-test AUC {auc:.3f}")


if __name__ == "__main__":
    main()
