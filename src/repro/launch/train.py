"""Training driver.

Two modes:
  * CPU / small-scale (default): actually trains a reduced or full config on
    the local devices — used by examples/train_lm.py for the end-to-end
    ~100M-param run.
  * --lower-only: builds the production-mesh train step exactly like the
    dry-run (for launcher parity checks).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as mdl
from repro.train import checkpoint as ckpt
from repro.train.lm_data import MarkovLM
from repro.train.optim import AdamW, cosine_schedule


def train_loop(cfg, *, steps: int, batch: int, seq: int, lr: float = 3e-4,
               seed: int = 0, log_every: int = 20, ckpt_path=None,
               moe_mode: str = "dense", d_model_vocab_cap: int | None = 8192):
    vocab = min(cfg.vocab, d_model_vocab_cap or cfg.vocab)
    data = MarkovLM(vocab, seed=seed)
    params = mdl.init_params(jax.random.PRNGKey(seed), cfg)
    opt = AdamW(lr=cosine_schedule(lr, warmup=max(10, steps // 20),
                                   total=steps),
                weight_decay=0.1, clip_norm=1.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch_):
        loss, grads = jax.value_and_grad(mdl.loss_fn)(
            params, cfg, batch_, moe_mode=moe_mode, q_chunk=min(512, seq))
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    it = data.batches(batch, seq)
    hist = []
    t0 = time.time()
    for s in range(steps):
        b = next(it)
        b = {k: jnp.asarray(np.minimum(v, cfg.vocab - 1)) for k, v in b.items()}
        params, opt_state, loss = step(params, opt_state, b)
        hist.append(float(loss))
        if s % log_every == 0 or s == steps - 1:
            print(f"step {s:5d}  loss {hist[-1]:.4f}  "
                  f"({(time.time()-t0)/(s+1):.2f}s/step)")
    if ckpt_path:
        ckpt.save(ckpt_path, {"params": params, "step": steps})
        print("saved checkpoint →", ckpt_path)
    return params, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    _, hist = train_loop(cfg, steps=args.steps, batch=args.batch,
                         seq=args.seq, lr=args.lr, ckpt_path=args.ckpt)
    print(f"final loss {hist[-1]:.4f} (start {hist[0]:.4f})")


if __name__ == "__main__":
    main()
