"""Configuration dataclasses for FedRoute.

Three config families:
  * ModelConfig  — one member of the routed LLM pool (the serving substrate).
  * RouterConfig — the paper's MLP / K-means router hyperparameters.
  * FedConfig    — federated simulation protocol (Section 6 of the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model pool configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    # d_ff of each expert (may differ from the dense d_ff field).
    d_expert: int = 0
    # Load-balance auxiliary loss coefficient.
    aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block hyperparameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # --- attention options ---
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # Sliding window used for the long-context decode variant (and, when
    # `sliding_window_always` is set, for every attention layer).
    sliding_window: int = 8192
    sliding_window_always: bool = False
    causal: bool = True  # False for encoder-only (hubert)
    # --- MoE / SSM / hybrid ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: period P means 1 attention layer per P layers (rest mamba).
    hybrid_attn_period: int = 0
    # MoE interleave: 1 = every layer is MoE; 2 = every other layer, etc.
    moe_period: int = 1
    # --- modality frontend (stubbed: inputs arrive as embeddings) ---
    frontend: Optional[str] = None  # None | "audio" | "vision"
    encoder_only: bool = False
    # --- misc ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    @property
    def sub_quadratic(self) -> bool:
        """Whether long_500k is runnable: native (ssm/hybrid) or via the
        sliding-window variant (implemented for all attention archs)."""
        return self.supports_decode

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        # keep q_per_kv ratio >= 1
        n_kv = min(n_kv, n_heads)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(4, self.moe.num_experts),
                top_k=min(2, self.moe.top_k), d_expert=128)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=32,
                                      chunk=64)
        n_layers = 2
        if self.hybrid_attn_period:
            n_layers = self.hybrid_attn_period  # one full hybrid group
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv, d_ff=min(self.d_ff, 512) or 0,
            vocab=min(self.vocab, 512), head_dim=64, moe=moe, ssm=ssm,
            sliding_window=128, dtype="float32")


# ---------------------------------------------------------------------------
# Router / federated configs (paper Section 6 + Appendix C defaults)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    d_emb: int = 768               # all-mpnet-base-v2 dimension
    num_models: int = 11           # RouterBench-Data pool size
    hidden: Tuple[int, ...] = (512, 512)
    dropout: float = 0.1
    # K-means router
    k_local: int = 15
    k_global: int = 20
    n_init: int = 3
    kmeans_iters: int = 30
    c_max: float = 1.0             # costs normalized to [0, c_max]
    # Matrix-factorization router (query-embedding × model-id factors)
    mf_rank: int = 32
    # Elo/ranking router (similarity-weighted one-shot ratings)
    elo_tau: float = 0.15          # kernel bandwidth, units of sqrt(d_emb)
    elo_prior: float = 4.0         # pseudo-count shrinkage to global mean


@dataclasses.dataclass(frozen=True)
class FedConfig:
    num_clients: int = 10
    participation: float = 0.6
    rounds: int = 30
    local_epochs: int = 1
    batch_size: int = 128
    lr: float = 1e-3
    weight_decay: float = 3e-4
    clip_norm: float = 1.0
    dirichlet_alpha: float = 0.6     # query heterogeneity over tasks
    model_alpha: float = 0.45        # per-client model-logging heterogeneity
    train_frac: float = 0.75
    seed: int = 0


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
