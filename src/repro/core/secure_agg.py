"""Secure aggregation simulation (Bonawitz et al. 2016, cited in §2/App. A).

Pairwise additive masking: every client pair (i, j) derives a shared mask
from a common PRNG seed; client i ADDS the pair mask when i < j and
SUBTRACTS it when i > j, so all masks cancel exactly in the server's sum —
the server learns only Σᵢ wᵢ·θᵢ, never any individual θᵢ. This composes
with the FedAvg aggregation (Alg. 1 line 11) and with central-DP noise
(``fedavg(dp_sigma=…)``); dropout recovery/key agreement are out of scope
for the simulation (see the paper for the full protocol).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pair_key(round_key, i: int, j: int):
    """Shared key for the unordered pair {i, j} (both clients derive it)."""
    lo, hi = (i, j) if i < j else (j, i)
    return jax.random.fold_in(jax.random.fold_in(round_key, lo), hi)


def _mask_like(key, tree, scale: float):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    masks = [scale * jax.random.normal(k, l.shape, jnp.float32)
             for k, l in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, masks)


def mask_update(round_key, client_id: int, n_clients: int, update,
                weight: float, *, scale: float = 10.0):
    """Client-side: weight the update and add the pairwise masks.
    Returns the masked contribution wᵢ·θᵢ + Σⱼ ±mask_{ij}."""
    out = jax.tree.map(lambda a: weight * a.astype(jnp.float32), update)
    for j in range(n_clients):
        if j == client_id:
            continue
        m = _mask_like(_pair_key(round_key, client_id, j), update, scale)
        sign = 1.0 if client_id < j else -1.0
        out = jax.tree.map(lambda a, mm: a + sign * mm, out, m)
    return out


def secure_aggregate(masked_contributions, total_weight: float):
    """Server-side: sum the masked contributions (masks cancel) and
    normalize. The server never handles an unmasked individual update."""
    total = masked_contributions[0]
    for c in masked_contributions[1:]:
        total = jax.tree.map(jnp.add, total, c)
    return jax.tree.map(lambda a: a / max(total_weight, 1e-12), total)
