"""Nonparametric K-Means-Router — paper Algorithm 2.

One-shot federated clustering: (i) each client runs local K-means and
uploads (centroid, size) pairs; (ii) the server runs size-weighted K-means
over the uploaded centroids; (iii) clients compute per-(cluster, model)
accuracy/cost sums + counts against the global centers; (iv) the server
aggregates count-weighted statistics. Inference: nearest global center →
cluster-level utility argmax.

A router is a dict θ = {"centroids": (K,d), "A": (K,M), "C": (K,M),
"n": (K,M)} — exactly the parameterization in Alg. 2 line 15. (k,m) cells
with no samples fall back to that model's global (count-weighted) mean; a
model never observed anywhere gets the pessimistic (acc 0, cost c_max).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import RouterConfig
from repro.core.kmeans import kmeans
from repro.kernels import ops as kops


def _cluster_stats(centroids, data_i, K: int, M: int):
    """Sums/counts of acc & cost per (cluster, model) for one client
    (Alg. 2 lines 9–12 — we ship sums+counts ≡ means+counts)."""
    assign = kops.kmeans_assign(data_i["x"], centroids)        # (D,)
    idx = assign * M + data_i["m"]                              # (D,)
    w = data_i["w"]
    seg = functools.partial(jax.ops.segment_sum, num_segments=K * M,
                            indices_are_sorted=False)
    n = seg(w, idx).reshape(K, M)
    a = seg(w * data_i["acc"], idx).reshape(K, M)
    c = seg(w * data_i["cost"], idx).reshape(K, M)
    return a, c, n


def _finalize(a_sum, c_sum, n, c_max: float):
    """Aggregate sums → estimators with the empty-cell fallback."""
    has = n > 0
    # global per-model backoff (count-weighted over clusters)
    tot_n = jnp.sum(n, axis=0)                                  # (M,)
    ga = jnp.where(tot_n > 0, jnp.sum(a_sum, 0) / jnp.maximum(tot_n, 1e-12), 0.0)
    gc = jnp.where(tot_n > 0, jnp.sum(c_sum, 0) / jnp.maximum(tot_n, 1e-12),
                   c_max)
    A = jnp.where(has, a_sum / jnp.maximum(n, 1e-12), ga[None, :])
    C = jnp.where(has, c_sum / jnp.maximum(n, 1e-12), gc[None, :])
    return A, C


def fed_centroids(key, data, rcfg: RouterConfig, *, client_mask=None
                  ) -> jnp.ndarray:
    """Alg. 2 stages (i)+(ii): local K-means per client (centroid, size
    uploads) → server size-weighted K-means → (k_global, d) centers.
    Shared by every one-shot family that anchors statistics to a federated
    partition of embedding space (K-means, Elo)."""
    N, D, d = data["x"].shape
    kl, kg = jax.random.split(key)

    # (i) local K-means per client
    def local(key_i, data_i):
        cents, _ = kmeans(key_i, data_i["x"], rcfg.k_local,
                          iters=rcfg.kmeans_iters, n_init=rcfg.n_init,
                          mask=data_i["w"] > 0)
        sizes = jnp.bincount(kops.kmeans_assign(data_i["x"], cents),
                             weights=data_i["w"], length=rcfg.k_local)
        return cents, sizes

    cents, sizes = jax.vmap(local)(jax.random.split(kl, N), data)
    if client_mask is not None:
        sizes = sizes * client_mask[:, None]

    # (ii) server: size-weighted K-means over uploaded centroids
    flat_c = cents.reshape(N * rcfg.k_local, d)
    flat_w = sizes.reshape(N * rcfg.k_local)
    centroids, _ = kmeans(kg, flat_c, rcfg.k_global,
                          iters=rcfg.kmeans_iters, n_init=rcfg.n_init,
                          weights=flat_w)
    return centroids


def fed_kmeans_router(key, data, rcfg: RouterConfig, *, num_models=None,
                      client_mask=None) -> dict:
    """Algorithm 2. data: stacked padded client arrays (see federated.py)."""
    M = num_models if num_models is not None else rcfg.num_models
    centroids = fed_centroids(key, data, rcfg, client_mask=client_mask)

    # (iii) clients → per-(cluster, model) stats; (iv) weighted aggregation
    a, c, n = jax.vmap(lambda di: _cluster_stats(centroids, di,
                                                 rcfg.k_global, M))(data)
    if client_mask is not None:
        m3 = client_mask[:, None, None]
        a, c, n = a * m3, c * m3, n * m3
    a, c, n = jnp.sum(a, 0), jnp.sum(c, 0), jnp.sum(n, 0)
    A, C = _finalize(a, c, n, rcfg.c_max)
    return {"centroids": centroids, "A": A, "C": C, "n": n}


def fed_kmeans_router_sharded(key, data, rcfg: RouterConfig, *,
                              num_models=None, mesh=None) -> dict:
    """Algorithm 2 under ``shard_map`` over a 1-D ``"clients"`` mesh:
    stage (i) — the expensive per-client local K-means — runs
    device-parallel on each device's block of the stacked slab; the
    (centroid, size) uploads and the per-(cluster, model) statistics
    return to the server stages through tiled ``all_gather``s in global
    client order (pure data movement), and stages (ii)+(iv) run
    replicated — so the result is bit-for-bit ``fed_kmeans_router`` on a
    fixed key, for any mesh shape."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import shard_map

    M = num_models if num_models is not None else rcfg.num_models
    N, D, d = data["x"].shape
    n_dev = mesh.shape["clients"]
    if N % n_dev != 0:
        raise ValueError(
            f"N={N} stacked clients do not divide the {n_dev}-device "
            "clients mesh — pad the stack (federated.pad_client_axis) or "
            "resize the mesh")
    L = N // n_dev

    def run(key, data_loc):
        dd = jax.lax.axis_index("clients")
        kl, kg = jax.random.split(key)
        keys = jax.random.split(kl, N)                        # replicated
        keys_loc = jax.lax.dynamic_slice_in_dim(keys, dd * L, L, 0)

        def local(key_i, data_i):
            cents, _ = kmeans(key_i, data_i["x"], rcfg.k_local,
                              iters=rcfg.kmeans_iters, n_init=rcfg.n_init,
                              mask=data_i["w"] > 0)
            sizes = jnp.bincount(kops.kmeans_assign(data_i["x"], cents),
                                 weights=data_i["w"], length=rcfg.k_local)
            return cents, sizes

        cents_l, sizes_l = jax.vmap(local)(keys_loc, data_loc)
        ag = functools.partial(jax.lax.all_gather, axis_name="clients",
                               axis=0, tiled=True)
        cents, sizes = ag(cents_l), ag(sizes_l)
        # (ii) server K-means over the uploads — replicated, verbatim
        centroids, _ = kmeans(kg, cents.reshape(N * rcfg.k_local, d),
                              rcfg.k_global, iters=rcfg.kmeans_iters,
                              n_init=rcfg.n_init,
                              weights=sizes.reshape(N * rcfg.k_local))
        # (iii) per-client statistics on this device's block
        a, c, n = jax.vmap(lambda di: _cluster_stats(
            centroids, di, rcfg.k_global, M))(data_loc)
        # (iv) gather then reduce replicated — same summation order as
        # the in-process jnp.sum over the full stack
        a, c, n = (jnp.sum(ag(a), 0), jnp.sum(ag(c), 0),
                   jnp.sum(ag(n), 0))
        A, C = _finalize(a, c, n, rcfg.c_max)
        return {"centroids": centroids, "A": A, "C": C, "n": n}

    fn = shard_map(run, mesh, in_specs=(P(), P("clients")), out_specs=P())
    return fn(key, data)


def local_kmeans_router(key, data_i, rcfg: RouterConfig, *,
                        num_models=None, k=None) -> dict:
    """Client-local (no-FL) baseline: own K-means + own statistics."""
    M = num_models if num_models is not None else rcfg.num_models
    K = k if k is not None else rcfg.k_local
    centroids, _ = kmeans(key, data_i["x"], K, iters=rcfg.kmeans_iters,
                          n_init=rcfg.n_init, mask=data_i["w"] > 0)
    a, c, n = _cluster_stats(centroids, data_i, K, M)
    A, C = _finalize(a, c, n, rcfg.c_max)
    return {"centroids": centroids, "A": A, "C": C, "n": n}


def predict(router: dict, x: jnp.ndarray):
    """x: (Q, d) → (A (Q,M), C (Q,M)) cluster-level estimates."""
    k = kops.kmeans_assign(x, router["centroids"])
    return router["A"][k], router["C"][k]


# ---------------------------------------------------------------------------
# §6.3 model onboarding / App. D.3 client onboarding (training-free)
# ---------------------------------------------------------------------------


def add_model_stats(router: dict, calib, c_max: float = 1.0) -> dict:
    """Onboard one new model from calibration evaluations
    calib = {"x": (D,d), "acc": (D,), "cost": (D,), "w": (D,)}."""
    K = router["centroids"].shape[0]
    assign = kops.kmeans_assign(calib["x"], router["centroids"])
    seg = functools.partial(jax.ops.segment_sum, num_segments=K)
    n = seg(calib["w"], assign)
    a = seg(calib["w"] * calib["acc"], assign)
    c = seg(calib["w"] * calib["cost"], assign)
    tot = jnp.maximum(jnp.sum(n), 1e-12)
    ga, gc = jnp.sum(a) / tot, jnp.sum(c) / tot
    A_new = jnp.where(n > 0, a / jnp.maximum(n, 1e-12), ga)
    C_new = jnp.where(n > 0, c / jnp.maximum(n, 1e-12), gc)
    return {
        "centroids": router["centroids"],
        "A": jnp.concatenate([router["A"], A_new[:, None]], axis=1),
        "C": jnp.concatenate([router["C"], C_new[:, None]], axis=1),
        "n": jnp.concatenate([router["n"], n[:, None]], axis=1),
    }


def merge_client_stats(router: dict, data_new, rcfg: RouterConfig,
                       num_models=None) -> dict:
    """New clients join (App. D.3): weighted update of cluster statistics
    against the *existing* centers — no participation from old clients."""
    M = num_models if num_models is not None else rcfg.num_models
    K = router["centroids"].shape[0]
    a, c, n = jax.vmap(lambda di: _cluster_stats(router["centroids"], di,
                                                 K, M))(data_new)
    a, c, n = jnp.sum(a, 0), jnp.sum(c, 0), jnp.sum(n, 0)
    # recover old sums from means × counts, then combine
    a_tot = router["A"] * router["n"] + a
    c_tot = router["C"] * router["n"] + c
    n_tot = router["n"] + n
    A, C = _finalize(a_tot, c_tot, n_tot, rcfg.c_max)
    return {"centroids": router["centroids"], "A": A, "C": C, "n": n_tot}
