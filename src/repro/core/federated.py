"""Federated MLP-Router training — paper Algorithm 1 (+ Appendix C.1).

Clients are simulated as a stacked, padded pytree so one ``vmap`` runs every
client's local epoch in parallel; on a multi-device mesh the same round is
``shard_map``-ped over a 1-D ``"clients"`` axis
(``fedavg_round_sharded``): each device trains its own block of the
stacked slab, cohort slabs are exchanged with masked ``psum``s, and the
updates return to the (replicated) server aggregation through a sorted
``all_gather`` — so every ``Aggregator`` strategy runs verbatim on the
full global-order stack and the sharded fit is bit-for-bit the in-process
one on a fixed key. ``fedavg(mesh=...)`` selects it.

Client dataset layout (N clients, padded to D_max rows):
  {"x": (N, D, d_emb), "m": (N, D) int32, "acc": (N, D), "cost": (N, D),
   "w": (N, D) ∈ {0,1} valid-row mask}
"""
from __future__ import annotations

import collections
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import FedConfig, RouterConfig
from repro.core import mlp_router as R
from repro.sharding import shard_map
from repro.train.optim import SGD, AdamW

# Appended at *trace* time from inside ``fedavg_round`` — one entry per
# compile, none per execution. Mirrors ``serve.engine.TRACE_LOG`` (layering
# keeps core/ from importing serve/, so the fit path gets its own log).
# Tests pin that cohort-sampled fits never retrace across rounds/syncs.
FIT_TRACE_LOG = collections.deque(maxlen=4096)


def reset_fit_trace_log() -> None:
    FIT_TRACE_LOG.clear()


def dataset_sizes(data) -> jnp.ndarray:
    return jnp.sum(data["w"], axis=-1)  # (N,)


def _make_opt(fcfg: FedConfig, optimizer: str):
    if optimizer == "adamw":
        return AdamW(lr=fcfg.lr, weight_decay=fcfg.weight_decay,
                     clip_norm=fcfg.clip_norm)
    if optimizer == "sgd":
        return SGD(lr=fcfg.lr, clip_norm=None)
    raise ValueError(optimizer)


def _distill_loss(params, theta0, x, w, apply_fn=None):
    """App. D.3 regularizer: match the frozen base router's predictions.
    ``apply_fn(params, x) -> (A, C)`` selects the family's forward pass
    (default: the MLP router)."""
    apply_fn = apply_fn if apply_fn is not None else R.apply_mlp_router
    A, C = apply_fn(params, x)
    A0, C0 = apply_fn(theta0, x)
    per = jnp.mean((A - A0) ** 2 + (C - C0) ** 2, axis=-1)  # mean over models
    return jnp.sum(per * w) / jnp.maximum(jnp.sum(w), 1.0)


def client_update(params, data_i, key, rcfg: RouterConfig, fcfg: FedConfig,
                  opt, max_steps: int, *, full_batch: bool = False,
                  freeze=None, distill: Optional[tuple] = None,
                  loss_fn: Optional[Callable] = None):
    """τ local steps (≈1 epoch: ⌈D_i/batch⌉ active steps) on one client.

    ``loss_fn(params, batch, rcfg, rng=...)`` selects the family's training
    loss — None keeps the MLP router loss (bit-for-bit the legacy path),
    so any parametric family rides the same FedAvg machinery.
    ``distill`` is ``(theta0, beta)`` or ``(theta0, beta, apply_fn)``; the
    3-tuple form points the App. D.3 regularizer at a non-MLP forward pass.
    """
    base_loss = loss_fn if loss_fn is not None else R.router_loss
    D_i = jnp.sum(data_i["w"]).astype(jnp.int32)
    n_steps_i = jnp.ceil(D_i / fcfg.batch_size).astype(jnp.int32)
    opt_state = opt.init(params)

    def loss_fn(p, batch, rng):  # noqa: F811 — resolved family loss
        loss = base_loss(p, batch, rcfg, rng=rng)
        if distill is not None:
            theta0, beta = distill[0], distill[1]
            apply_fn = distill[2] if len(distill) > 2 else None
            w = batch.get("w")
            if w is None:  # don't build the all-ones fallback eagerly
                w = jnp.ones(batch["x"].shape[0])
            loss = loss + beta * _distill_loss(p, theta0, batch["x"], w,
                                               apply_fn)
        return loss

    def step(carry, s):
        params, opt_state, key = carry
        key, k_idx, k_drop = jax.random.split(key, 3)
        if full_batch:
            batch = data_i
            rng = None
        else:
            idx = jax.random.randint(k_idx, (fcfg.batch_size,), 0,
                                     jnp.maximum(D_i, 1))
            batch = jax.tree.map(lambda a: jnp.take(a, idx, axis=0), data_i)
            rng = k_drop
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
        if freeze is not None:
            grads = jax.tree.map(lambda g, f: g * f, grads, freeze)
        new_params, new_opt = opt.update(grads, opt_state, params)
        if freeze is not None:  # gate the whole delta: weight decay too
            new_params = jax.tree.map(
                lambda n, o, f: n * f + o * (1 - f), new_params, params,
                freeze)
        active = s < n_steps_i
        sel = lambda a, b: jax.tree.map(
            lambda u, v: jnp.where(active, u, v), a, b)
        return (sel(new_params, params), sel(new_opt, opt_state), key), loss

    (params, _, _), losses = jax.lax.scan(
        step, (params, opt_state, key), jnp.arange(max_steps))
    return params, jnp.mean(losses)


def _default_aggregator(dp_sigma: float):
    """The pre-refactor behaviour as a strategy object: plain weighted
    FedAvg, wrapped in central-DP noise when dp_sigma > 0 (bit-for-bit the
    old inline branch — same tensordot, same noise keys). Imported lazily:
    repro.fed is the higher layer."""
    from repro.fed.aggregators import FedAvgAggregator, GaussianDPAggregator
    if dp_sigma > 0.0:
        return GaussianDPAggregator(sigma=dp_sigma)
    return FedAvgAggregator()


def fedavg_round(params, data, key, rcfg: RouterConfig, fcfg: FedConfig,
                 opt, max_steps: int, *, full_batch=False, freeze=None,
                 distill=None, client_mask=None, dp_sigma: float = 0.0,
                 aggregator=None, loss_fn=None, cohort: Optional[int] = None,
                 staleness=None):
    """One communication round: local updates on active clients + server
    aggregation (Alg. 1 lines 3–11) through a pluggable strategy
    (``repro.fed.aggregators``). The default is plain weighted FedAvg;
    pass ``aggregator=`` for secure-agg masking or custom strategies.
    dp_sigma > 0 wraps whichever strategy runs in server-side Gaussian
    noise on the aggregate (central-DP flavour of the paper's privacy
    motivation — bit-for-bit the old inline branch on the default path,
    and composing over explicit strategies instead of being dropped).

    ``cohort=C`` samples C of the N stacked clients per round and gathers
    their stacks into a fixed ``(C, ...)`` slab *inside* the traced
    function — shapes stay static, so the scan-fused fit compiles once and
    never retraces across cohorts, and only C local updates run per round
    (the production sampled-participation shape: C ≪ N).
    ``fcfg.participation`` then applies within the cohort.

    ``staleness`` is an optional traced ``(N,)`` vector (rounds since each
    client's last contribution) forwarded to aggregators that declare
    ``needs_staleness`` (buffered-async / FedBuffer-style strategies);
    aggregators declaring ``needs_prev`` additionally receive the round's
    input server params (norm-clipped and delta-based strategies)."""
    N = data["x"].shape[0]
    FIT_TRACE_LOG.append(("fedavg_round", N, cohort,
                          type(aggregator).__name__ if aggregator is not None
                          else "default"))
    if cohort is not None:
        # Static-shape cohort gather: permutation + static slice keeps the
        # compiled round independent of *which* clients were drawn.
        key, k_coh = jax.random.split(key)
        idx = jax.random.permutation(k_coh, N)[:cohort]
        data = jax.tree.map(lambda a: jnp.take(a, idx, axis=0), data)
        if staleness is not None:
            staleness = jnp.take(staleness, idx, axis=0)
        N = cohort
    key, k_sel, k_cli, k_agg = jax.random.split(key, 4)
    n_active = max(1, int(round(fcfg.participation * N)))
    perm = jax.random.permutation(k_sel, N)
    active = jnp.zeros((N,)).at[perm[:n_active]].set(1.0)
    if client_mask is not None:  # restrict the eligible pool (App. D.3)
        active = active * client_mask
        active = jnp.where(jnp.sum(active) > 0, active, client_mask)

    upd = functools.partial(client_update, rcfg=rcfg, fcfg=fcfg, opt=opt,
                            max_steps=max_steps, full_batch=full_batch,
                            freeze=freeze, distill=distill, loss_fn=loss_fn)
    client_params, client_loss = jax.vmap(upd, in_axes=(None, 0, 0))(
        params, data, jax.random.split(k_cli, N))

    wts = dataset_sizes(data) * active
    if aggregator is None:
        agg = _default_aggregator(dp_sigma)
    elif dp_sigma > 0.0:
        # dp composes over any strategy (it is server-side noise on the
        # aggregate) — never silently drop the privacy knob
        from repro.fed.aggregators import GaussianDPAggregator
        agg = GaussianDPAggregator(sigma=dp_sigma, inner=aggregator)
    else:
        agg = aggregator
    # Strategy extras are declared, not positional: plain 3-arg strategies
    # (including any custom callable) keep their exact legacy call.
    extras = {}
    if getattr(agg, "needs_prev", False):
        extras["prev"] = params
    if getattr(agg, "needs_staleness", False):
        extras["staleness"] = (jnp.zeros_like(wts) if staleness is None
                               else staleness.astype(jnp.float32))
    new_params = agg(client_params, wts, k_agg, **extras)
    wn = wts / jnp.maximum(jnp.sum(wts), 1e-12)
    avg_loss = jnp.sum(client_loss * wn)
    return new_params, avg_loss


def pad_client_axis(data, multiple: int, staleness=None):
    """Pad the stacked client axis up to a multiple of ``multiple`` with
    empty clients (all-zero rows, ``w = 0`` — zero aggregation weight, so
    they never move the params). Returns ``(data, staleness)`` — the
    staleness vector, when given, pads with zeros. Used by mesh callers
    whose organic client count doesn't divide the device axis."""
    N = jax.tree.leaves(data)[0].shape[0]
    pad = (-N) % int(multiple)
    if pad == 0:
        return data, staleness
    data = jax.tree.map(
        lambda a: jnp.concatenate(
            [jnp.asarray(a),
             jnp.zeros((pad,) + a.shape[1:], jnp.asarray(a).dtype)]), data)
    if staleness is not None:
        staleness = jnp.concatenate(
            [jnp.asarray(staleness, jnp.float32), jnp.zeros((pad,))])
    return data, staleness


def fedavg_round_sharded(params, data, key, rcfg: RouterConfig,
                         fcfg: FedConfig, opt, max_steps: int, *,
                         mesh: Mesh, full_batch=False, dp_sigma: float = 0.0,
                         aggregator=None, loss_fn=None,
                         cohort: Optional[int] = None, staleness=None):
    """``fedavg_round`` under ``shard_map`` over a 1-D ``"clients"`` mesh:
    the stacked slab stays sharded (N/n_dev clients per device), local
    updates run device-parallel, and the server aggregation is replicated.

    Bit-for-bit contract: every random draw (cohort permutation, active
    mask, client keys, aggregation key) is computed *replicated* with the
    exact key splits of the in-process round, and the client-update stacks
    return to the aggregation through a tiled ``all_gather`` in global
    client order — pure data movement, no arithmetic — so every
    ``Aggregator`` strategy (including the sort-based robust ones and
    secure-agg's pairwise masks) sees exactly the stack the in-process
    path sees and the fit matches it bit-for-bit on a fixed key, for any
    mesh shape.

    ``cohort=C`` gathers the round's C-client slab across devices with a
    masked ``psum`` exchange (each device contributes the cohort rows it
    owns; adding zeros is exact), then splits it C/n_dev per device — the
    compiled round stays independent of which clients were drawn, same as
    in-process. The expensive stage — τ local steps × clients — is what
    parallelizes; aggregation is O(N · |params|) and runs replicated.
    """
    N = jax.tree.leaves(data)[0].shape[0]
    n_dev = mesh.shape["clients"]
    Np = cohort if cohort is not None else N      # clients trained per round
    L = Np // n_dev                               # ... per device
    FIT_TRACE_LOG.append(("fedavg_round_sharded", N, cohort, n_dev,
                          type(aggregator).__name__ if aggregator is not None
                          else "default"))
    upd = functools.partial(client_update, rcfg=rcfg, fcfg=fcfg, opt=opt,
                            max_steps=max_steps, full_batch=full_batch,
                            loss_fn=loss_fn)
    if aggregator is None:
        agg = _default_aggregator(dp_sigma)
    elif dp_sigma > 0.0:
        from repro.fed.aggregators import GaussianDPAggregator
        agg = GaussianDPAggregator(sigma=dp_sigma, inner=aggregator)
    else:
        agg = aggregator
    n_active = max(1, int(round(fcfg.participation * Np)))

    def body(params, data_loc, key, stal):
        d = jax.lax.axis_index("clients")
        if cohort is not None:
            key, k_coh = jax.random.split(key)
            idx = jax.random.permutation(k_coh, N)[:cohort]   # replicated
            lo = d * (N // n_dev)

            def exchange(a):
                # masked-psum cohort exchange: each device contributes the
                # cohort rows it owns; zeros elsewhere add exactly.
                rel = jnp.clip(idx - lo, 0, a.shape[0] - 1)
                own = (idx >= lo) & (idx < lo + a.shape[0])
                g = jnp.take(a, rel, axis=0)
                g = jnp.where(own.reshape((cohort,) + (1,) * (a.ndim - 1)),
                              g, jnp.zeros((), a.dtype))
                return jax.lax.psum(g, "clients")

            slab = jax.tree.map(exchange, data_loc)
            data_loc = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, d * L, L, 0), slab)
            if stal is not None:
                stal = jnp.take(stal, idx, axis=0)
        key, k_sel, k_cli, k_agg = jax.random.split(key, 4)
        perm = jax.random.permutation(k_sel, Np)
        active = jnp.zeros((Np,)).at[perm[:n_active]].set(1.0)
        keys = jax.random.split(k_cli, Np)
        keys_loc = jax.lax.dynamic_slice_in_dim(keys, d * L, L, 0)
        cp_loc, closs_loc = jax.vmap(upd, in_axes=(None, 0, 0))(
            params, data_loc, keys_loc)
        # sorted gather: updates return to the server in global client
        # order — pure data movement, so the aggregation below is the
        # in-process code running on the in-process stack, verbatim.
        cp = jax.tree.map(
            lambda a: jax.lax.all_gather(a, "clients", axis=0, tiled=True),
            cp_loc)
        closs = jax.lax.all_gather(closs_loc, "clients", axis=0, tiled=True)
        w_loc = jnp.sum(data_loc["w"], axis=-1)
        wts = jax.lax.all_gather(w_loc, "clients", axis=0,
                                 tiled=True) * active
        extras = {}
        if getattr(agg, "needs_prev", False):
            extras["prev"] = params
        if getattr(agg, "needs_staleness", False):
            extras["staleness"] = (jnp.zeros_like(wts) if stal is None
                                   else stal.astype(jnp.float32))
        new_params = agg(cp, wts, k_agg, **extras)
        wn = wts / jnp.maximum(jnp.sum(wts), 1e-12)
        avg_loss = jnp.sum(closs * wn)
        return new_params, avg_loss

    if staleness is None:
        fn = shard_map(lambda p, dt, k: body(p, dt, k, None), mesh,
                       in_specs=(P(), P("clients"), P()),
                       out_specs=(P(), P()))
        return fn(params, data, key)
    fn = shard_map(body, mesh,
                   in_specs=(P(), P("clients"), P(), P()),
                   out_specs=(P(), P()))
    return fn(params, data, key, staleness)


def fedavg(key, data, rcfg: RouterConfig, fcfg: FedConfig, *,
           rounds: Optional[int] = None, optimizer: str = "adamw",
           init=None, full_batch: bool = False, freeze=None, distill=None,
           client_mask=None, dp_sigma: float = 0.0, aggregator=None,
           loss_fn: Optional[Callable] = None, cohort: Optional[int] = None,
           staleness=None, mesh: Optional[Mesh] = None,
           donate_data: bool = False,
           eval_fn: Optional[Callable] = None, eval_every: int = 1):
    """Run T rounds of Algorithm 1. Returns (params, history dict).

    Without ``eval_fn`` the T-round loop is fused into one ``lax.scan`` —
    a single dispatch and one host sync for the whole fit, bit-for-bit
    equal to the per-round loop on the same key. ``eval_fn`` needs params
    on the host, so it falls back to a host loop — per round by default;
    ``eval_every=E > 1`` scans E rounds per eval sync instead (one
    dispatch + one host sync per E rounds — most of the fusion win while
    keeping a round-level loss curve and an every-E eval curve). Params
    and losses stay bit-for-bit identical to the per-round loop; the eval
    list gets one entry per chunk boundary (after rounds E, 2E, ..., T).

    ``aggregator`` selects the server aggregation strategy
    (``repro.fed.aggregators``); None keeps the plain-FedAvg (+ optional
    dp_sigma noise) default. Hashable strategies (the built-in frozen
    dataclasses) ride the module-level compiled-fit caches.

    ``loss_fn`` selects the family's training loss (see ``client_update``);
    module-level functions are hashable, so non-default families ride the
    same compiled-fit caches as the MLP default.

    ``cohort=C`` enables per-round client sampling (see ``fedavg_round``):
    C is part of the compiled-fit cache key, so every cohort draw reuses
    the same compiled scan. ``staleness`` is an optional ``(N,)`` vector
    consumed by aggregators declaring ``needs_staleness``; providing it to
    a strategy that ignores it is an error (silent drops would fake
    async-tolerance).

    ``mesh=Mesh(..., ("clients",))`` runs every round through
    ``fedavg_round_sharded`` — the client slab sharded across devices,
    bit-for-bit the in-process fit on a fixed key (pass the data through
    ``sharding.shard_clients`` to keep the slab distributed end to end).
    The mesh path supports every knob except the pytree-carrying ones
    (freeze/distill/client_mask), which are rejected rather than silently
    replicated. ``donate_data=True`` hands the stacked client slab to the
    fit: once the fit drains, the caller's device buffers are released
    (``is_deleted()`` turns true) instead of living until GC — safe only
    when the caller won't reuse the slab, e.g. a per-sync harvest stack;
    incompatible with ``eval_fn``, whose chunked driver reuses the slab
    across chunks. (A jit donation annotation would be a no-op here: the
    slab is read by every scan round, so XLA can never alias it.)
    """
    rounds = rounds if rounds is not None else fcfg.rounds
    N = data["x"].shape[0]
    if mesh is not None:
        pytree_kw = [n for n, v in (("freeze", freeze), ("distill", distill),
                                    ("client_mask", client_mask))
                     if v is not None]
        if pytree_kw:
            raise ValueError(
                f"the mesh path supports only hashable knobs — "
                f"{', '.join(pytree_kw)} carry pytrees that would pin the "
                "sharded round to one fit; drop mesh= to use the "
                "in-process simulation with those")
        n_dev = mesh.shape["clients"]
        if N % n_dev != 0:
            raise ValueError(
                f"N={N} stacked clients do not divide the {n_dev}-device "
                "clients mesh — pad the stack (pad_client_axis) or resize "
                "the mesh")
        if cohort is not None and cohort < N and cohort % n_dev != 0:
            raise ValueError(
                f"cohort={cohort} does not divide the {n_dev}-device "
                "clients mesh — each device trains cohort/n_dev clients "
                "per round, so pick a multiple")
    if donate_data and eval_fn is not None:
        raise ValueError(
            "donate_data=True with eval_fn: the chunked-eval driver "
            "reuses the client slab across chunks, so it cannot be "
            "donated — drop one of the two")
    if cohort is not None:
        if client_mask is not None:
            raise ValueError(
                "cohort sampling and client_mask are mutually exclusive: "
                "the mask is indexed by the full client axis, the cohort "
                "gather re-indexes it per round")
        cohort = int(cohort)
        if cohort < 1:
            raise ValueError(f"cohort must be >= 1, got {cohort}")
        if cohort >= N:
            cohort = None  # full participation — keep the legacy path
    if staleness is not None:
        # GaussianDP delegates needs_staleness to its inner strategy, so
        # checking the user's aggregator covers the dp_sigma>0 wrap too.
        if not getattr(aggregator, "needs_staleness", False):
            name = type(aggregator).__name__ if aggregator is not None \
                else "default FedAvg"
            raise ValueError(
                f"staleness= was provided but the aggregator ({name}) does "
                "not consume it — use a buffered-async strategy (e.g. "
                "BufferedAsyncAggregator) or drop the argument")
        staleness = jnp.asarray(staleness, jnp.float32)
        if staleness.shape != (N,):
            raise ValueError(
                f"staleness must have shape ({N},) — one entry per stacked "
                f"client — got {staleness.shape}")
    D_max = data["x"].shape[1]
    max_steps = 1 if full_batch else max(
        1, int(np.ceil(D_max / fcfg.batch_size))) * fcfg.local_epochs
    key, k_init = jax.random.split(key)
    params = init if init is not None else R.init_mlp_router(key=k_init,
                                                             cfg=rcfg)
    # Hashable-config fits reuse module-level compiled functions (repeated
    # fits — restarts, sweeps, benchmarks — compile once per config+shape);
    # pytree-carrying knobs (freeze/distill/client_mask) and unhashable
    # custom aggregators build a fresh jit.
    # Keep `simple`/`cfg_key` in sync with _round_partial's signature.
    try:
        hash(aggregator)
        agg_hashable = True
    except TypeError:
        agg_hashable = False
    simple = (freeze is None and distill is None and client_mask is None
              and agg_hashable)
    cfg_key = (rcfg, fcfg, optimizer, max_steps, full_batch, dp_sigma,
               aggregator, loss_fn, cohort, mesh)

    if eval_fn is None:
        if simple:
            fit = _scan_fit_cached(*cfg_key, rounds, init is None)
        else:
            fit = _make_scan_fit(
                _round_partial(*cfg_key, freeze, distill, client_mask),
                rounds, donate=init is None)
        params, _, losses = _call_fit(fit, params, key, data, staleness)
        hist = {"loss": np.asarray(losses).tolist(), "eval": []}
        if donate_data:
            # A jit-level donation annotation can never alias the slab —
            # every scan round reads it, so XLA has no dead window to
            # reuse (the in-process path warns "not usable", shard_map
            # drops the annotation). Honor the contract at the array
            # level instead: np.asarray(losses) above drained the fit,
            # so release the caller's buffers now — not at GC time.
            for a in jax.tree.leaves(data):
                if isinstance(a, jax.Array):
                    a.delete()
        return params, hist

    if eval_every > 1:
        def chunk_fn(E):
            return (_scan_fit_cached(*cfg_key, E, False) if simple
                    else _make_scan_fit(
                _round_partial(*cfg_key, freeze, distill, client_mask),
                E, donate=False))

        return chunked_eval_fit(chunk_fn, params, key, data, rounds,
                                eval_every, eval_fn, staleness=staleness)

    round_jit = (_round_fn_cached(*cfg_key) if simple else
                 jax.jit(_round_partial(*cfg_key, freeze, distill,
                                        client_mask)))
    hist = {"loss": [], "eval": []}
    for t in range(rounds):
        key, k_r = jax.random.split(key)
        if staleness is None:
            params, loss = round_jit(params, data, k_r)
        else:
            params, loss = round_jit(params, data, k_r, staleness=staleness)
        hist["loss"].append(float(loss))
        hist["eval"].append(eval_fn(params))
    return params, hist


def _call_fit(fit, params, key, data, staleness):
    """Invoke a scan fit with/without the optional staleness operand.
    ``staleness is None`` keeps the legacy 3-arg call so fits whose
    ``run`` predates the knob (the sharded mesh path) stay valid."""
    if staleness is None:
        return fit(params, key, data)
    return fit(params, key, data, staleness)


def chunked_eval_fit(chunk_fn, params, key, data, rounds: int,
                     eval_every: int, eval_fn, staleness=None):
    """Drive a fit that scans E rounds between eval syncs: one dispatch +
    one host sync per chunk instead of per round. ``chunk_fn(E)`` returns
    a compiled ``(params, key, data) -> (params, key, losses)`` scan fit
    of E rounds (built at most once per distinct length — E and the
    tail). The scan body splits the key exactly like the per-round loop
    and the carry key threads across chunks, so the trajectory is
    bit-for-bit the per-round loop; history gets every per-round loss and
    one eval entry per chunk boundary. Shared by the in-process and the
    ``shard_map`` mesh paths so their bookkeeping can't diverge. No
    donation: eval_fn may hold onto the params it was handed."""
    hist = {"loss": [], "eval": []}
    chunk_fns = {}
    done = 0
    while done < rounds:
        E = min(eval_every, rounds - done)
        if E not in chunk_fns:
            chunk_fns[E] = chunk_fn(E)
        params, key, losses = _call_fit(chunk_fns[E], params, key, data,
                                        staleness)
        hist["loss"].extend(float(l) for l in np.asarray(losses))
        hist["eval"].append(eval_fn(params))
        done += E
    return params, hist


def _make_scan_fit(round_fn, rounds: int, *, donate: bool = True):
    """Fuse T communication rounds into one ``lax.scan``: per-step key
    handling replicates the per-round loop exactly (split → round), so the
    result is bit-for-bit identical on a fixed key. Params are donated when
    the caller does not hold the initial buffer (fresh init); the client
    slab is deliberately NOT in donate_argnums — every scan round reads
    it, so the annotation can never alias (``fedavg(donate_data=True)``
    releases the caller's buffers after the fit drains instead). Returns
    (params, advanced key, per-round losses) so chunked-eval fits can
    thread the key across chunks. ``staleness`` is an optional extra
    operand; the None default is resolved at trace time, so 3-arg callers
    are bit-for-bit the legacy scan."""
    def run(params, key, data, staleness=None):
        def body(carry, _):
            params, key = carry
            key, k_r = jax.random.split(key)
            if staleness is None:
                params, loss = round_fn(params, data, k_r)
            else:
                params, loss = round_fn(params, data, k_r,
                                        staleness=staleness)
            return (params, key), loss

        (params, key), losses = jax.lax.scan(body, (params, key), None,
                                             length=rounds)
        return params, key, losses

    return jax.jit(run, donate_argnums=(0,) if donate else ())


def _round_partial(rcfg, fcfg, optimizer, max_steps, full_batch, dp_sigma,
                   aggregator, loss_fn=None, cohort=None, mesh=None,
                   freeze=None, distill=None, client_mask=None):
    """The one place a fedavg_round closure is built — every fit path
    (cached or not, in-process or mesh-sharded) goes through it, so a new
    knob can't silently diverge between the variants. ``mesh`` selects the
    ``shard_map`` round; its unsupported pytree knobs were rejected by
    ``fedavg`` before this point."""
    if mesh is not None:
        return functools.partial(
            fedavg_round_sharded, rcfg=rcfg, fcfg=fcfg,
            opt=_make_opt(fcfg, optimizer), max_steps=max_steps, mesh=mesh,
            full_batch=full_batch, dp_sigma=dp_sigma, aggregator=aggregator,
            loss_fn=loss_fn, cohort=cohort)
    return functools.partial(
        fedavg_round, rcfg=rcfg, fcfg=fcfg, opt=_make_opt(fcfg, optimizer),
        max_steps=max_steps, full_batch=full_batch, freeze=freeze,
        distill=distill, client_mask=client_mask, dp_sigma=dp_sigma,
        aggregator=aggregator, loss_fn=loss_fn, cohort=cohort)


@functools.lru_cache(maxsize=64)
def _round_fn_cached(rcfg, fcfg, optimizer, max_steps, full_batch, dp_sigma,
                     aggregator, loss_fn, cohort=None, mesh=None):
    return jax.jit(_round_partial(rcfg, fcfg, optimizer, max_steps,
                                  full_batch, dp_sigma, aggregator, loss_fn,
                                  cohort, mesh))


@functools.lru_cache(maxsize=64)
def _scan_fit_cached(rcfg, fcfg, optimizer, max_steps, full_batch, dp_sigma,
                     aggregator, loss_fn, cohort, mesh, rounds, donate):
    return _make_scan_fit(
        _round_partial(rcfg, fcfg, optimizer, max_steps, full_batch,
                       dp_sigma, aggregator, loss_fn, cohort, mesh),
        rounds, donate=donate)


# ---------------------------------------------------------------------------
# Non-federated baselines (client-local / centralized ERM)
# ---------------------------------------------------------------------------


def sgd_train(key, data_i, rcfg: RouterConfig, fcfg: FedConfig, *,
              steps: int, optimizer: str = "adamw", init=None, freeze=None,
              loss_fn: Optional[Callable] = None):
    """Plain minibatch training on a single (flat) dataset
    {"x": (D,d), "m", "acc", "cost", "w"} — the no-FL baseline.
    ``loss_fn`` selects the family loss (None → MLP, the legacy path)."""
    base_loss = loss_fn if loss_fn is not None else R.router_loss
    opt = _make_opt(fcfg, optimizer)
    key, k_init = jax.random.split(key)
    params = init if init is not None else R.init_mlp_router(key=k_init,
                                                             cfg=rcfg)
    D_i = jnp.sum(data_i["w"]).astype(jnp.int32)
    opt_state = opt.init(params)

    @jax.jit
    def step(carry, _):
        params, opt_state, key = carry
        key, k_idx, k_drop = jax.random.split(key, 3)
        idx = jax.random.randint(k_idx, (fcfg.batch_size,), 0,
                                 jnp.maximum(D_i, 1))
        batch = jax.tree.map(lambda a: jnp.take(a, idx, axis=0), data_i)
        loss, grads = jax.value_and_grad(
            lambda p: base_loss(p, batch, rcfg, rng=k_drop))(params)
        if freeze is not None:
            grads = jax.tree.map(lambda g, f: g * f, grads, freeze)
        new_params, opt_state = opt.update(grads, opt_state, params)
        if freeze is not None:  # gate the whole delta: weight decay too
            new_params = jax.tree.map(
                lambda n, o, f: n * f + o * (1 - f), new_params, params,
                freeze)
        return (new_params, opt_state, key), loss

    (params, _, _), losses = jax.lax.scan(
        step, (params, opt_state, key), None, length=steps)
    return params, losses
