"""The paper's contribution: federated LLM-router training (the math).

  * policy            — utility U_λ, frontier sweep, AUC (§3, §6)
  * mlp_router        — parametric router (§4.1)
  * kmeans / kmeans_router — nonparametric router (§4.2, Alg. 2)
  * federated         — FedAvg simulation (Alg. 1) + local/centralized ERM
  * personalization   — adaptive federated/local mixture (§6.4)
  * expansion         — model & client onboarding (§6.3, App. D.3)

Consumers (benchmarks, examples, serving, launch drivers) should not use
these modules directly: the public surface is ``repro.routers`` — one
``Router`` interface, a string registry, and ``fit_federated``.
"""
from repro.core import (  # noqa: F401
    expansion,
    federated,
    kmeans,
    kmeans_router,
    mlp_router,
    personalization,
    policy,
)
