"""Adaptive personalization (paper §6.4).

Each client holds the federated estimators and its locally trained
estimators; per model m it computes mean-absolute calibration errors on its
own training samples (no extra model calls) and mixes the two routers with
weights inversely proportional to those errors:

  w_a^{(i,m)} = e(A^fed_m) / (e(A^fed_m) + e(A^loc_m))        (local weight)
  A_mix = w_a · A^loc + (1 − w_a) · A^fed          (same for cost with w_c)
"""
from __future__ import annotations

import jax.numpy as jnp


def calibration_errors(predict_fn, data_i, num_models: int):
    """MAE of a router's acc/cost predictions on one client's own logged
    samples, per model. Models never logged locally get error = +inf (the
    mixture then falls back entirely to the other estimator).

    predict_fn(x) → (A (D,M), C (D,M)).
    Returns (e_acc (M,), e_cost (M,)).
    """
    A, C = predict_fn(data_i["x"])
    m = data_i["m"][:, None]
    a_hat = jnp.take_along_axis(A, m, axis=1)[:, 0]
    c_hat = jnp.take_along_axis(C, m, axis=1)[:, 0]
    ae = jnp.abs(a_hat - data_i["acc"]) * data_i["w"]
    ce = jnp.abs(c_hat - data_i["cost"]) * data_i["w"]
    onehot = (jnp.arange(num_models)[None, :] == data_i["m"][:, None])
    onehot = onehot * data_i["w"][:, None]
    n_m = jnp.sum(onehot, axis=0)                       # (M,)
    e_acc = jnp.where(n_m > 0, (ae[:, None] * onehot).sum(0) /
                      jnp.maximum(n_m, 1e-12), jnp.inf)
    e_cost = jnp.where(n_m > 0, (ce[:, None] * onehot).sum(0) /
                       jnp.maximum(n_m, 1e-12), jnp.inf)
    return e_acc, e_cost


def mixture_weights(e_fed, e_loc):
    """Local-estimator weight per model; safe at 0/∞ edge cases."""
    both_inf = jnp.isinf(e_fed) & jnp.isinf(e_loc)
    w = jnp.where(jnp.isinf(e_loc), 0.0,
                  jnp.where(jnp.isinf(e_fed), 1.0,
                            e_fed / jnp.maximum(e_fed + e_loc, 1e-12)))
    return jnp.where(both_inf, 0.0, w)


def personalized_predict(fed_fn, loc_fn, w_a, w_c):
    """Build the mixed predictor (closure over per-model weights)."""
    def predict(x):
        Af, Cf = fed_fn(x)
        Al, Cl = loc_fn(x)
        A = w_a[None, :] * Al + (1.0 - w_a)[None, :] * Af
        C = w_c[None, :] * Cl + (1.0 - w_c)[None, :] * Cf
        return A, C
    return predict


def make_personalized(fed_fn, loc_fn, data_i, num_models: int):
    """End-to-end §6.4: calibrate both routers on the client's training
    samples, return the mixed predictor."""
    ef_a, ef_c = calibration_errors(fed_fn, data_i, num_models)
    el_a, el_c = calibration_errors(loc_fn, data_i, num_models)
    w_a = mixture_weights(ef_a, el_a)
    w_c = mixture_weights(ef_c, el_c)
    return personalized_predict(fed_fn, loc_fn, w_a, w_c), (w_a, w_c)
