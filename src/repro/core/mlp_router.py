"""Parametric MLP-Router (paper §4.1, Appendix C.1).

Shared trunk: two hidden layers (512, 512), each Linear → LayerNorm → GELU →
Dropout(0.1). Per-model heads: one accuracy logit (sigmoid at inference) and
one normalized cost scalar per model, kept as (d_h, M) matrices so onboarding
a model appends a column (§6.3).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import RouterConfig


def init_mlp_router(key, cfg: RouterConfig, num_models: Optional[int] = None) -> dict:
    M = num_models if num_models is not None else cfg.num_models
    dims = (cfg.d_emb,) + tuple(cfg.hidden)
    keys = jax.random.split(key, len(cfg.hidden) + 2)
    trunk = []
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        trunk.append({
            "w": jax.random.normal(keys[i], (din, dout)) * (din ** -0.5),
            "b": jnp.zeros((dout,)),
            "ln_s": jnp.ones((dout,)),
            "ln_b": jnp.zeros((dout,)),
        })
    dh = dims[-1]
    ka, kc = jax.random.split(keys[-1])
    heads = {
        "acc_w": jax.random.normal(ka, (dh, M)) * (dh ** -0.5),
        "acc_b": jnp.zeros((M,)),
        "cost_w": jax.random.normal(kc, (dh, M)) * (dh ** -0.5),
        "cost_b": jnp.zeros((M,)),
    }
    return {"trunk": trunk, "heads": heads}


def trunk_apply(params: dict, x: jnp.ndarray, *, dropout: float = 0.0,
                rng=None) -> jnp.ndarray:
    h = x
    for lyr in params["trunk"]:
        h = h @ lyr["w"] + lyr["b"]
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.var(h, axis=-1, keepdims=True)
        h = (h - mu) * jax.lax.rsqrt(var + 1e-5) * lyr["ln_s"] + lyr["ln_b"]
        h = jax.nn.gelu(h)
        if dropout > 0.0 and rng is not None:
            rng, sub = jax.random.split(rng)
            keep = jax.random.bernoulli(sub, 1.0 - dropout, h.shape)
            h = jnp.where(keep, h / (1.0 - dropout), 0.0)
    return h


def apply_mlp_router(params: dict, x: jnp.ndarray, *, dropout: float = 0.0,
                     rng=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, d_emb) → (A (B, M) in [0,1], C (B, M))."""
    h = trunk_apply(params, x, dropout=dropout, rng=rng)
    hd = params["heads"]
    A = jax.nn.sigmoid(h @ hd["acc_w"] + hd["acc_b"])
    C = h @ hd["cost_w"] + hd["cost_b"]
    return A, C


def router_loss(params: dict, batch: dict, cfg: RouterConfig, *,
                rng=None) -> jnp.ndarray:
    """Paper Eq. 3: MSE on the single logged model per sample.

    batch: {"x": (B,d), "m": (B,), "acc": (B,), "cost": (B,),
            optional "w": (B,) sample weights (0 for padding)}.
    """
    A, C = apply_mlp_router(params, batch["x"], dropout=cfg.dropout, rng=rng)
    m = batch["m"][:, None]
    a_hat = jnp.take_along_axis(A, m, axis=1)[:, 0]
    c_hat = jnp.take_along_axis(C, m, axis=1)[:, 0]
    err = (a_hat - batch["acc"]) ** 2 + (c_hat - batch["cost"]) ** 2
    w = batch.get("w")
    if w is None:
        return jnp.mean(err)
    return jnp.sum(err * w) / jnp.maximum(jnp.sum(w), 1.0)


def add_model_head(params: dict, key) -> dict:
    """§6.3 model onboarding: append a fresh column to each head."""
    hd = params["heads"]
    dh = hd["acc_w"].shape[0]
    ka, kc = jax.random.split(key)
    new = {
        "acc_w": jnp.concatenate(
            [hd["acc_w"], jax.random.normal(ka, (dh, 1)) * dh ** -0.5], axis=1),
        "acc_b": jnp.concatenate([hd["acc_b"], jnp.zeros((1,))]),
        "cost_w": jnp.concatenate(
            [hd["cost_w"], jax.random.normal(kc, (dh, 1)) * dh ** -0.5], axis=1),
        "cost_b": jnp.concatenate([hd["cost_b"], jnp.zeros((1,))]),
    }
    return {"trunk": params["trunk"], "heads": new}
