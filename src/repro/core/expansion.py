"""Pool evolution (paper §6.3 + App. D.3).

Gradient-trained families (MLP, MF):
  * model onboarding — append fresh head/factor columns and train ONLY
    those columns (everything else frozen) on a small calibration subset.
  * client onboarding — continued FedAvg restricted to the new clients with
    a distillation regularizer toward the frozen pre-join router.

One-shot family equivalents are training-free and live beside their math
(kmeans_router.py / elo_router.py: add_model_stats / merge_client_stats).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import FedConfig, RouterConfig
from repro.core import federated as F
from repro.core import mf_router as MF
from repro.core import mlp_router as R


def add_models(params: dict, key, n_new: int, add_fn=None) -> dict:
    add_fn = add_fn if add_fn is not None else R.add_model_head
    for _ in range(n_new):
        key, sub = jax.random.split(key)
        params = add_fn(params, sub)
    return params


def new_head_freeze_mask(params: dict, n_new: int) -> dict:
    """Gradient mask: 1 only on the last n_new head columns. Works for any
    family whose params carry the {"heads": {acc_w, acc_b, cost_w, cost_b}}
    layout (MLP trunk features or MF latent factors alike)."""
    def zeros_like(t):
        return jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), t)

    mask = zeros_like(params)
    M = params["heads"]["acc_b"].shape[0]
    col = (jnp.arange(M) >= M - n_new).astype(jnp.float32)
    mask["heads"] = {
        "acc_w": jnp.broadcast_to(col, params["heads"]["acc_w"].shape),
        "acc_b": col,
        "cost_w": jnp.broadcast_to(col, params["heads"]["cost_w"].shape),
        "cost_b": col,
    }
    return mask


def onboard_models_mlp(key, params, calib_data, rcfg: RouterConfig,
                       fcfg: FedConfig, n_new: int, *, steps: int = 300):
    """§6.3: train only the new columns on the calibration subset.
    calib_data: flat {"x","m","acc","cost","w"} with m indexing the
    EXPANDED pool (new models have indices ≥ M_old)."""
    key, k_add = jax.random.split(key)
    params = add_models(params, k_add, n_new)
    freeze = new_head_freeze_mask(params, n_new)
    params, losses = F.sgd_train(key, calib_data, rcfg, fcfg, steps=steps,
                                 init=params, freeze=freeze)
    return params, losses


def onboard_clients_mlp(key, params, data_new, rcfg: RouterConfig,
                        fcfg: FedConfig, *, rounds: int = 15,
                        beta: float = 1.0):
    """App. D.3: continued training using only newly joined clients, with
    a distillation penalty toward the frozen pre-join parameters."""
    theta0 = jax.tree.map(lambda a: a, params)  # frozen copy
    return F.fedavg(key, data_new, rcfg, fcfg, rounds=rounds, init=params,
                    distill=(theta0, beta))


def onboard_models_mf(key, params, calib_data, rcfg: RouterConfig,
                      fcfg: FedConfig, n_new: int, *, steps: int = 300):
    """§6.3 for the MF family: append fresh factor columns, train only
    those columns on the calibration subset (projection + old factors
    frozen)."""
    key, k_add = jax.random.split(key)
    params = add_models(params, k_add, n_new, add_fn=MF.add_model_factor)
    freeze = new_head_freeze_mask(params, n_new)
    params, losses = F.sgd_train(key, calib_data, rcfg, fcfg, steps=steps,
                                 init=params, freeze=freeze,
                                 loss_fn=MF.mf_loss)
    return params, losses


def onboard_clients_mf(key, params, data_new, rcfg: RouterConfig,
                       fcfg: FedConfig, *, rounds: int = 15,
                       beta: float = 1.0):
    """App. D.3 for the MF family: continued FedAvg on the new clients,
    anchored by distillation toward the frozen pre-join factorization."""
    theta0 = jax.tree.map(lambda a: a, params)  # frozen copy
    return F.fedavg(key, data_new, rcfg, fcfg, rounds=rounds, init=params,
                    distill=(theta0, beta, MF.apply_mf_router),
                    loss_fn=MF.mf_loss)
