"""Parametric matrix-factorization router (RouterBench / RouteLLM style).

Factorizes the sparse (query × model) evaluation matrix: a learned linear
map projects the query embedding into a rank-r latent space, and each model
carries a learned r-dim factor per head, so

    A(x, m) = sigmoid(<phi(x), v_m^acc> + b_m^acc),   phi(x) = x W + b
    C(x, m) =        <phi(x), v_m^cost> + b_m^cost

Compared to the MLP router this is the most direct instantiation of the
paper's non-uniform-coverage setting: every observed (query, model, score)
triple updates one row × one column of the factorization, and models a
client never logged are reached purely through the shared latent space.

The params pytree mirrors the MLP head layout ({"heads": {"acc_w", ...}}),
so head-wise machinery — the fused Pallas utility kernel, the onboarding
freeze mask — applies unchanged with the latent phi(x) in place of the
trunk features.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import RouterConfig


def init_mf_router(key, cfg: RouterConfig,
                   num_models: Optional[int] = None) -> dict:
    M = num_models if num_models is not None else cfg.num_models
    r = cfg.mf_rank
    kq, ka, kc = jax.random.split(key, 3)
    return {
        "proj": {
            "w": jax.random.normal(kq, (cfg.d_emb, r)) * (cfg.d_emb ** -0.5),
            "b": jnp.zeros((r,)),
        },
        "heads": {
            "acc_w": jax.random.normal(ka, (r, M)) * (r ** -0.5),
            "acc_b": jnp.zeros((M,)),
            "cost_w": jax.random.normal(kc, (r, M)) * (r ** -0.5),
            "cost_b": jnp.zeros((M,)),
        },
    }


def factor_apply(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, d_emb) → latent query factors phi(x): (B, r)."""
    return x @ params["proj"]["w"] + params["proj"]["b"]


def apply_mf_router(params: dict, x: jnp.ndarray
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, d_emb) → (A (B, M) in [0,1], C (B, M))."""
    z = factor_apply(params, x)
    hd = params["heads"]
    A = jax.nn.sigmoid(z @ hd["acc_w"] + hd["acc_b"])
    C = z @ hd["cost_w"] + hd["cost_b"]
    return A, C


def mf_loss(params: dict, batch: dict, cfg: RouterConfig, *,
            rng=None) -> jnp.ndarray:
    """Eq. 3 MSE on the single logged model per sample — same contract as
    ``mlp_router.router_loss`` so it plugs straight into the shared FedAvg
    machinery (``rng`` is accepted but unused: the model is deterministic).

    batch: {"x": (B,d), "m": (B,), "acc": (B,), "cost": (B,),
            optional "w": (B,) sample weights (0 for padding)}.
    """
    A, C = apply_mf_router(params, batch["x"])
    m = batch["m"][:, None]
    a_hat = jnp.take_along_axis(A, m, axis=1)[:, 0]
    c_hat = jnp.take_along_axis(C, m, axis=1)[:, 0]
    err = (a_hat - batch["acc"]) ** 2 + (c_hat - batch["cost"]) ** 2
    w = batch.get("w")
    if w is None:
        return jnp.mean(err)
    return jnp.sum(err * w) / jnp.maximum(jnp.sum(w), 1.0)


def add_model_factor(params: dict, key) -> dict:
    """§6.3 model onboarding: append a fresh factor column to each head."""
    hd = params["heads"]
    r = hd["acc_w"].shape[0]
    ka, kc = jax.random.split(key)
    new = {
        "acc_w": jnp.concatenate(
            [hd["acc_w"], jax.random.normal(ka, (r, 1)) * r ** -0.5], axis=1),
        "acc_b": jnp.concatenate([hd["acc_b"], jnp.zeros((1,))]),
        "cost_w": jnp.concatenate(
            [hd["cost_w"], jax.random.normal(kc, (r, 1)) * r ** -0.5], axis=1),
        "cost_b": jnp.concatenate([hd["cost_b"], jnp.zeros((1,))]),
    }
    return {"proj": params["proj"], "heads": new}
