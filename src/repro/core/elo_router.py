"""Similarity-weighted Elo/ranking router — nonparametric, one-shot (Alg. 2).

Anchors come from the same two-stage federated K-means as the
K-Means-Router (local K-means uploads → server size-weighted K-means,
``kmeans_router.fed_centroids``). Each client then uploads, per
(anchor k, model m), similarity-weighted evaluation sums

    n[k,m] = Σ_i s_k(x_i) · w_i · 1[m_i = m]
    a[k,m] = Σ_i s_k(x_i) · w_i · acc_i · 1[m_i = m]
    c[k,m] = Σ_i s_k(x_i) · w_i · cost_i · 1[m_i = m]

where s_k(x) is a softmax similarity kernel over anchors. The sums are
linear in the samples, so server aggregation is plain addition — exactly
the one-shot statistics protocol of Alg. 2, with soft anchor assignment in
place of hard cluster membership.

The server turns shrunk win-rates into Elo-style ratings,

    R[k,m] = s_elo · logit(p̃),  p̃ = (a + n0·p_glob[m]) / (n + n0),

the Bradley–Terry strength model m would need to produce its observed score
against a par opponent near anchor k (n0 pseudo-counts shrink sparse cells
toward the model's global mean — the regularization classic Elo gets from
its update rate). Inference interpolates in *rating space* — a
similarity-weighted mean of per-anchor ratings mapped back through the
logistic link — i.e. geometric rather than arithmetic pooling of
win-rates, which is what makes this a ranking router instead of a soft
mean-value table.

State θ = {"anchors" (K,d), "rating" (K,M), "C" (K,M), raw sums
"a"/"c"/"n" (K,M), "tau" ()}. Raw sums are kept so onboarding merges stay
exact; "tau" rides in the state so a checkpoint is self-describing.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import RouterConfig
from repro.core.kmeans import kmeans
from repro.core.kmeans_router import fed_centroids

# classic Elo logistic scale: 400 rating points per decade of odds
ELO_SCALE = 400.0 / math.log(10.0)
_P_CLIP = 1e-3


def _tau(rcfg: RouterConfig) -> float:
    """Kernel bandwidth. Squared distances between unit-scale embeddings
    grow linearly with d, so the config knob is in units of sqrt(d_emb)."""
    return rcfg.elo_tau * math.sqrt(rcfg.d_emb)


def kernel_weights(x: jnp.ndarray, anchors: jnp.ndarray,
                   tau) -> jnp.ndarray:
    """Softmax similarity kernel s_k(x) over anchors: (Q, d) → (Q, K)."""
    d2 = (jnp.sum(x * x, -1)[:, None] +
          jnp.sum(anchors * anchors, -1)[None, :] - 2.0 * x @ anchors.T)
    return jax.nn.softmax(-d2 / (2.0 * tau * tau), axis=-1)


def _anchor_stats(anchors, data_i, M: int, tau):
    """Similarity-weighted sums per (anchor, model) for one client —
    linear in the samples, hence one-shot aggregable (Alg. 2 lines 9–12)."""
    s = kernel_weights(data_i["x"], anchors, tau)        # (D, K)
    sw = s * data_i["w"][:, None]                        # (D, K)
    onehot = jax.nn.one_hot(data_i["m"], M)              # (D, M)
    n = jnp.einsum("dk,dm->km", sw, onehot)
    a = jnp.einsum("dk,dm->km", sw * data_i["acc"][:, None], onehot)
    c = jnp.einsum("dk,dm->km", sw * data_i["cost"][:, None], onehot)
    return a, c, n


def _finalize(a_sum, c_sum, n, rcfg: RouterConfig):
    """Aggregate sums → per-anchor ratings + cost estimates, with
    pseudo-count shrinkage toward each model's global mean (a model never
    observed anywhere backs off to the pessimistic (acc 0, cost c_max))."""
    n0 = max(rcfg.elo_prior, 1e-6)
    tot_n = jnp.sum(n, axis=0)                           # (M,)
    p_glob = jnp.where(tot_n > 0,
                       jnp.sum(a_sum, 0) / jnp.maximum(tot_n, 1e-12), 0.0)
    c_glob = jnp.where(tot_n > 0,
                       jnp.sum(c_sum, 0) / jnp.maximum(tot_n, 1e-12),
                       rcfg.c_max)
    p = (a_sum + n0 * p_glob[None, :]) / (n + n0)
    p = jnp.clip(p, _P_CLIP, 1.0 - _P_CLIP)
    rating = ELO_SCALE * (jnp.log(p) - jnp.log1p(-p))
    C = (c_sum + n0 * c_glob[None, :]) / (n + n0)
    return rating, C


def _build_state(anchors, a, c, n, rcfg: RouterConfig) -> dict:
    rating, C = _finalize(a, c, n, rcfg)
    return {"anchors": anchors, "rating": rating, "C": C,
            "a": a, "c": c, "n": n, "tau": jnp.asarray(_tau(rcfg))}


def fed_elo_router(key, data, rcfg: RouterConfig, *, num_models=None,
                   client_mask=None) -> dict:
    """One-shot federated fit. data: stacked padded client arrays
    (see federated.py)."""
    M = num_models if num_models is not None else rcfg.num_models
    anchors = fed_centroids(key, data, rcfg, client_mask=client_mask)
    tau = _tau(rcfg)
    a, c, n = jax.vmap(lambda di: _anchor_stats(anchors, di, M, tau))(data)
    if client_mask is not None:
        m3 = client_mask[:, None, None]
        a, c, n = a * m3, c * m3, n * m3
    return _build_state(anchors, jnp.sum(a, 0), jnp.sum(c, 0),
                        jnp.sum(n, 0), rcfg)


def local_elo_router(key, data_i, rcfg: RouterConfig, *, num_models=None,
                     k=None) -> dict:
    """Client-local (no-FL) baseline: own K-means anchors + own ratings."""
    M = num_models if num_models is not None else rcfg.num_models
    K = k if k is not None else rcfg.k_local
    anchors, _ = kmeans(key, data_i["x"], K, iters=rcfg.kmeans_iters,
                        n_init=rcfg.n_init, mask=data_i["w"] > 0)
    a, c, n = _anchor_stats(anchors, data_i, M, _tau(rcfg))
    return _build_state(anchors, a, c, n, rcfg)


def predict(router: dict, x: jnp.ndarray):
    """x: (Q, d) → (A (Q,M) in [0,1], C (Q,M)): similarity-weighted rating
    interpolation, mapped back through the logistic link."""
    s = kernel_weights(x, router["anchors"], router["tau"])  # (Q, K)
    A = jax.nn.sigmoid((s @ router["rating"]) / ELO_SCALE)
    return A, s @ router["C"]


def prior_state(key, rcfg: RouterConfig, *, num_models=None) -> dict:
    """An uninformative cold-start state: random anchors, near-flat
    ratings, mid-scale costs, zero counts. Shapes match any fitted state
    with the same (k_global, num_models), so a live service can hot-swap a
    real fit in without retracing.

    The ratings carry a small per-(anchor, model) jitter (±~10 Elo points,
    A within 0.5 ± 0.01): an exactly flat prior would tie every utility
    argmax and route ALL cold-start traffic to model 0, so the harvest
    would never cover the rest of the pool and refits could never learn it
    — the same role the random output heads play for the parametric
    families' cold starts."""
    M = num_models if num_models is not None else rcfg.num_models
    K = rcfg.k_global
    ka, kr, kc = jax.random.split(key, 3)
    anchors = jax.random.normal(ka, (K, rcfg.d_emb))
    z = jnp.zeros((K, M))
    rating = 10.0 * jax.random.normal(kr, (K, M))
    C = jnp.clip(rcfg.c_max / 2.0 *
                 (1.0 + 0.05 * jax.random.normal(kc, (K, M))),
                 0.0, rcfg.c_max)
    return {"anchors": anchors, "rating": rating, "C": C,
            "a": z, "c": z, "n": z, "tau": jnp.asarray(_tau(rcfg))}


# ---------------------------------------------------------------------------
# §6.3 model onboarding / App. D.3 client onboarding (training-free)
# ---------------------------------------------------------------------------


def add_model_stats(router: dict, calib, rcfg: RouterConfig) -> dict:
    """Onboard one new model from calibration evaluations
    calib = {"x": (D,d), "acc": (D,), "cost": (D,), "w": (D,)}: append its
    similarity-weighted sums as a new column and re-finalize the ratings."""
    s = kernel_weights(calib["x"], router["anchors"], router["tau"])
    sw = s * calib["w"][:, None]                         # (D, K)
    n_new = jnp.sum(sw, axis=0)                          # (K,)
    a_new = jnp.sum(sw * calib["acc"][:, None], axis=0)
    c_new = jnp.sum(sw * calib["cost"][:, None], axis=0)
    a = jnp.concatenate([router["a"], a_new[:, None]], axis=1)
    c = jnp.concatenate([router["c"], c_new[:, None]], axis=1)
    n = jnp.concatenate([router["n"], n_new[:, None]], axis=1)
    return _build_state(router["anchors"], a, c, n, rcfg)


def merge_client_stats(router: dict, data_new, rcfg: RouterConfig,
                       num_models=None) -> dict:
    """New clients join (App. D.3): add their similarity-weighted sums
    against the *existing* anchors — exact, because the state keeps raw
    sums rather than only the finalized ratings."""
    M = num_models if num_models is not None else rcfg.num_models
    a, c, n = jax.vmap(lambda di: _anchor_stats(router["anchors"], di, M,
                                                router["tau"]))(data_new)
    return _build_state(router["anchors"], router["a"] + jnp.sum(a, 0),
                        router["c"] + jnp.sum(c, 0),
                        router["n"] + jnp.sum(n, 0), rcfg)
