"""Lloyd's K-means (paper Alg. 2 building block) in JAX.

Supports per-point weights (server-side weighted K-means over client
centroids) and validity masks (padded per-client datasets under vmap).
Assignment uses the shared distance/argmin op (Pallas kernel on TPU,
jnp oracle elsewhere) from ``repro.kernels.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def _plusplus_init(key, X, w, K):
    """k-means++ style seeding (weighted).

    Tracks the running min squared distance incrementally: each step only
    computes distances to the one newly added center — O(n·d) per step
    instead of the O(n·K·d) full-table broadcast (min over centers is
    exact, so the fold is bit-for-bit the same argmin)."""
    n = X.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.choice(k0, n, p=w / jnp.sum(w))
    cents = jnp.zeros((K, X.shape[1]), X.dtype).at[0].set(X[first])
    d2min = jnp.sum((X - X[first][None, :]) ** 2, -1)

    def body(i, carry):
        cents, d2min, key = carry
        p = d2min * w
        p = jnp.where(jnp.isfinite(p), p, 0.0)
        p = p / jnp.maximum(jnp.sum(p), 1e-12)
        key, sub = jax.random.split(key)
        nxt = jax.random.choice(sub, n, p=p)
        d2min = jnp.minimum(d2min, jnp.sum((X - X[nxt][None, :]) ** 2, -1))
        return cents.at[i].set(X[nxt]), d2min, key

    cents, _, _ = jax.lax.fori_loop(1, K, body, (cents, d2min, key))
    return cents


@functools.partial(jax.jit, static_argnames=("K", "iters"))
def _lloyd_once(key, X, w, K: int, iters: int):
    cents = _plusplus_init(key, X, w, K)

    def step(cents, _):
        # fused assign-reduce: argmin + weighted per-cluster sums/counts in
        # one pass (Pallas kernel on TPU, jnp oracle elsewhere)
        _, sums, cnts = kops.kmeans_assign_reduce(X, cents, w)
        new = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts, 1e-12)[:, None],
                        cents)  # keep empty clusters in place
        return new.astype(cents.dtype), None  # f32 sums; keep carry dtype

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    assign = kops.kmeans_assign(X, cents)
    d2 = jnp.sum((X - cents[assign]) ** 2, axis=-1)
    inertia = jnp.sum(d2 * w)
    return cents, inertia


def kmeans(key, X: jnp.ndarray, K: int, *, iters: int = 30, n_init: int = 3,
           weights=None, mask=None):
    """Weighted Lloyd K-means with n_init restarts.

    X: (n, d); weights: (n,) or None; mask: (n,) bool — masked-out points get
    zero weight (padded rows). Returns (centroids (K,d), inertia).
    """
    n = X.shape[0]
    w = jnp.ones((n,)) if weights is None else jnp.asarray(weights, jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    keys = jax.random.split(key, n_init)
    cents, inertias = jax.vmap(lambda k: _lloyd_once(k, X, w, K, iters))(keys)
    best = jnp.argmin(inertias)
    return cents[best], inertias[best]
