"""Lloyd's K-means (paper Alg. 2 building block) in JAX.

Supports per-point weights (server-side weighted K-means over client
centroids) and validity masks (padded per-client datasets under vmap).
Assignment uses the shared distance/argmin op (Pallas kernel on TPU,
jnp oracle elsewhere) from ``repro.kernels.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def _plusplus_init(key, X, w, K):
    """k-means++ style seeding (weighted)."""
    n = X.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.choice(k0, n, p=w / jnp.sum(w))
    cents = jnp.zeros((K, X.shape[1]), X.dtype).at[0].set(X[first])

    def body(i, carry):
        cents, key = carry
        d2 = jnp.min(
            jnp.sum((X[:, None, :] - cents[None, :, :]) ** 2, -1)
            + jnp.where(jnp.arange(K)[None, :] < i, 0.0, jnp.inf), axis=1)
        p = d2 * w
        p = jnp.where(jnp.isfinite(p), p, 0.0)
        p = p / jnp.maximum(jnp.sum(p), 1e-12)
        key, sub = jax.random.split(key)
        nxt = jax.random.choice(sub, n, p=p)
        return cents.at[i].set(X[nxt]), key

    cents, _ = jax.lax.fori_loop(1, K, body, (cents, key))
    return cents


@functools.partial(jax.jit, static_argnames=("K", "iters"))
def _lloyd_once(key, X, w, K: int, iters: int):
    cents = _plusplus_init(key, X, w, K)

    def step(cents, _):
        assign = kops.kmeans_assign(X, cents)               # (n,)
        onehot = jax.nn.one_hot(assign, K, dtype=X.dtype)   # (n, K)
        wv = onehot * w[:, None]
        sums = wv.T @ X                                     # (K, d)
        cnts = jnp.sum(wv, axis=0)                          # (K,)
        new = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts, 1e-12)[:, None],
                        cents)  # keep empty clusters in place
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    assign = kops.kmeans_assign(X, cents)
    d2 = jnp.sum((X - cents[assign]) ** 2, axis=-1)
    inertia = jnp.sum(d2 * w)
    return cents, inertia


def kmeans(key, X: jnp.ndarray, K: int, *, iters: int = 30, n_init: int = 3,
           weights=None, mask=None):
    """Weighted Lloyd K-means with n_init restarts.

    X: (n, d); weights: (n,) or None; mask: (n,) bool — masked-out points get
    zero weight (padded rows). Returns (centroids (K,d), inertia).
    """
    n = X.shape[0]
    w = jnp.ones((n,)) if weights is None else jnp.asarray(weights, jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    keys = jax.random.split(key, n_init)
    cents, inertias = jax.vmap(lambda k: _lloyd_once(k, X, w, K, iters))(keys)
    best = jnp.argmin(inertias)
    return cents[best], inertias[best]
