"""Routing policy: utility U_λ(x,m) = A(x,m) − λ·C(x,m)  (paper Eq. 1/4).

Also the evaluation protocol of §6: accuracy–cost frontiers swept over a log
grid of λ and the normalized area-under-curve (AUC) summary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def utility(A: jnp.ndarray, C: jnp.ndarray, lam: float) -> jnp.ndarray:
    """A, C: (..., M) estimated accuracy / cost → utility (..., M)."""
    return A - lam * C


def route(A: jnp.ndarray, C: jnp.ndarray, lam) -> jnp.ndarray:
    """argmax_m U_λ — returns chosen model indices (...,)."""
    return jnp.argmax(utility(A, C, lam), axis=-1)


def lambda_grid(num: int = 100, lo: float = 1e-2, hi: float = 1e7) -> np.ndarray:
    """Paper Appendix C: log grid λ ∈ [1e-2, 1e7], 100 points."""
    return np.logspace(np.log10(lo), np.log10(hi), num)


def frontier(A_est: jnp.ndarray, C_est: jnp.ndarray,
             acc_true: jnp.ndarray, cost_true: jnp.ndarray,
             lams=None) -> tuple[np.ndarray, np.ndarray]:
    """Sweep λ; route with *estimates*, score with *true* tables.

    A_est, C_est, acc_true, cost_true: (Q, M). Returns (costs, accs) arrays
    over the λ grid (mean over test queries).
    """
    lams = lambda_grid() if lams is None else lams
    lams_j = jnp.asarray(np.asarray(lams))

    def one(lam):
        m = route(A_est, C_est, lam)  # (Q,)
        acc = jnp.take_along_axis(acc_true, m[:, None], axis=1)[:, 0]
        cost = jnp.take_along_axis(cost_true, m[:, None], axis=1)[:, 0]
        return jnp.mean(cost), jnp.mean(acc)

    costs, accs = jax.vmap(one)(lams_j)
    return np.asarray(costs), np.asarray(accs)


def frontier_auc(costs: np.ndarray, accs: np.ndarray) -> float:
    """Normalized AUC: integrate the *upper envelope* of accuracy as a
    function of cost, divided by the observed cost range (paper §6)."""
    costs = np.asarray(costs, dtype=np.float64)
    accs = np.asarray(accs, dtype=np.float64)
    order = np.argsort(costs)
    c, a = costs[order], accs[order]
    # Upper envelope: running max (a rational operator never does worse by
    # spending more — mirrors how the paper's monotone frontiers look).
    a = np.maximum.accumulate(a)
    # collapse duplicate costs to their best accuracy
    uc, idx = np.unique(c, return_index=True)
    ua = np.maximum.reduceat(a, idx)
    if len(uc) < 2:
        return float(ua[-1])
    area = np.trapezoid(ua, uc)
    return float(area / (uc[-1] - uc[0]))


def eval_router(predict_fn, x_test, acc_true, cost_true, lams=None):
    """predict_fn(x) → (A_est, C_est) each (Q, M). Returns (costs, accs, auc)."""
    A_est, C_est = predict_fn(x_test)
    costs, accs = frontier(A_est, C_est, acc_true, cost_true, lams)
    return costs, accs, frontier_auc(costs, accs)
