"""Pytree checkpointing via msgpack (no orbax offline).

Arrays are serialized as (dtype, shape, raw bytes); the tree structure is
encoded as nested msgpack maps/lists. Atomic write (tmp + rename) so a
killed trainer never leaves a torn checkpoint. bfloat16 round-trips via a
uint16 view.
"""
from __future__ import annotations

import os
import pathlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_ARR = "__arr__"


def _pack(obj: Any):
    if isinstance(obj, (jax.Array, np.ndarray)):
        a = np.asarray(obj)
        dt = str(a.dtype)
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
            dt = "bfloat16"
        return {_ARR: True, "dtype": dt, "shape": list(a.shape),
                "data": a.tobytes()}
    if isinstance(obj, dict):
        return {k: _pack(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return {"__list__": [_pack(v) for v in obj],
                "__tuple__": isinstance(obj, tuple)}
    return obj


def _unpack(obj: Any):
    if isinstance(obj, dict):
        if obj.get(_ARR):
            dt = obj["dtype"]
            if dt == "bfloat16":
                a = np.frombuffer(obj["data"], np.uint16).reshape(obj["shape"])
                return jnp.asarray(a.view(jnp.bfloat16))
            return jnp.asarray(
                np.frombuffer(obj["data"], np.dtype(dt)).reshape(obj["shape"]))
        if "__list__" in obj:
            vals = [_unpack(v) for v in obj["__list__"]]
            return tuple(vals) if obj.get("__tuple__") else vals
        return {k: _unpack(v) for k, v in obj.items()}
    return obj


def save(path: str | pathlib.Path, tree: Any) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_pack(tree), use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str | pathlib.Path) -> Any:
    with open(path, "rb") as f:
        return _unpack(msgpack.unpackb(f.read(), raw=False,
                                       strict_map_key=False))
