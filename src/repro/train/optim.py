"""Optimizers (own implementation — no optax in this environment).

AdamW with decoupled weight decay + global-norm clipping, and plain SGD
(used by the FedAvg-equivalence theory tests, matching paper Alg. 1).
State and update are pytree-shaped like the params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(z, params), jax.tree.map(z, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mu, g: b1 * mu + (1 - b1) * g.astype(jnp.float32),
                         state.m, grads)
        v = jax.tree.map(lambda nu, g: b2 * nu + (1 - b2) * jnp.square(
            g.astype(jnp.float32)), state.v, grads)
        t = step.astype(jnp.float32)
        mhat_scale = 1.0 / (1 - b1 ** t)
        vhat_scale = 1.0 / (1 - b2 ** t)

        def upd(p, mu, nu):
            u = (mu * mhat_scale) / (jnp.sqrt(nu * vhat_scale) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float | Callable = 1e-2
    clip_norm: Optional[float] = None

    def init(self, params):
        return jnp.zeros((), jnp.int32)

    def update(self, grads, state, params):
        step = state + 1
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        lr = self.lr(step) if callable(self.lr) else self.lr
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, step


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr
