"""Synthetic LM token pipeline for end-to-end training examples.

Markov-chain token stream with per-document transition structure: the model
has real statistical signal to learn (loss decreases measurably within a few
hundred steps on a ~100M model), unlike iid-uniform tokens. Batches are
(tokens, labels) with next-token alignment.
"""
from __future__ import annotations

import numpy as np


class MarkovLM:
    def __init__(self, vocab: int, order_states: int = 64, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.n_states = order_states
        # sparse-ish row-stochastic transition over latent states
        logits = rng.standard_normal((order_states, order_states)) * 2.0
        self.trans = np.exp(logits) / np.exp(logits).sum(1, keepdims=True)
        # each latent state emits a skewed distribution over a vocab slice
        emit = rng.standard_normal((order_states, vocab)) * 2.5
        self.emit = np.exp(emit) / np.exp(emit).sum(1, keepdims=True)
        self.emit_cdf = np.cumsum(self.emit, axis=1)
        self.trans_cdf = np.cumsum(self.trans, axis=1)
        self.rng = rng

    def sample(self, batch: int, seq: int) -> np.ndarray:
        """Vectorized inverse-CDF sampling of the latent-state chain."""
        s = self.rng.integers(0, self.n_states, size=batch)
        out = np.zeros((batch, seq + 1), np.int32)
        for t in range(seq + 1):
            u = self.rng.random((batch, 1))
            out[:, t] = (self.emit_cdf[s] < u).sum(axis=1)
            u2 = self.rng.random((batch, 1))
            s = (self.trans_cdf[s] < u2).sum(axis=1)
        return np.clip(out, 0, self.vocab - 1)

    def batches(self, batch: int, seq: int):
        while True:
            toks = self.sample(batch, seq)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
