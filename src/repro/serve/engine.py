"""Continuous-batching serving engine with a persistent paged KV pool.

The deployment shape the paper targets (§3) is a router in front of a
model pool serving *many clients concurrently*. The per-request gateway
path serves one caller's batch at a time and pad-copies a fresh KV cache
per request; this engine instead keeps, per routed model, one persistent
cache pool and decodes every in-flight request together:

  admission  — ``submit()`` queues a request; when capacity frees up it is
               prefilled in its pow2 length bucket and its K/V written
               into the pool (buffers donated — no copy). Same-bucket
               admissions **coalesce** into one (B_b, S_b) prefill
               dispatch (per-row ``last_pos``) instead of B separate
               (1, S_b) calls — one trace per (B_b, S_b), and bursty
               arrivals pay one dispatch instead of a convoy.
  decode     — ``step()`` runs ONE cached jitted ``lax.scan`` chunk of
               ``chunk`` greedy tokens over the whole decode batch. Each
               row carries its own position (a per-row ``pos`` vector),
               so requests at different depths share the batch; per-row
               validity (``pos + 1``) masks anything an earlier occupant
               left behind. New requests join between chunks instead of
               waiting for the batch to drain.
  completion — a request that has emitted ``max_new`` tokens frees its
               capacity at the next chunk boundary — steady-state decode
               never reallocates.

KV memory comes in two regimes (``EngineConfig.page_size``):

* **paged** (default, vLLM-style — see ``kv_cache.alloc_page_pool``): one
  flat pool of fixed-size pages shared by every request. A request
  reserves only the pages its own prompt + decode budget needs (its page
  table row maps logical blocks → pool pages; decode gathers by page
  table — ``models.decode_step_paged``, Pallas scalar-prefetch kernel on
  TPU, jnp gather on CPU). Long and short requests share the pool with no
  per-slot worst-case reservation: strictly more in-flight requests per
  byte of KV pool under long-tail length mixes.
* **uniform** (``page_size=None`` — the PR 3 engine, kept as baseline and
  for benchmarks): every slot reserves a full ``max_seq`` region.

Every jitted function is built once per (model config, static shape) and
cached at module level; warm traffic compiles nothing (appends to
``TRACE_LOG`` are per jit *trace*, and tests pin them flat — including
paged decode across mixed per-request page counts, whose shapes are
static ``(slots, max_pages)``).

Greedy decode is prefix-stable, so a request's tokens are bit-identical
to the single-request scan path (``RoutedServer.generate(engine=False)``
on that prompt alone) — test-enforced in tests/test_engine.py and
property-tested over random schedules in tests/test_engine_properties.py.
The parity guarantee is verified on the jnp paths (CPU/interpret); the
TPU Pallas decode kernels now share the jnp path's dtype discipline
(cache-dtype dots, f32 accumulation — kernels/decode_attention.py), and
token equality across the dispatch boundary is pinned in
tests/test_kernels.py on both f32 and bf16 caches; confirming on real
hardware remains a ROADMAP item (online-softmax normalization order still
differs from the one-shot softmax, values agree to tolerance).

**Speculative decode** (``EngineConfig.spec_k > 0``): each round a cheap
drafter — per request, router-chosen through the gateway or pinned via
``submit(draft=)`` / ``EngineConfig.draft`` — decodes ``spec_k`` tokens
ahead in its own slot pool, the target verifies the window in ONE
multi-position dispatch, and the longest matching prefix commits (plus
the verify's correction token on a mismatch). Rollback is free: ``pos``
simply doesn't advance past the accepted point, and write-before-validity
masks the stale suffix. Emitted tokens stay bit-identical to the
non-speculative engine (greedy verify at every position — test-pinned),
and acceptance variation is data, never shape: zero decode retraces
(``_draft_fn``/``_verify_fn``/``_verify_paged_fn`` cache like every other
engine jit). Counters: ``spec_rounds`` / ``spec_drafted`` /
``spec_accepted`` / ``spec_rejected``.

SSM/hybrid archs integrate state over every prefill position and cannot
share right-padded prompt buckets; they stay on the gateway's per-request
path (``RoutedServer.generate`` falls back automatically).

Overload resilience (PR 8): requests carry an optional **deadline**
(engine steps) and can be **cancelled**; both release their slot and
pages immediately between chunks — pure host bookkeeping, the decode
program never retraces. Paged lanes with ``reserve="initial"`` claim only
the prefill bucket's pages at admission and **grow on demand** each chunk;
under page pressure the engine **preempts** the lowest-priority victim
(latest deadline first, then fewest tokens generated), releases its pages,
and re-queues it as a prefill of prompt + tokens-so-far — greedy decode is
prefix-stable, so the resumed request's tokens are bit-identical to its
never-preempted twin (test-pinned). A bounded admission queue
(``queue_cap`` / per-model ``lane_quotas``) **sheds** excess load instead
of queuing without bound. Every request ends in exactly one typed terminal
status — ``DONE`` / ``PREEMPTED-resumed`` / ``EXPIRED`` / ``CANCELLED`` /
``SHED`` — surfaced through ``step()``/``drain()``/``status()``, and the
counters (``sheds``, ``preemptions``, ``expiries``, ``cancels``,
``resume_recompute_toks``, ``queue_depth_hw``) are exact accounting for
the chaos bench (``benchmarks/perf_suite.bench_preempt``).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import time
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.sharding as shd
from repro.config import ModelConfig
from repro.models import model as mdl
from repro.serve.kv_cache import (PageTable, alloc_draft_pool,
                                  alloc_page_pool, alloc_slot_pool,
                                  write_prefill_pages, write_slot)

#: one entry appended per jit TRACE of an engine/serve function (including
#: the gateway's route program — hot-swapped router state must enter it as
#: a traced argument, never a retrace) — bounded so a long-running server
#: can't leak memory; tests assert its length stays flat after warmup and
#: across router hot-swaps. gateway.py re-exports this same object.
TRACE_LOG: Deque[tuple] = collections.deque(maxlen=4096)


def reset_trace_log() -> None:
    """Explicitly clear the retrace log (long-running servers)."""
    TRACE_LOG.clear()


def next_pow2(v: int) -> int:
    return 1 << (max(v, 1) - 1).bit_length()


def region_len(n_tokens: int, max_new: int, chunk: int) -> int:
    """Positions a request writes over its lifetime: the pow2 prefill
    bucket or prompt + whole decode chunks, whichever is larger. Module
    level so tests/benchmarks size page pools with the engine's own math
    instead of re-deriving it."""
    steps = -(-max_new // chunk) * chunk
    return max(next_pow2(n_tokens), n_tokens + steps)


#: typed terminal statuses. A completed request (DONE, or PREEMPTED-resumed
#: when it survived >= 1 preemption) surfaces its np token array directly —
#: result-consuming callers written against the PR 3 engine never change.
#: The non-completion terminals (EXPIRED / CANCELLED / SHED) surface an
#: ``Outcome`` record carrying any partial tokens.
DONE = "DONE"
PREEMPTED_RESUMED = "PREEMPTED-resumed"
EXPIRED = "EXPIRED"
CANCELLED = "CANCELLED"
SHED = "SHED"
TERMINAL_STATUSES = (DONE, PREEMPTED_RESUMED, EXPIRED, CANCELLED, SHED)

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class Outcome:
    """Terminal record for a request that did NOT complete: ``status`` is
    EXPIRED / CANCELLED / SHED and ``tokens`` holds whatever it emitted
    before termination (None if nothing was). Surfaced as the request's
    result through ``step()``/``drain()`` in place of the token array."""
    rid: int
    status: str
    tokens: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine shape — one compiled program set per value of this."""
    slots: int = 8     #: concurrent sequences per model (decode batch rows)
    max_seq: int = 256  #: max per-request region: prompt bucket + decode room
    chunk: int = 8     #: decode tokens per jitted chunk (admission period)
    done_buffer: int = 1024  #: finished results kept for drain(); oldest
    #: evicted beyond this, so step()-consuming servers don't leak
    page_size: Optional[int] = 16  #: paged KV pool page length (positions);
    #: None selects the uniform slot pool (every slot reserves max_seq)
    pages: int = 0  #: allocatable pages in the pool; 0 → auto
    #: (slots * ceil(max_seq / page_size) — worst-case-equivalent, so
    #: admission is never page-bound; set lower to trade reservation
    #: headroom for strictly more in-flight requests per byte)
    reserve: str = "lifetime"  #: paged reservation policy. "lifetime"
    #: claims every page a request can ever write at admission (the PR 4
    #: engine — admission stalls on pool exhaustion, never preempts).
    #: "initial" claims only the prefill bucket's pages and grows on
    #: demand at chunk boundaries; under page pressure the engine preempts
    #: the lowest-priority victim (latest deadline first, then fewest
    #: tokens generated) and re-queues it as a prefill of
    #: prompt + tokens-so-far (recompute-on-resume, bit-identical tokens)
    queue_cap: Optional[int] = None  #: bounded admission queue per lane;
    #: a submit past the cap SHEDs per ``shed_policy`` instead of queuing
    #: without bound. None = unbounded (seed behavior)
    shed_policy: str = "reject-newest"  #: which request a full lane queue
    #: sheds: "reject-newest" (the incoming one) or "reject-latest-deadline"
    #: (the queued request best able to afford it — the incoming one only
    #: if its own effective deadline is latest)
    lane_quotas: Tuple[Tuple[int, int], ...] = ()  #: per-model queue-cap
    #: overrides as (model_idx, cap) pairs, so one overloaded pool model
    #: sheds its own excess instead of starving the other lanes
    spec_k: int = 0  #: speculative decode: tokens drafted ahead per round.
    #: 0 disables (seed behavior — ``step()`` decodes ``chunk``-token
    #: scans). > 0 replaces each lane's decode chunk with a draft/verify
    #: ROUND: the request's drafter decodes ``spec_k`` tokens ahead
    #: (a cheap sequential scan on the draft model), the target verifies
    #: all ``spec_k + 1`` positions in ONE batched dispatch, the greedy-
    #: matching prefix commits (plus the verify's own next token), and the
    #: rejected suffix rolls back by resetting the slot's ``pos`` — tokens
    #: stay bit-identical to the non-speculative engine (greedy verify),
    #: between 1 and spec_k + 1 of them per row per round
    draft: Optional[int] = None  #: default drafter (model pool index) for
    #: requests that don't pass ``submit(..., draft=)``. None → each
    #: request drafts with its own target model (degenerate k-step
    #: lookahead, full acceptance). The gateway overrides per request from
    #: the router's utility ranking (cheapest model the router still
    #: rates — see RoutedServer)

    @property
    def resolved_pages(self) -> int:
        """Allocatable pages (excluding the trash page)."""
        if not self.page_size:
            return 0
        return self.pages or self.slots * (-(-self.max_seq // self.page_size))


# ---------------------------------------------------------------------------
# Cached jitted stages (module level — never rebuilt per request)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _prefill_fn(cfg: ModelConfig):
    """Prefill one prompt bucket → (first greedy token (B,), KV cache).
    Identical math to the gateway scan path's prefill segment (same
    q_chunk, same last_pos unembed), so engine tokens stay bit-identical
    to the single-request path. ``last_pos`` may be a scalar (uniform
    lanes admit one request at a time) or a (B,) vector (coalesced paged
    admission: same-bucket requests of different true lengths batched into
    one dispatch, each row unembedded at its own last position)."""
    def prefill(params, toks, last_pos):
        TRACE_LOG.append(("engine_prefill", cfg.name, toks.shape))
        logits, _, cache = mdl.forward(params, cfg, tokens=toks,
                                       logits_last_only=True,
                                       last_pos=last_pos,
                                       return_cache=True, q_chunk=64)
        tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return tok0, cache
    return jax.jit(prefill)


@functools.lru_cache(maxsize=None)
def _admit_fn(cfg: ModelConfig):
    """Write a prefill cache into one pool slot. The pool argument is
    donated: admission mutates the persistent buffers in place instead of
    copying the whole pool per request."""
    def admit(pool, prefill_cache, slot):
        TRACE_LOG.append(("engine_admit", cfg.name,
                          jax.tree.leaves(prefill_cache)[0].shape))
        return write_slot(pool, prefill_cache, slot)
    return jax.jit(admit, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _write_pages_fn(cfg: ModelConfig):
    """Scatter a coalesced prefill cache into the paged pool. The pool
    argument is donated: admission mutates the persistent page buffers in
    place instead of copying the pool per batch. One trace per
    (B_b, S_b, n_pp) admission shape."""
    def write(pool, prefill_cache, pages_mat):
        TRACE_LOG.append(("engine_write_pages", cfg.name,
                          jax.tree.leaves(prefill_cache)[0].shape,
                          pages_mat.shape))
        return write_prefill_pages(pool, prefill_cache, pages_mat)
    return jax.jit(write, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _chunk_paged_fn(cfg: ModelConfig, chunk: int):
    """One decode chunk over the paged decode batch: ``chunk`` greedy
    tokens via ``lax.scan`` with per-row positions and the (slots,
    max_pages) page table. The table's shape is static, so mixed
    per-request page counts never retrace; the pool is donated —
    steady-state decode reuses the page buffers."""
    def run(params, cache, page_table, tok, pos):
        TRACE_LOG.append(("engine_chunk_paged", cfg.name, tok.shape,
                          page_table.shape, chunk))

        def body(carry, _):
            tok, pos, cache = carry
            logits, cache = mdl.decode_step_paged(
                params, cache, cfg, tokens=tok[:, None],
                page_table=page_table, pos=pos)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return (nxt, pos + 1, cache), tok

        (tok, pos, cache), out = jax.lax.scan(body, (tok, pos, cache), None,
                                              length=chunk)
        return cache, tok, pos, out.T                     # out: (B, chunk)
    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _chunk_fn(cfg: ModelConfig, chunk: int):
    """One decode chunk over the whole slot batch: ``chunk`` greedy tokens
    via ``lax.scan`` with a per-slot position vector. Emits the token fed
    at each step (same emission order as the gateway scan), the slot
    cache (donated — steady-state decode reuses the pool buffers), and the
    advanced (tok, pos) carry."""
    def run(params, cache, tok, pos):
        TRACE_LOG.append(("engine_chunk", cfg.name, tok.shape, chunk))

        def body(carry, _):
            tok, pos, cache = carry
            logits, cache = mdl.decode_step(params, cache, cfg,
                                            tokens=tok[:, None], pos=pos)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return (nxt, pos + 1, cache), tok

        (tok, pos, cache), out = jax.lax.scan(body, (tok, pos, cache), None,
                                              length=chunk)
        return cache, tok, pos, out.T                     # out: (B, chunk)
    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _draft_fn(cfg: ModelConfig, k: int):
    """Draft ``k`` tokens ahead on the draft model's slot pool: a cheap
    sequential greedy scan (same body as ``_chunk_fn``) that RETURNS the
    generated tokens instead of the fed ones — the drafted window the
    target's verify step will judge. One trace per (draft config, k);
    the draft pool is donated like every steady-state cache."""
    def run(params, cache, tok, pos):
        TRACE_LOG.append(("engine_draft", cfg.name, tok.shape, k))

        def body(carry, _):
            tok, pos, cache = carry
            logits, cache = mdl.decode_step(params, cache, cfg,
                                            tokens=tok[:, None], pos=pos)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return (nxt, pos + 1, cache), nxt

        (tok, pos, cache), drafted = jax.lax.scan(body, (tok, pos, cache),
                                                  None, length=k)
        return cache, drafted.T                           # (B, k)
    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _verify_fn(cfg: ModelConfig, T: int):
    """Verify ``T = spec_k + 1`` positions per row in ONE dispatch on the
    uniform slot pool (``mdl.decode_verify``): returns the greedy token at
    every position — position j's argmax is exactly what the sequential
    chain would emit after the first j drafted tokens, so the host-side
    accept loop just compares it against the draft. One trace per
    (model config, T); the pool is donated."""
    def run(params, cache, tok, pos):
        TRACE_LOG.append(("engine_verify", cfg.name, tok.shape))
        logits, cache = mdl.decode_verify(params, cache, cfg,
                                          tokens=tok, pos=pos)
        return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _verify_paged_fn(cfg: ModelConfig, T: int):
    """Paged twin of ``_verify_fn`` (``mdl.decode_verify_paged``): the
    (slots, max_pages) table shape is static, so mixed per-request page
    counts never retrace — same guarantee as ``_chunk_paged_fn``."""
    def run(params, cache, page_table, tok, pos):
        TRACE_LOG.append(("engine_verify_paged", cfg.name, tok.shape,
                          page_table.shape))
        logits, cache = mdl.decode_verify_paged(params, cache, cfg,
                                                tokens=tok,
                                                page_table=page_table,
                                                pos=pos)
        return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.jit(run, donate_argnums=(1,))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


def _empty_toks() -> np.ndarray:
    return np.zeros((0,), np.int32)


@dataclasses.dataclass
class _Active:
    rid: int
    max_new: int               # TOTAL decode budget (prefix included)
    toks: np.ndarray = dataclasses.field(default_factory=_empty_toks)
    #: original prompt — kept so preemption can re-queue the request
    deadline: Optional[int] = None   # absolute engine-step bound
    t_submit: float = 0.0
    prefix: np.ndarray = dataclasses.field(default_factory=_empty_toks)
    #: tokens emitted before the last preemption (this tenure re-prefilled
    #: prompt + prefix; ``chunks`` holds only the current tenure)
    chunks: List[np.ndarray] = dataclasses.field(default_factory=list)
    #: only COMMITTED tokens ever enter ``chunks`` — a speculative round
    #: appends its accepted prefix after verification, never raw drafts —
    #: so ``_partial_tokens`` stays an exact solo prefix under
    #: cancel/expire/preempt even mid-draft-window
    emitted: int = 0           # total emitted, prefix included
    preempts: int = 0
    draft: int = -1            # drafter pool index (spec mode; -1 = unset)
    region: int = 0            # commit-bound write extent: len(prompt) +
    #: max_new — speculative page growth is clamped here (write-ahead past
    #: it scatters to the trash page and must not claim pages)


@dataclasses.dataclass
class _Pending:
    rid: int
    toks: np.ndarray           # (S,) int32 prompt tokens, unpadded
    max_new: int
    t_submit: float = 0.0      # perf_counter at submit (admission latency)
    deadline: Optional[int] = None   # absolute engine-step bound
    prefix: np.ndarray = dataclasses.field(default_factory=_empty_toks)
    #: tokens already emitted before a preemption — admission prefills
    #: prompt + prefix (recompute-on-resume)
    preempts: int = 0
    draft: int = -1            # drafter pool index (spec mode; -1 = unset)

    def eff_deadline(self) -> float:
        return _INF if self.deadline is None else float(self.deadline)


class _Lane:
    """Per-model engine state: the KV pool (paged or uniform) + host-side
    slot/page bookkeeping."""

    def __init__(self, pm, ecfg: EngineConfig, mesh=None):
        self.pm = pm
        self.ecfg = ecfg
        self.mesh = mesh
        self.paged = bool(ecfg.page_size)
        if self.paged:
            self.pool = alloc_page_pool(pm.cfg, ecfg.resolved_pages,
                                        ecfg.page_size)
            self.pt = PageTable(ecfg.slots, ecfg.resolved_pages,
                                ecfg.page_size, ecfg.max_seq)
        else:
            self.pool = alloc_slot_pool(pm.cfg, ecfg.slots, ecfg.max_seq)
            self.pt = None
        #: the params handle the decode stages feed the jitted programs —
        #: replicated over the mesh when one is live (so every pool-sharded
        #: dispatch is one mesh program), the model's own buffers otherwise
        self.params = pm.params
        if mesh is not None:
            self.pool = shd.shard_kv_pool(self.pool, mesh)
            self.params = shd.replicate(pm.params, mesh)
        self.free: List[int] = list(range(ecfg.slots))[::-1]
        self.active: Dict[int, _Active] = {}             # slot -> request
        self.queue: Deque[_Pending] = collections.deque()
        self.tok = np.zeros((ecfg.slots,), np.int32)     # next token to feed
        self.pos = np.zeros((ecfg.slots,), np.int32)     # its write position
        #: speculative mode: drafter pool index → the drafter's own slot
        #: pool (uniform, with spec_k write-ahead headroom — see
        #: kv_cache.alloc_draft_pool), allocated lazily on first use and
        #: kept for the lane's lifetime. Row s mirrors slot s; rows whose
        #: request drafts with a different model hold garbage until the
        #: draft prefill of their next matching occupant overwrites them
        #: (write-before-validity, same invariant as the target pool).
        self.draft_pools: Dict[int, object] = {}
        #: drafter pool index → its params handle (replicated on a mesh),
        #: filled alongside draft_pools
        self.draft_params: Dict[int, object] = {}


class ServeEngine:
    """Admission queue + slot pools over a model pool (attention archs).

    ``submit`` enqueues, ``step`` admits + decodes one chunk per lane,
    ``drain`` steps until idle and returns {request id: np tokens}.
    """

    def __init__(self, pool: List, ecfg: Optional[EngineConfig] = None, *,
                 mesh=None):
        self.ecfg = ecfg or EngineConfig()
        #: cross-silo mesh execution (repro.sharding): with a live Mesh the
        #: per-lane KV pools are placed via ``shard_kv_pool`` (slot dim over
        #: "data" — slot-parallel decode, bit-identical tokens; Hkv dim over
        #: "heads" — tensor-parallel attention), params replicate, and every
        #: jitted stage traces under ``ENGINE_RULES`` so the attention
        #: code's logical-axis annotations bind to mesh axes. Host-side
        #: bookkeeping (slots, page tables, queues) is untouched, so the
        #: zero-retrace guarantees carry over verbatim.
        self.mesh = mesh
        if mesh is not None and not any(a in mesh.shape
                                        for a in ("data", "heads")):
            raise ValueError(
                f"ServeEngine mesh carries axes {tuple(mesh.shape)} — the "
                "engine shards over \"data\" (slot-parallel) and/or "
                "\"heads\" (tensor-parallel); build one with "
                "sharding.data_mesh()/head_mesh()/make_mesh()")
        if self.ecfg.reserve not in ("lifetime", "initial"):
            raise ValueError(f"EngineConfig.reserve={self.ecfg.reserve!r}: "
                             "expected 'lifetime' or 'initial'")
        if self.ecfg.reserve == "initial" and not self.ecfg.page_size:
            raise ValueError("reserve='initial' is a paged-pool feature — "
                             "uniform slot lanes reserve max_seq per slot "
                             "by construction (set page_size)")
        if self.ecfg.shed_policy not in ("reject-newest",
                                         "reject-latest-deadline"):
            raise ValueError(
                f"EngineConfig.shed_policy={self.ecfg.shed_policy!r}: "
                "expected 'reject-newest' or 'reject-latest-deadline'")
        if self.ecfg.spec_k < 0:
            raise ValueError(f"EngineConfig.spec_k={self.ecfg.spec_k}: "
                             "the drafted window cannot be negative")
        if self.ecfg.draft is not None:
            if self.ecfg.spec_k == 0:
                raise ValueError("EngineConfig.draft without spec_k > 0: "
                                 "a drafter only exists in speculative mode")
            if not 0 <= int(self.ecfg.draft) < len(pool):
                raise ValueError(
                    f"EngineConfig.draft={self.ecfg.draft}: not a model "
                    f"pool index (pool has {len(pool)} models)")
        self.pool = pool
        self._lanes: Dict[int, _Lane] = {}
        self._next_rid = 0
        self._done: Dict[int, np.ndarray] = {}
        self._lane_caps = dict(self.ecfg.lane_quotas)
        self._steps = 0              #: step() calls so far — the deadline
        #: clock (submit(deadline=d) expires after d further steps)
        self._status: Dict[int, str] = {}   # rid → terminal status, bounded
        #: terminal records produced since the last step()/drain() flush —
        #: cancel()/shed/expiry land here so their typed results surface
        #: through the same channel as completions
        self._events: List[Tuple[int, object]] = []
        #: resilience counters — exact accounting, threaded into FedLoop
        #: sync history and BENCH_preempt.json. Reset by assigning 0.
        self.sheds = 0
        self.preemptions = 0
        self.expiries = 0
        self.cancels = 0
        #: prompt+prefix positions re-prefilled by preemption resumes (the
        #: recompute cost preemption pays for its page elasticity)
        self.resume_recompute_toks = 0
        self.queue_depth_hw = 0      #: queue-depth high-water across lanes
        #: speculative-decode accounting (exact, host-side): rounds run,
        #: tokens drafted (spec_k per active row per round), drafted tokens
        #: accepted by verify, and drafted tokens rejected-and-recomputed
        #: (the rollback cost — each rejected draft burned draft-model work
        #: and a verify position that re-decodes next round). Acceptance
        #: rate = spec_accepted / spec_drafted.
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_rejected = 0
        #: queue-wait per admitted request (submit → prefill dispatched),
        #: seconds; bounded like TRACE_LOG so long-running servers don't
        #: leak. benchmarks/perf_suite.bench_paged reads the p99.
        self.admission_lat: Deque[float] = collections.deque(maxlen=65536)
        #: high-water mark of concurrently admitted requests, sampled at
        #: every chunk boundary between admission and decode (completions
        #: release capacity before step() returns, so callers can't see
        #: it). Reset by assigning 0; bench_paged's in-flight-per-byte
        #: numerator.
        self.peak_active: int = 0

    def _rules(self):
        """Logical-axis rules context for the jitted stages: on a mesh the
        attention code's ``constrain`` annotations bind to the engine axes
        at trace time (rules naming axes the mesh doesn't carry replicate);
        solo it's a no-op, so the stage programs are unchanged."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return shd.use_rules(self.mesh, shd.ENGINE_RULES)

    def _region_len(self, n_tokens: int, max_new: int) -> int:
        return region_len(n_tokens, max_new, self.ecfg.chunk)

    def _region_cap(self, n_tokens: int, max_new: int) -> int:
        """Worst-case region a request may ever need. Lifetime reservation:
        its own ``region_len``. Initial reservation additionally covers the
        worst RESUME point — a request preempted after k emitted tokens
        re-prefills n_tokens + k in ITS pow2 bucket, and the largest k at
        which a resume can still happen is the last chunk boundary before
        max_new. Admitting only requests whose worst resume bucket fits
        guarantees every preempted request stays resumable and a lone
        request always completes (no preemption livelock)."""
        region = self._region_len(n_tokens, max_new)
        if self.ecfg.page_size and self.ecfg.reserve == "initial":
            chunk = self.ecfg.chunk
            k_max = (-(-max_new // chunk) - 1) * chunk
            region = max(region, next_pow2(n_tokens + k_max))
        return region

    def fits(self, n_tokens: int, max_new: int) -> bool:
        """Whether a request can ever be admitted: its written region must
        stay inside ``max_seq`` (the page-table width on paged lanes, the
        slot region on uniform ones), and on paged lanes its page count
        must not exceed the whole pool. Under ``reserve="initial"`` the
        region also covers the worst resume-point prefill bucket (see
        ``_region_cap``) — slightly stricter, so preempted requests are
        always resumable."""
        region = self._region_cap(n_tokens, max_new)
        if region > self.ecfg.max_seq:
            return False
        if self.ecfg.page_size:
            need = -(-region // self.ecfg.page_size)
            return need <= self.ecfg.resolved_pages
        return True

    def kv_pool_bytes(self) -> int:
        """Bytes held by every lane's persistent KV pool (paged pools
        include the trash page)."""
        return sum(leaf.nbytes for lane in self._lanes.values()
                   for leaf in jax.tree.leaves(lane.pool))

    def n_active(self) -> int:
        """Requests currently holding decode capacity (all lanes)."""
        return sum(len(lane.active) for lane in self._lanes.values())

    def _resolve_draft(self, model_idx: int, draft, pm) -> int:
        """Pick and validate a request's drafter (spec mode): the explicit
        ``submit(draft=)`` override, else ``EngineConfig.draft``, else the
        target itself (degenerate lookahead — always correct, never
        faster). The drafter must share the target's token space and be an
        attention arch (its cache rolls back positionally)."""
        d = int(draft if draft is not None
                else (self.ecfg.draft if self.ecfg.draft is not None
                      else model_idx))
        if not 0 <= d < len(self.pool):
            raise ValueError(f"draft={d}: not a model pool index "
                             f"(pool has {len(self.pool)} models)")
        dcfg = self.pool[d].cfg
        if dcfg.arch_type in ("ssm", "hybrid"):
            raise TypeError(f"{dcfg.name}: SSM/hybrid drafters cannot roll "
                            "back a rejected suffix (state is not "
                            "positional) — pick an attention drafter")
        if dcfg.vocab != pm.cfg.vocab:
            raise ValueError(
                f"drafter {dcfg.name} (vocab {dcfg.vocab}) and target "
                f"{pm.cfg.name} (vocab {pm.cfg.vocab}) don't share a token "
                "space — drafted tokens would be meaningless to verify")
        return d

    # ------------------------------------------------------------- submit
    def submit(self, model_idx: int, toks: np.ndarray, max_new: int, *,
               deadline: Optional[int] = None,
               draft: Optional[int] = None) -> int:
        """Enqueue a request; returns its rid. ``deadline`` bounds its
        lifetime in engine steps: after that many further ``step()`` calls
        an unfinished request EXPIREs (slot and pages released between
        chunks, partial tokens surfaced in its ``Outcome``). None = never.
        A full lane queue (``queue_cap`` / ``lane_quotas``) SHEDs per
        ``shed_policy`` — the shed request's rid still comes back here and
        its typed ``Outcome`` surfaces through the next step()/drain().
        ``draft`` (speculative mode only) picks this request's drafter by
        model pool index, overriding ``EngineConfig.draft``; the gateway
        passes the router's utility-ranked choice here."""
        pm = self.pool[int(model_idx)]
        if pm.cfg.arch_type in ("ssm", "hybrid"):
            raise TypeError(
                f"{pm.cfg.name}: SSM/hybrid archs integrate state over pad "
                "positions and can't share right-padded slot buckets — use "
                "RoutedServer.generate (it falls back per request)")
        toks = np.asarray(toks, np.int32).reshape(-1)
        if not self.fits(len(toks), max_new):
            raise ValueError(
                f"prompt ({len(toks)} tokens, pow2 bucket "
                f"{next_pow2(len(toks))}) + whole decode chunks for "
                f"max_new={max_new} exceed the per-request region "
                f"max_seq={self.ecfg.max_seq}"
                + (f" or the page pool ({self.ecfg.resolved_pages} pages of "
                   f"{self.ecfg.page_size})" if self.ecfg.page_size else "")
                + " — raise EngineConfig.max_seq/pages or shorten the "
                "request (RoutedServer.generate falls back to the per-call "
                "path automatically)")
        if deadline is not None and int(deadline) < 1:
            raise ValueError(f"deadline={deadline}: a request needs at "
                             "least one engine step to make progress")
        if self.ecfg.spec_k > 0:
            draft_idx = self._resolve_draft(int(model_idx), draft, pm)
        elif draft is not None:
            raise ValueError("submit(draft=...) needs EngineConfig.spec_k "
                             "> 0 — the non-speculative engine has no "
                             "drafter")
        else:
            draft_idx = -1
        rid = self._next_rid
        self._next_rid += 1
        lane = self._lanes.get(int(model_idx))
        if lane is None:
            lane = self._lanes[int(model_idx)] = _Lane(pm, self.ecfg,
                                                       self.mesh)
        pend = _Pending(rid, toks, max_new, t_submit=time.perf_counter(),
                        deadline=(self._steps + int(deadline)
                                  if deadline is not None else None),
                        draft=draft_idx)
        cap = self._lane_caps.get(int(model_idx), self.ecfg.queue_cap)
        if cap is not None and len(lane.queue) >= cap:
            victim = pend
            if self.ecfg.shed_policy == "reject-latest-deadline":
                # shed whichever of queue ∪ {incoming} can best afford it:
                # latest effective deadline, newest rid on ties — so the
                # incoming request sheds only when ITS priority is lowest
                qv = max(lane.queue, key=lambda q: (q.eff_deadline(), q.rid))
                if ((qv.eff_deadline(), qv.rid)
                        > (pend.eff_deadline(), pend.rid)):
                    lane.queue.remove(qv)
                    lane.queue.append(pend)
                    victim = qv
            self.sheds += 1
            self._record(victim.rid, SHED,
                         tokens=(victim.prefix.copy()
                                 if len(victim.prefix) else None))
        else:
            lane.queue.append(pend)
        depth = sum(len(l.queue) for l in self._lanes.values())
        self.queue_depth_hw = max(self.queue_depth_hw, depth)
        return rid

    # ---------------------------------------------------------- lifecycle
    def _record(self, rid: int, status: str, tokens=None) -> None:
        """Write a request's single terminal record: its result payload
        (np tokens for completions, a typed Outcome otherwise) lands in the
        step()-return event buffer and the drain() buffer, its status in
        the bounded status map."""
        payload = (tokens if status in (DONE, PREEMPTED_RESUMED)
                   else Outcome(rid, status, tokens))
        self._events.append((rid, payload))
        self._done[rid] = payload
        self._status[rid] = status
        while len(self._status) > 4 * self.ecfg.done_buffer:
            self._status.pop(next(iter(self._status)))

    @staticmethod
    def _partial_tokens(st: _Active) -> Optional[np.ndarray]:
        parts = ([st.prefix] if len(st.prefix) else []) + st.chunks
        if not parts or st.emitted == 0:
            return None
        return np.concatenate(parts)[:st.emitted]

    def _release_slot(self, lane: _Lane, slot: int) -> None:
        """Free a slot's capacity between chunks: slot to the free list,
        pages to the page free list, carry zeroed. Pure host bookkeeping —
        the decode program's shapes don't change, so no retrace."""
        del lane.active[slot]
        lane.free.append(slot)
        if lane.paged:
            lane.pt.release(slot)
        lane.tok[slot] = 0
        lane.pos[slot] = 0

    def cancel(self, rid: int) -> str:
        """Cancel a request wherever it is: queued/preempted requests
        leave the queue; an active one releases its slot and pages at this
        chunk boundary (no decode retrace). Already-terminal rids are a
        no-op returning their existing status; unknown rids raise KeyError.
        The CANCELLED record (with any partial tokens) surfaces through
        the next ``step()``/``drain()``."""
        if rid in self._status:
            return self._status[rid]
        for lane in self._lanes.values():
            for q in lane.queue:
                if q.rid == rid:
                    lane.queue.remove(q)
                    self.cancels += 1
                    self._record(rid, CANCELLED,
                                 tokens=(q.prefix.copy()
                                         if len(q.prefix) else None))
                    return CANCELLED
            for slot, st in list(lane.active.items()):
                if st.rid == rid:
                    toks = self._partial_tokens(st)
                    self._release_slot(lane, slot)
                    self.cancels += 1
                    self._record(rid, CANCELLED, tokens=toks)
                    return CANCELLED
        raise KeyError(f"unknown request id {rid}")

    def status(self, rid: int) -> str:
        """Typed lifecycle status: one of the terminal statuses once the
        request ended, else "ACTIVE" (holding a slot), "PREEMPTED"
        (evicted, queued for recompute-resume) or "QUEUED". KeyError for a
        rid the engine never saw (or whose terminal record aged out of the
        bounded status buffer)."""
        if rid in self._status:
            return self._status[rid]
        for lane in self._lanes.values():
            for st in lane.active.values():
                if st.rid == rid:
                    return "ACTIVE"
            for q in lane.queue:
                if q.rid == rid:
                    return "PREEMPTED" if q.preempts else "QUEUED"
        raise KeyError(f"unknown request id {rid} (never submitted, or its "
                       "terminal record aged out of the status buffer)")

    def counters(self) -> Dict[str, int]:
        """Snapshot of the resilience counters (threaded into FedLoop sync
        history and the chaos bench)."""
        return {"sheds": self.sheds, "preemptions": self.preemptions,
                "expiries": self.expiries, "cancels": self.cancels,
                "resume_recompute_toks": self.resume_recompute_toks,
                "queue_depth_hw": self.queue_depth_hw,
                "peak_active": self.peak_active,
                "spec_rounds": self.spec_rounds,
                "spec_drafted": self.spec_drafted,
                "spec_accepted": self.spec_accepted,
                "spec_rejected": self.spec_rejected}

    def _expire(self, lane: _Lane) -> None:
        """EXPIRE every request (active or queued) whose deadline has
        passed — slot and pages release immediately, partial tokens ride
        in the Outcome."""
        now = self._steps
        for slot, st in sorted(lane.active.items()):
            if st.deadline is not None and now >= st.deadline:
                toks = self._partial_tokens(st)
                self._release_slot(lane, slot)
                self.expiries += 1
                self._record(st.rid, EXPIRED, tokens=toks)
        if any(q.deadline is not None and now >= q.deadline
               for q in lane.queue):
            keep: Deque[_Pending] = collections.deque()
            for q in lane.queue:
                if q.deadline is not None and now >= q.deadline:
                    self.expiries += 1
                    self._record(q.rid, EXPIRED,
                                 tokens=(q.prefix.copy()
                                         if len(q.prefix) else None))
                else:
                    keep.append(q)
            lane.queue = keep

    # --------------------------------------------------------------- step
    def step(self) -> List[Tuple[int, object]]:
        """Expire, admit (preempting under page pressure in "initial"
        mode), grow page reservations, then decode one chunk on every busy
        lane. Returns every request that reached a TERMINAL state this
        step as (rid, result): completions (DONE / PREEMPTED-resumed)
        carry their np token array, EXPIRED/CANCELLED/SHED carry a typed
        ``Outcome``. Results are also buffered for ``drain()`` — up to
        ``EngineConfig.done_buffer`` of them, oldest evicted first, so a
        server that consumes step()'s return value and never drains can
        run forever without growing memory."""
        for lane in self._lanes.values():
            self._expire(lane)
        for lane in self._lanes.values():
            self._admit(lane)
        self.peak_active = max(self.peak_active, self.n_active())
        for lane in self._lanes.values():
            if lane.active and lane.paged and self.ecfg.reserve == "initial":
                self._grow_for_chunk(lane)
            if lane.active:
                if self.ecfg.spec_k:
                    self._decode_spec_round(lane)
                else:
                    self._decode_chunk(lane)
        self._steps += 1
        finished = self._events
        self._events = []
        while len(self._done) > self.ecfg.done_buffer:
            self._done.pop(next(iter(self._done)))
        return finished

    @property
    def busy(self) -> bool:
        return any(l.queue or l.active for l in self._lanes.values())

    def drain(self, rids=None) -> Dict[int, object]:
        """Step until completion and return {rid: result} — np tokens for
        completed requests, a typed ``Outcome`` for expired / cancelled /
        shed ones. With rids=None, runs until every lane is idle and
        returns (and clears) everything; with an iterable of request ids,
        runs until exactly those reach a terminal state and leaves other
        results in place (so interleaved ``submit`` streams keep their
        results). A wanted rid that already terminated — cancelled,
        expired, shed — returns its typed record instead of hanging or
        KeyError-ing; only a rid the engine has no record of raises
        KeyError."""
        if rids is None:
            # capture from step() returns as requests finish — like the
            # rids branch below, immune to done-buffer eviction when more
            # than done_buffer requests are in flight
            out = dict(self._done)
            while self.busy:
                out.update(self.step())
            out.update(self._done)
            self._done = {}
            self._events = []
            return out
        want = set(rids)
        # collect straight from step() results (not only the _done buffer,
        # whose oldest entries step() may evict) — a wanted rid is captured
        # the moment it finishes, so any batch size is safe
        out = {r: self._done.pop(r) for r in want if r in self._done}
        # a terminal rid whose payload was evicted from the done buffer
        # still resolves through the status map (tokens lost to eviction)
        for r in want - out.keys():
            if r in self._status and self._status[r] not in (
                    DONE, PREEMPTED_RESUMED):
                out[r] = Outcome(r, self._status[r])
        self._events = [(r, p) for r, p in self._events if r not in out]
        while want - out.keys():
            if not self.busy:
                raise KeyError(f"unknown request ids: "
                               f"{sorted(want - out.keys())}")
            for rid, payload in self.step():
                if rid in want:
                    out[rid] = payload
                    self._done.pop(rid, None)
        return out

    # ------------------------------------------------------------ internals
    @staticmethod
    def _full_prompt(req: _Pending) -> np.ndarray:
        """The token sequence admission actually prefills: the original
        prompt, plus — after a preemption — every token the request had
        already emitted (recompute-on-resume; greedy decode's prefix
        stability makes the continuation bit-identical)."""
        if len(req.prefix):
            return np.concatenate([req.toks, req.prefix])
        return req.toks

    def _activate(self, req: _Pending, S: int) -> _Active:
        if req.preempts:
            self.resume_recompute_toks += S
        return _Active(req.rid, req.max_new, toks=req.toks,
                       deadline=req.deadline, t_submit=req.t_submit,
                       prefix=req.prefix, emitted=len(req.prefix),
                       preempts=req.preempts, draft=req.draft,
                       region=len(req.toks) + req.max_new)

    def _pick_victim(self, lane: _Lane,
                     before: Optional[float] = None) -> Optional[int]:
        """The eviction policy: latest effective deadline first (None →
        +inf), then fewest tokens generated (least recompute thrown away),
        then the youngest rid — deterministic. With ``before`` set
        (admission preemption) only a victim whose deadline is STRICTLY
        later qualifies: a deadline burst displaces lower-priority work
        but never equal-or-higher-priority work, and deadline-less traffic
        never triggers admission preemption at all. Returns the victim's
        slot, or None."""
        best_key, best_slot = None, None
        for slot, st in sorted(lane.active.items()):
            dl = _INF if st.deadline is None else float(st.deadline)
            if before is not None and not dl > before:
                continue
            key = (dl, -st.emitted, st.rid)
            if best_key is None or key > best_key:
                best_key, best_slot = key, slot
        return best_slot

    def _preempt(self, lane: _Lane, slot: int) -> None:
        """Evict one in-flight request: pages back to the free list, slot
        freed, request re-queued (queue back) as a prefill of
        prompt + tokens-so-far. Host bookkeeping only — no decode-program
        retrace (TRACE_LOG-pinned)."""
        st = lane.active[slot]
        prefix = self._partial_tokens(st)
        self._release_slot(lane, slot)
        self.preemptions += 1
        lane.queue.append(_Pending(
            st.rid, st.toks, st.max_new, t_submit=st.t_submit,
            deadline=st.deadline,
            prefix=(np.asarray(prefix, np.int32) if prefix is not None
                    else _empty_toks()),
            preempts=st.preempts + 1, draft=st.draft))

    def _grow_for_chunk(self, lane: _Lane) -> None:
        """Initial-reservation lanes, right before a decode chunk: every
        active slot's page table must cover its next writes — [pos,
        pos + chunk) for the plain scan, [pos, pos + spec_k) for a
        speculative round, clamped to the request's commit-bound region
        (write-ahead past it scatters into the trash page by design and
        must not claim pages that could never hold a committed position).
        Grow reservations on demand; under pool pressure preempt victims
        (``_pick_victim`` policy) until the survivors fit. ``fits()``'s
        resumable-region bound guarantees a lone request always covers
        itself, so this terminates with at least zero active slots and
        never deadlocks."""
        ps = self.ecfg.page_size
        span = self.ecfg.spec_k or self.ecfg.chunk
        while lane.active:
            need: Dict[int, int] = {}
            for slot in sorted(lane.active):
                hi = int(lane.pos[slot]) + span
                if self.ecfg.spec_k:
                    hi = min(hi, lane.active[slot].region)
                want = -(-hi // ps)
                short = want - lane.pt.held(slot)
                if short > 0:
                    need[slot] = short
            if sum(need.values()) <= lane.pt.available:
                for slot, n in sorted(need.items()):
                    lane.pt.grow(slot, n)
                return
            self._preempt(lane, self._pick_victim(lane))

    def _admit_draft(self, lane: _Lane, slot: int, draft_idx: int,
                     full: np.ndarray) -> None:
        """Speculative admission sidecar: prefill the request's prompt
        through its DRAFTER and write the K/V into the drafter's slot pool
        (lazily allocated per lane — uniform, spec_k headroom past the
        target region so sequential drafting never clamps at the edge).
        The draft's own first-token output is discarded: drafting always
        starts from the target-committed ``lane.tok``."""
        dpm = self.pool[draft_idx]
        if draft_idx not in lane.draft_pools:
            dpool = alloc_draft_pool(dpm.cfg, self.ecfg.slots,
                                     self.ecfg.max_seq, self.ecfg.spec_k)
            if self.mesh is not None:
                dpool = shd.shard_kv_pool(dpool, self.mesh)
                lane.draft_params[draft_idx] = shd.replicate(dpm.params,
                                                             self.mesh)
            else:
                lane.draft_params[draft_idx] = dpm.params
            lane.draft_pools[draft_idx] = dpool
        S = len(full)
        S_b = next_pow2(S)
        toks_p = np.zeros((1, S_b), np.int32)
        toks_p[0, :S] = full
        with self._rules():
            _, kv = _prefill_fn(dpm.cfg)(lane.draft_params[draft_idx],
                                         jnp.asarray(toks_p),
                                         jnp.int32(S - 1))
            lane.draft_pools[draft_idx] = _admit_fn(dpm.cfg)(
                lane.draft_pools[draft_idx], kv, jnp.int32(slot))

    def _admit(self, lane: _Lane) -> None:
        if lane.paged:
            self._admit_paged(lane)
            return
        cfg = lane.pm.cfg
        while lane.free and lane.queue:
            req = lane.queue.popleft()
            slot = lane.free.pop()
            full = self._full_prompt(req)
            S = len(full)
            S_b = next_pow2(S)
            toks_p = np.zeros((1, S_b), np.int32)
            toks_p[0, :S] = full
            with self._rules():
                tok0, kv = _prefill_fn(cfg)(lane.params,
                                            jnp.asarray(toks_p),
                                            jnp.int32(S - 1))
                lane.pool = _admit_fn(cfg)(lane.pool, kv, jnp.int32(slot))
            if self.ecfg.spec_k:
                self._admit_draft(lane, slot, req.draft, full)
            self.admission_lat.append(time.perf_counter() - req.t_submit)
            lane.tok[slot] = int(tok0[0])
            lane.pos[slot] = S          # first decode token writes K/V at S
            lane.active[slot] = self._activate(req, S)

    def _admit_paged(self, lane: _Lane) -> None:
        """Paged admission: claim a decode slot + pages (FIFO — the head
        waits for pages rather than being overtaken), then COALESCE
        everything admitted this boundary by prompt bucket: one (B_b, S_b)
        prefill dispatch per bucket with per-row ``last_pos``, one donated
        page scatter. Pad rows of a non-pow2 group prefill garbage into
        the trash page. Lifetime reservation claims the whole region up
        front; initial reservation claims only the prefill bucket's pages
        (growth happens chunk-by-chunk) and may PREEMPT a strictly
        later-deadline victim to admit a deadline-pressed queue head.
        Preemption resumes re-prefill prompt + emitted tokens — they
        coalesce into their (larger) bucket like any fresh request."""
        ecfg = self.ecfg
        ps = ecfg.page_size
        initial = ecfg.reserve == "initial"
        admitted = []                   # (req, slot, S, S_b, pages)
        while lane.queue:
            req = lane.queue[0]
            S = len(req.toks) + len(req.prefix)
            S_b = next_pow2(S)
            if initial:
                need = lane.pt.pages_needed(S_b)
            else:
                need = lane.pt.pages_needed(
                    self._region_len(S, req.max_new - len(req.prefix)))
            if not lane.free or need > lane.pt.available:
                if not initial:
                    break
                victim = self._pick_victim(lane, before=req.eff_deadline())
                if victim is None:
                    break
                self._preempt(lane, victim)
                continue
            lane.queue.popleft()
            slot = lane.free.pop()
            pages = lane.pt.alloc(slot, need)
            admitted.append((req, slot, S, S_b, pages))
        if not admitted:
            return
        cfg = lane.pm.cfg
        groups: Dict[int, list] = {}
        for item in admitted:
            groups.setdefault(item[3], []).append(item)
        for S_b, items in sorted(groups.items()):
            B = len(items)
            B_b = next_pow2(B)
            n_pp = -(-S_b // ps)        # pages the prefill bucket covers
            toks_p = np.zeros((B_b, S_b), np.int32)
            last = np.zeros((B_b,), np.int32)
            pages_mat = np.zeros((B_b, n_pp), np.int32)   # pad rows → trash
            for r, (req, slot, S, _, pages) in enumerate(items):
                toks_p[r, :S] = self._full_prompt(req)
                last[r] = S - 1
                pages_mat[r] = pages[:n_pp]
            with self._rules():
                tok0, kv = _prefill_fn(cfg)(lane.params,
                                            jnp.asarray(toks_p),
                                            jnp.asarray(last))
                lane.pool = _write_pages_fn(cfg)(lane.pool, kv,
                                                 jnp.asarray(pages_mat))
            tok0 = np.asarray(tok0)
            now = time.perf_counter()
            for r, (req, slot, S, _, pages) in enumerate(items):
                if self.ecfg.spec_k:
                    self._admit_draft(lane, slot, req.draft,
                                      self._full_prompt(req))
                self.admission_lat.append(now - req.t_submit)
                lane.tok[slot] = int(tok0[r])
                lane.pos[slot] = S      # first decode token writes K/V at S
                lane.active[slot] = self._activate(req, S)

    def _decode_spec_round(self, lane: _Lane) -> None:
        """One speculative draft/verify round (replaces ``_decode_chunk``
        when ``spec_k > 0``):

        1. **draft** — group active slots by drafter; each drafter's pool
           decodes ``spec_k`` tokens ahead in one cached sequential scan.
           Rows outside a group run masked (tok 0 at pos 0 — their writes
           land below the next occupant's prefill, the same free-row
           convention as the plain chunk).
        2. **verify** — ONE target dispatch over ``spec_k`` positions per
           row: the pending committed token plus the first spec_k - 1
           drafts, each position attending only below its own causal
           bound, so position j's argmax is bitwise what the sequential
           chain would produce there.
        3. **commit / roll back** (host) — the longest prefix of drafts
           matching the verify argmax commits, plus the verify's own
           correction token on a mismatch — between 1 (all drafts
           rejected: exactly one plain decode step) and spec_k tokens per
           row. On FULL acceptance the carry becomes the last draft
           rather than the verify's bonus token: taking the bonus would
           advance past a position the draft model never ingested (it
           drafts only spec_k - 1 tokens past the carry), silently
           corrupting the draft cache and collapsing acceptance from the
           next round on. Capping at spec_k keeps the draft and target
           streams aligned with zero catch-up dispatches. The rejected
           suffix rolls back by simply NOT advancing ``pos`` past the
           accepted point: stale drafted K/V above it stays masked by
           validity and is overwritten before it could ever be attended
           (write-before-validity). Only committed tokens enter
           ``st.chunks``/``st.emitted``, so partial tokens under
           cancel/expire/preempt remain exact solo prefixes.
        """
        cfg, ecfg = lane.pm.cfg, self.ecfg
        k = ecfg.spec_k
        T = k     # verify positions: carry token + first k - 1 drafts
        drafted = np.zeros((ecfg.slots, k), np.int32)
        by_draft: Dict[int, List[int]] = {}
        for slot, st in lane.active.items():
            by_draft.setdefault(st.draft, []).append(slot)
        for d, slots in sorted(by_draft.items()):
            dpm = self.pool[d]
            mask = np.zeros((ecfg.slots,), bool)
            mask[slots] = True
            tok_m = np.where(mask, lane.tok, 0).astype(np.int32)
            pos_m = np.where(mask, lane.pos, 0).astype(np.int32)
            with self._rules():
                lane.draft_pools[d], dr = _draft_fn(dpm.cfg, k)(
                    lane.draft_params[d], lane.draft_pools[d],
                    jnp.asarray(tok_m), jnp.asarray(pos_m))
            dr = np.asarray(dr)
            drafted[slots] = dr[slots]
        ver_tok = np.concatenate([lane.tok[:, None], drafted[:, :k - 1]],
                                 axis=1)
        with self._rules():
            if lane.paged:
                lane.pool, g = _verify_paged_fn(cfg, T)(
                    lane.params, lane.pool, jnp.asarray(lane.pt.table),
                    jnp.asarray(ver_tok), jnp.asarray(lane.pos))
            else:
                lane.pool, g = _verify_fn(cfg, T)(
                    lane.params, lane.pool, jnp.asarray(ver_tok),
                    jnp.asarray(lane.pos))
        g = np.asarray(g)                                 # (slots, T)
        self.spec_rounds += 1
        for slot in list(lane.active):
            st = lane.active[slot]
            ds, gs = drafted[slot], g[slot]
            m = 0
            while m < k and ds[m] == gs[m]:
                m += 1
            self.spec_drafted += k
            self.spec_accepted += m
            self.spec_rejected += k - m
            if m < k:
                # correction: gs[m] is the argmax after the last accepted
                # draft — carry it as the next feed, roll the rest back
                adv = m + 1
                committed = np.concatenate(
                    ([np.int32(lane.tok[slot])], ds[:m])).astype(np.int32)
                lane.tok[slot] = gs[m]
            else:
                # full acceptance: carry the last draft (verified: it
                # equals gs[k-1]), not the bonus gs[k] — the draft cache
                # only extends spec_k - 1 past the carry (see docstring)
                adv = k
                committed = np.concatenate(
                    ([np.int32(lane.tok[slot])],
                     ds[:k - 1])).astype(np.int32)
                lane.tok[slot] = ds[k - 1]
            lane.pos[slot] = int(lane.pos[slot]) + adv
            st.chunks.append(committed)
            st.emitted += adv
            if st.emitted >= st.max_new:
                parts = ([st.prefix] if len(st.prefix) else []) + st.chunks
                tokens = np.concatenate(parts)[:st.max_new]
                status = PREEMPTED_RESUMED if st.preempts else DONE
                self._release_slot(lane, slot)
                self._record(st.rid, status, tokens=tokens)

    def _decode_chunk(self, lane: _Lane) -> None:
        cfg, ecfg = lane.pm.cfg, self.ecfg
        with self._rules():
            if lane.paged:
                lane.pool, tok, pos, out = _chunk_paged_fn(cfg, ecfg.chunk)(
                    lane.params, lane.pool, jnp.asarray(lane.pt.table),
                    jnp.asarray(lane.tok), jnp.asarray(lane.pos))
            else:
                lane.pool, tok, pos, out = _chunk_fn(cfg, ecfg.chunk)(
                    lane.params, lane.pool, jnp.asarray(lane.tok),
                    jnp.asarray(lane.pos))
        out = np.asarray(out)
        active_mask = np.zeros((ecfg.slots,), bool)
        active_mask[list(lane.active)] = True
        # free slots keep (tok=0, pos=0). Their garbage K/V writes are safe
        # by the write-before-validity invariant: a slot's valid region
        # [0, pos+1) is always entirely written by its CURRENT occupant —
        # prefill covers [0, S_b), and each decode step writes position p
        # before validity reaches p — so stale leftovers are never attended.
        # (Paged lanes scatter free rows' garbage into the trash page, whose
        # contents no request's page table maps below its validity bound.)
        lane.tok = np.where(active_mask, np.asarray(tok), 0).astype(np.int32)
        lane.pos = np.where(active_mask, np.asarray(pos), 0).astype(np.int32)
        for slot in list(lane.active):
            st = lane.active[slot]
            st.chunks.append(out[slot])
            st.emitted += ecfg.chunk
            if st.emitted >= st.max_new:
                parts = ([st.prefix] if len(st.prefix) else []) + st.chunks
                tokens = np.concatenate(parts)[:st.max_new]
                status = PREEMPTED_RESUMED if st.preempts else DONE
                self._release_slot(lane, slot)
                self._record(st.rid, status, tokens=tokens)
