"""Continuous-batching serving engine with a persistent paged KV pool.

The deployment shape the paper targets (§3) is a router in front of a
model pool serving *many clients concurrently*. The per-request gateway
path serves one caller's batch at a time and pad-copies a fresh KV cache
per request; this engine instead keeps, per routed model, one persistent
cache pool and decodes every in-flight request together:

  admission  — ``submit()`` queues a request; when capacity frees up it is
               prefilled in its pow2 length bucket and its K/V written
               into the pool (buffers donated — no copy). Same-bucket
               admissions **coalesce** into one (B_b, S_b) prefill
               dispatch (per-row ``last_pos``) instead of B separate
               (1, S_b) calls — one trace per (B_b, S_b), and bursty
               arrivals pay one dispatch instead of a convoy.
  decode     — ``step()`` runs ONE cached jitted ``lax.scan`` chunk of
               ``chunk`` greedy tokens over the whole decode batch. Each
               row carries its own position (a per-row ``pos`` vector),
               so requests at different depths share the batch; per-row
               validity (``pos + 1``) masks anything an earlier occupant
               left behind. New requests join between chunks instead of
               waiting for the batch to drain.
  completion — a request that has emitted ``max_new`` tokens frees its
               capacity at the next chunk boundary — steady-state decode
               never reallocates.

KV memory comes in two regimes (``EngineConfig.page_size``):

* **paged** (default, vLLM-style — see ``kv_cache.alloc_page_pool``): one
  flat pool of fixed-size pages shared by every request. A request
  reserves only the pages its own prompt + decode budget needs (its page
  table row maps logical blocks → pool pages; decode gathers by page
  table — ``models.decode_step_paged``, Pallas scalar-prefetch kernel on
  TPU, jnp gather on CPU). Long and short requests share the pool with no
  per-slot worst-case reservation: strictly more in-flight requests per
  byte of KV pool under long-tail length mixes.
* **uniform** (``page_size=None`` — the PR 3 engine, kept as baseline and
  for benchmarks): every slot reserves a full ``max_seq`` region.

Every jitted function is built once per (model config, static shape) and
cached at module level; warm traffic compiles nothing (appends to
``TRACE_LOG`` are per jit *trace*, and tests pin them flat — including
paged decode across mixed per-request page counts, whose shapes are
static ``(slots, max_pages)``).

Greedy decode is prefix-stable, so a request's tokens are bit-identical
to the single-request scan path (``RoutedServer.generate(engine=False)``
on that prompt alone) — test-enforced in tests/test_engine.py and
property-tested over random schedules in tests/test_engine_properties.py.
Caveat: the guarantee is verified on the jnp paths (CPU/interpret). On
TPU the paged decode dispatches to the f32 online-softmax Pallas kernel,
whose accumulation discipline differs from the solo path's cache-dtype
dot — near-tie argmaxes could in principle flip there; running that
parity on real hardware is a ROADMAP item.

SSM/hybrid archs integrate state over every prefill position and cannot
share right-padded prompt buckets; they stay on the gateway's per-request
path (``RoutedServer.generate`` falls back automatically).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model as mdl
from repro.serve.kv_cache import (PageTable, alloc_page_pool,
                                  alloc_slot_pool, write_prefill_pages,
                                  write_slot)

#: one entry appended per jit TRACE of an engine/serve function (including
#: the gateway's route program — hot-swapped router state must enter it as
#: a traced argument, never a retrace) — bounded so a long-running server
#: can't leak memory; tests assert its length stays flat after warmup and
#: across router hot-swaps. gateway.py re-exports this same object.
TRACE_LOG: Deque[tuple] = collections.deque(maxlen=4096)


def reset_trace_log() -> None:
    """Explicitly clear the retrace log (long-running servers)."""
    TRACE_LOG.clear()


def next_pow2(v: int) -> int:
    return 1 << (max(v, 1) - 1).bit_length()


def region_len(n_tokens: int, max_new: int, chunk: int) -> int:
    """Positions a request writes over its lifetime: the pow2 prefill
    bucket or prompt + whole decode chunks, whichever is larger. Module
    level so tests/benchmarks size page pools with the engine's own math
    instead of re-deriving it."""
    steps = -(-max_new // chunk) * chunk
    return max(next_pow2(n_tokens), n_tokens + steps)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine shape — one compiled program set per value of this."""
    slots: int = 8     #: concurrent sequences per model (decode batch rows)
    max_seq: int = 256  #: max per-request region: prompt bucket + decode room
    chunk: int = 8     #: decode tokens per jitted chunk (admission period)
    done_buffer: int = 1024  #: finished results kept for drain(); oldest
    #: evicted beyond this, so step()-consuming servers don't leak
    page_size: Optional[int] = 16  #: paged KV pool page length (positions);
    #: None selects the uniform slot pool (every slot reserves max_seq)
    pages: int = 0  #: allocatable pages in the pool; 0 → auto
    #: (slots * ceil(max_seq / page_size) — worst-case-equivalent, so
    #: admission is never page-bound; set lower to trade reservation
    #: headroom for strictly more in-flight requests per byte)

    @property
    def resolved_pages(self) -> int:
        """Allocatable pages (excluding the trash page)."""
        if not self.page_size:
            return 0
        return self.pages or self.slots * (-(-self.max_seq // self.page_size))


# ---------------------------------------------------------------------------
# Cached jitted stages (module level — never rebuilt per request)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _prefill_fn(cfg: ModelConfig):
    """Prefill one prompt bucket → (first greedy token (B,), KV cache).
    Identical math to the gateway scan path's prefill segment (same
    q_chunk, same last_pos unembed), so engine tokens stay bit-identical
    to the single-request path. ``last_pos`` may be a scalar (uniform
    lanes admit one request at a time) or a (B,) vector (coalesced paged
    admission: same-bucket requests of different true lengths batched into
    one dispatch, each row unembedded at its own last position)."""
    def prefill(params, toks, last_pos):
        TRACE_LOG.append(("engine_prefill", cfg.name, toks.shape))
        logits, _, cache = mdl.forward(params, cfg, tokens=toks,
                                       logits_last_only=True,
                                       last_pos=last_pos,
                                       return_cache=True, q_chunk=64)
        tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return tok0, cache
    return jax.jit(prefill)


@functools.lru_cache(maxsize=None)
def _admit_fn(cfg: ModelConfig):
    """Write a prefill cache into one pool slot. The pool argument is
    donated: admission mutates the persistent buffers in place instead of
    copying the whole pool per request."""
    def admit(pool, prefill_cache, slot):
        TRACE_LOG.append(("engine_admit", cfg.name,
                          jax.tree.leaves(prefill_cache)[0].shape))
        return write_slot(pool, prefill_cache, slot)
    return jax.jit(admit, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _write_pages_fn(cfg: ModelConfig):
    """Scatter a coalesced prefill cache into the paged pool. The pool
    argument is donated: admission mutates the persistent page buffers in
    place instead of copying the pool per batch. One trace per
    (B_b, S_b, n_pp) admission shape."""
    def write(pool, prefill_cache, pages_mat):
        TRACE_LOG.append(("engine_write_pages", cfg.name,
                          jax.tree.leaves(prefill_cache)[0].shape,
                          pages_mat.shape))
        return write_prefill_pages(pool, prefill_cache, pages_mat)
    return jax.jit(write, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _chunk_paged_fn(cfg: ModelConfig, chunk: int):
    """One decode chunk over the paged decode batch: ``chunk`` greedy
    tokens via ``lax.scan`` with per-row positions and the (slots,
    max_pages) page table. The table's shape is static, so mixed
    per-request page counts never retrace; the pool is donated —
    steady-state decode reuses the page buffers."""
    def run(params, cache, page_table, tok, pos):
        TRACE_LOG.append(("engine_chunk_paged", cfg.name, tok.shape,
                          page_table.shape, chunk))

        def body(carry, _):
            tok, pos, cache = carry
            logits, cache = mdl.decode_step_paged(
                params, cache, cfg, tokens=tok[:, None],
                page_table=page_table, pos=pos)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return (nxt, pos + 1, cache), tok

        (tok, pos, cache), out = jax.lax.scan(body, (tok, pos, cache), None,
                                              length=chunk)
        return cache, tok, pos, out.T                     # out: (B, chunk)
    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _chunk_fn(cfg: ModelConfig, chunk: int):
    """One decode chunk over the whole slot batch: ``chunk`` greedy tokens
    via ``lax.scan`` with a per-slot position vector. Emits the token fed
    at each step (same emission order as the gateway scan), the slot
    cache (donated — steady-state decode reuses the pool buffers), and the
    advanced (tok, pos) carry."""
    def run(params, cache, tok, pos):
        TRACE_LOG.append(("engine_chunk", cfg.name, tok.shape, chunk))

        def body(carry, _):
            tok, pos, cache = carry
            logits, cache = mdl.decode_step(params, cache, cfg,
                                            tokens=tok[:, None], pos=pos)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return (nxt, pos + 1, cache), tok

        (tok, pos, cache), out = jax.lax.scan(body, (tok, pos, cache), None,
                                              length=chunk)
        return cache, tok, pos, out.T                     # out: (B, chunk)
    return jax.jit(run, donate_argnums=(1,))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Active:
    rid: int
    max_new: int
    chunks: List[np.ndarray] = dataclasses.field(default_factory=list)
    emitted: int = 0


@dataclasses.dataclass
class _Pending:
    rid: int
    toks: np.ndarray           # (S,) int32 prompt tokens, unpadded
    max_new: int
    t_submit: float = 0.0      # perf_counter at submit (admission latency)


class _Lane:
    """Per-model engine state: the KV pool (paged or uniform) + host-side
    slot/page bookkeeping."""

    def __init__(self, pm, ecfg: EngineConfig):
        self.pm = pm
        self.ecfg = ecfg
        self.paged = bool(ecfg.page_size)
        if self.paged:
            self.pool = alloc_page_pool(pm.cfg, ecfg.resolved_pages,
                                        ecfg.page_size)
            self.pt = PageTable(ecfg.slots, ecfg.resolved_pages,
                                ecfg.page_size, ecfg.max_seq)
        else:
            self.pool = alloc_slot_pool(pm.cfg, ecfg.slots, ecfg.max_seq)
            self.pt = None
        self.free: List[int] = list(range(ecfg.slots))[::-1]
        self.active: Dict[int, _Active] = {}             # slot -> request
        self.queue: Deque[_Pending] = collections.deque()
        self.tok = np.zeros((ecfg.slots,), np.int32)     # next token to feed
        self.pos = np.zeros((ecfg.slots,), np.int32)     # its write position


class ServeEngine:
    """Admission queue + slot pools over a model pool (attention archs).

    ``submit`` enqueues, ``step`` admits + decodes one chunk per lane,
    ``drain`` steps until idle and returns {request id: np tokens}.
    """

    def __init__(self, pool: List, ecfg: Optional[EngineConfig] = None):
        self.ecfg = ecfg or EngineConfig()
        self.pool = pool
        self._lanes: Dict[int, _Lane] = {}
        self._next_rid = 0
        self._done: Dict[int, np.ndarray] = {}
        #: queue-wait per admitted request (submit → prefill dispatched),
        #: seconds; bounded like TRACE_LOG so long-running servers don't
        #: leak. benchmarks/perf_suite.bench_paged reads the p99.
        self.admission_lat: Deque[float] = collections.deque(maxlen=65536)
        #: high-water mark of concurrently admitted requests, sampled at
        #: every chunk boundary between admission and decode (completions
        #: release capacity before step() returns, so callers can't see
        #: it). Reset by assigning 0; bench_paged's in-flight-per-byte
        #: numerator.
        self.peak_active: int = 0

    def _region_len(self, n_tokens: int, max_new: int) -> int:
        return region_len(n_tokens, max_new, self.ecfg.chunk)

    def fits(self, n_tokens: int, max_new: int) -> bool:
        """Whether a request can ever be admitted: its written region must
        stay inside ``max_seq`` (the page-table width on paged lanes, the
        slot region on uniform ones), and on paged lanes its page count
        must not exceed the whole pool."""
        region = self._region_len(n_tokens, max_new)
        if region > self.ecfg.max_seq:
            return False
        if self.ecfg.page_size:
            need = -(-region // self.ecfg.page_size)
            return need <= self.ecfg.resolved_pages
        return True

    def kv_pool_bytes(self) -> int:
        """Bytes held by every lane's persistent KV pool (paged pools
        include the trash page)."""
        return sum(leaf.nbytes for lane in self._lanes.values()
                   for leaf in jax.tree.leaves(lane.pool))

    def n_active(self) -> int:
        """Requests currently holding decode capacity (all lanes)."""
        return sum(len(lane.active) for lane in self._lanes.values())

    # ------------------------------------------------------------- submit
    def submit(self, model_idx: int, toks: np.ndarray, max_new: int) -> int:
        pm = self.pool[int(model_idx)]
        if pm.cfg.arch_type in ("ssm", "hybrid"):
            raise TypeError(
                f"{pm.cfg.name}: SSM/hybrid archs integrate state over pad "
                "positions and can't share right-padded slot buckets — use "
                "RoutedServer.generate (it falls back per request)")
        toks = np.asarray(toks, np.int32).reshape(-1)
        if not self.fits(len(toks), max_new):
            raise ValueError(
                f"prompt ({len(toks)} tokens, pow2 bucket "
                f"{next_pow2(len(toks))}) + whole decode chunks for "
                f"max_new={max_new} exceed the per-request region "
                f"max_seq={self.ecfg.max_seq}"
                + (f" or the page pool ({self.ecfg.resolved_pages} pages of "
                   f"{self.ecfg.page_size})" if self.ecfg.page_size else "")
                + " — raise EngineConfig.max_seq/pages or shorten the "
                "request (RoutedServer.generate falls back to the per-call "
                "path automatically)")
        rid = self._next_rid
        self._next_rid += 1
        lane = self._lanes.get(int(model_idx))
        if lane is None:
            lane = self._lanes[int(model_idx)] = _Lane(pm, self.ecfg)
        lane.queue.append(_Pending(rid, toks, max_new,
                                   t_submit=time.perf_counter()))
        return rid

    # --------------------------------------------------------------- step
    def step(self) -> List[Tuple[int, np.ndarray]]:
        """Admit what fits, then decode one chunk on every busy lane.
        Returns the requests finished this step as (rid, tokens). Finished
        results are also buffered for ``drain()`` — up to
        ``EngineConfig.done_buffer`` of them, oldest evicted first, so a
        server that consumes step()'s return value and never drains can
        run forever without growing memory."""
        finished: List[Tuple[int, np.ndarray]] = []
        for lane in self._lanes.values():
            self._admit(lane)
        self.peak_active = max(self.peak_active, self.n_active())
        for lane in self._lanes.values():
            if lane.active:
                finished.extend(self._decode_chunk(lane))
        for rid, out in finished:
            self._done[rid] = out
        while len(self._done) > self.ecfg.done_buffer:
            self._done.pop(next(iter(self._done)))
        return finished

    @property
    def busy(self) -> bool:
        return any(l.queue or l.active for l in self._lanes.values())

    def drain(self, rids=None) -> Dict[int, np.ndarray]:
        """Step until completion and return {rid: tokens}. With rids=None,
        runs until every lane is idle and returns (and clears) everything;
        with an iterable of request ids, runs until exactly those finish
        and leaves other results in place (so interleaved ``submit``
        streams keep their results)."""
        if rids is None:
            # capture from step() returns as requests finish — like the
            # rids branch below, immune to done-buffer eviction when more
            # than done_buffer requests are in flight
            out = dict(self._done)
            while self.busy:
                out.update(self.step())
            out.update(self._done)
            self._done = {}
            return out
        want = set(rids)
        # collect straight from step() results (not only the _done buffer,
        # whose oldest entries step() may evict) — a wanted rid is captured
        # the moment it finishes, so any batch size is safe
        out = {r: self._done.pop(r) for r in want if r in self._done}
        while want - out.keys():
            if not self.busy:
                raise KeyError(f"unknown request ids: "
                               f"{sorted(want - out.keys())}")
            for rid, toks in self.step():
                if rid in want:
                    out[rid] = toks
                    self._done.pop(rid, None)
        return out

    # ------------------------------------------------------------ internals
    def _admit(self, lane: _Lane) -> None:
        if lane.paged:
            self._admit_paged(lane)
            return
        cfg = lane.pm.cfg
        while lane.free and lane.queue:
            req = lane.queue.popleft()
            slot = lane.free.pop()
            S = len(req.toks)
            S_b = next_pow2(S)
            toks_p = np.zeros((1, S_b), np.int32)
            toks_p[0, :S] = req.toks
            tok0, kv = _prefill_fn(cfg)(lane.pm.params, jnp.asarray(toks_p),
                                        jnp.int32(S - 1))
            lane.pool = _admit_fn(cfg)(lane.pool, kv, jnp.int32(slot))
            self.admission_lat.append(time.perf_counter() - req.t_submit)
            lane.tok[slot] = int(tok0[0])
            lane.pos[slot] = S          # first decode token writes K/V at S
            lane.active[slot] = _Active(req.rid, req.max_new)

    def _admit_paged(self, lane: _Lane) -> None:
        """Paged admission: claim a decode slot + exactly the pages each
        request's own region needs (FIFO — the head waits for pages rather
        than being overtaken), then COALESCE everything admitted this
        boundary by prompt bucket: one (B_b, S_b) prefill dispatch per
        bucket with per-row ``last_pos``, one donated page scatter. Pad
        rows of a non-pow2 group prefill garbage into the trash page."""
        ecfg = self.ecfg
        ps = ecfg.page_size
        admitted = []                   # (req, slot, S, S_b, pages)
        while lane.queue and lane.free:
            req = lane.queue[0]
            S = len(req.toks)
            S_b = next_pow2(S)
            need = lane.pt.pages_needed(self._region_len(S, req.max_new))
            if need > lane.pt.available:
                break
            lane.queue.popleft()
            slot = lane.free.pop()
            pages = lane.pt.alloc(slot, need)
            admitted.append((req, slot, S, S_b, pages))
        if not admitted:
            return
        cfg = lane.pm.cfg
        groups: Dict[int, list] = {}
        for item in admitted:
            groups.setdefault(item[3], []).append(item)
        for S_b, items in sorted(groups.items()):
            B = len(items)
            B_b = next_pow2(B)
            n_pp = -(-S_b // ps)        # pages the prefill bucket covers
            toks_p = np.zeros((B_b, S_b), np.int32)
            last = np.zeros((B_b,), np.int32)
            pages_mat = np.zeros((B_b, n_pp), np.int32)   # pad rows → trash
            for r, (req, slot, S, _, pages) in enumerate(items):
                toks_p[r, :S] = req.toks
                last[r] = S - 1
                pages_mat[r] = pages[:n_pp]
            tok0, kv = _prefill_fn(cfg)(lane.pm.params, jnp.asarray(toks_p),
                                        jnp.asarray(last))
            lane.pool = _write_pages_fn(cfg)(lane.pool, kv,
                                             jnp.asarray(pages_mat))
            tok0 = np.asarray(tok0)
            now = time.perf_counter()
            for r, (req, slot, S, _, pages) in enumerate(items):
                self.admission_lat.append(now - req.t_submit)
                lane.tok[slot] = int(tok0[r])
                lane.pos[slot] = S      # first decode token writes K/V at S
                lane.active[slot] = _Active(req.rid, req.max_new)

    def _decode_chunk(self, lane: _Lane) -> List[Tuple[int, np.ndarray]]:
        cfg, ecfg = lane.pm.cfg, self.ecfg
        if lane.paged:
            lane.pool, tok, pos, out = _chunk_paged_fn(cfg, ecfg.chunk)(
                lane.pm.params, lane.pool, jnp.asarray(lane.pt.table),
                jnp.asarray(lane.tok), jnp.asarray(lane.pos))
        else:
            lane.pool, tok, pos, out = _chunk_fn(cfg, ecfg.chunk)(
                lane.pm.params, lane.pool, jnp.asarray(lane.tok),
                jnp.asarray(lane.pos))
        out = np.asarray(out)
        active_mask = np.zeros((ecfg.slots,), bool)
        active_mask[list(lane.active)] = True
        # free slots keep (tok=0, pos=0). Their garbage K/V writes are safe
        # by the write-before-validity invariant: a slot's valid region
        # [0, pos+1) is always entirely written by its CURRENT occupant —
        # prefill covers [0, S_b), and each decode step writes position p
        # before validity reaches p — so stale leftovers are never attended.
        # (Paged lanes scatter free rows' garbage into the trash page, whose
        # contents no request's page table maps below its validity bound.)
        lane.tok = np.where(active_mask, np.asarray(tok), 0).astype(np.int32)
        lane.pos = np.where(active_mask, np.asarray(pos), 0).astype(np.int32)
        finished = []
        for slot in list(lane.active):
            st = lane.active[slot]
            st.chunks.append(out[slot])
            st.emitted += ecfg.chunk
            if st.emitted >= st.max_new:
                tokens = np.concatenate(st.chunks)[:st.max_new]
                finished.append((st.rid, tokens))
                del lane.active[slot]
                lane.free.append(slot)
                if lane.paged:
                    lane.pt.release(slot)
                lane.tok[slot] = 0
                lane.pos[slot] = 0
        return finished
