"""Continuous-batching serving engine with a persistent slot-based KV pool.

The deployment shape the paper targets (§3) is a router in front of a
model pool serving *many clients concurrently*. The per-request gateway
path serves one caller's batch at a time and pad-copies a fresh KV cache
per request; this engine instead keeps, per routed model, one persistent
cache pool with a fixed number of sequence **slots** and decodes every
in-flight request together:

  admission  — ``submit()`` queues a request; when a slot frees up the
               prompt is prefilled in its own pow2 length bucket (cached
               jit per (config, bucket)) and its K/V written into the slot
               (``kv_cache.write_slot``, pool buffer donated — no copy).
  decode     — ``step()`` runs ONE cached jitted ``lax.scan`` chunk of
               ``chunk`` greedy tokens over the whole slot batch. Each
               slot carries its own position (a per-slot ``pos`` vector —
               see ``models.attention.attn_decode_step``), so requests at
               different depths share the batch; per-slot validity
               (``pos + 1``) masks whatever an earlier occupant left in
               the region. New requests join between chunks instead of
               waiting for the batch to drain.
  completion — a request that has emitted ``max_new`` tokens frees its
               slot at the next chunk boundary; freeing is just returning
               the slot index — steady-state decode never reallocates.

Every jitted function is built once per (model config, static shape) and
cached at module level; warm traffic compiles nothing (appends to
``TRACE_LOG`` are per jit *trace*, and tests pin them flat).

Greedy decode is prefix-stable, so a request's tokens are bit-identical
to the single-request scan path (``RoutedServer.generate(engine=False)``
on that prompt alone) — test-enforced in tests/test_engine.py.

SSM/hybrid archs integrate state over every prefill position and cannot
share right-padded prompt buckets; they stay on the gateway's per-request
path (``RoutedServer.generate`` falls back automatically).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model as mdl
from repro.serve.kv_cache import alloc_slot_pool, write_slot

#: one entry appended per jit TRACE of an engine/serve function — bounded
#: so a long-running server can't leak memory; tests assert its length
#: stays flat after warmup. gateway.py re-exports this same object.
TRACE_LOG: Deque[tuple] = collections.deque(maxlen=4096)


def reset_trace_log() -> None:
    """Explicitly clear the retrace log (long-running servers)."""
    TRACE_LOG.clear()


def next_pow2(v: int) -> int:
    return 1 << (max(v, 1) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine shape — one compiled program set per value of this."""
    slots: int = 8     #: concurrent sequences per model (pool batch rows)
    max_seq: int = 256  #: per-slot KV region: prompt bucket + decode room
    chunk: int = 8     #: decode tokens per jitted chunk (admission period)
    done_buffer: int = 1024  #: finished results kept for drain(); oldest
    #: evicted beyond this, so step()-consuming servers don't leak


# ---------------------------------------------------------------------------
# Cached jitted stages (module level — never rebuilt per request)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _prefill_fn(cfg: ModelConfig):
    """Prefill one prompt bucket → (first greedy token (B,), KV cache).
    Identical math to the gateway scan path's prefill segment (same
    q_chunk, same last_pos unembed), so engine tokens stay bit-identical
    to the single-request path."""
    def prefill(params, toks, last_pos):
        TRACE_LOG.append(("engine_prefill", cfg.name, toks.shape))
        logits, _, cache = mdl.forward(params, cfg, tokens=toks,
                                       logits_last_only=True,
                                       last_pos=last_pos,
                                       return_cache=True, q_chunk=64)
        tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return tok0, cache
    return jax.jit(prefill)


@functools.lru_cache(maxsize=None)
def _admit_fn(cfg: ModelConfig):
    """Write a prefill cache into one pool slot. The pool argument is
    donated: admission mutates the persistent buffers in place instead of
    copying the whole pool per request."""
    def admit(pool, prefill_cache, slot):
        TRACE_LOG.append(("engine_admit", cfg.name,
                          jax.tree.leaves(prefill_cache)[0].shape))
        return write_slot(pool, prefill_cache, slot)
    return jax.jit(admit, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _chunk_fn(cfg: ModelConfig, chunk: int):
    """One decode chunk over the whole slot batch: ``chunk`` greedy tokens
    via ``lax.scan`` with a per-slot position vector. Emits the token fed
    at each step (same emission order as the gateway scan), the slot
    cache (donated — steady-state decode reuses the pool buffers), and the
    advanced (tok, pos) carry."""
    def run(params, cache, tok, pos):
        TRACE_LOG.append(("engine_chunk", cfg.name, tok.shape, chunk))

        def body(carry, _):
            tok, pos, cache = carry
            logits, cache = mdl.decode_step(params, cache, cfg,
                                            tokens=tok[:, None], pos=pos)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return (nxt, pos + 1, cache), tok

        (tok, pos, cache), out = jax.lax.scan(body, (tok, pos, cache), None,
                                              length=chunk)
        return cache, tok, pos, out.T                     # out: (B, chunk)
    return jax.jit(run, donate_argnums=(1,))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Active:
    rid: int
    max_new: int
    chunks: List[np.ndarray] = dataclasses.field(default_factory=list)
    emitted: int = 0


@dataclasses.dataclass
class _Pending:
    rid: int
    toks: np.ndarray           # (S,) int32 prompt tokens, unpadded
    max_new: int


class _Lane:
    """Per-model engine state: the slot pool + host-side slot bookkeeping."""

    def __init__(self, pm, ecfg: EngineConfig):
        self.pm = pm
        self.ecfg = ecfg
        self.pool = alloc_slot_pool(pm.cfg, ecfg.slots, ecfg.max_seq)
        self.free: List[int] = list(range(ecfg.slots))[::-1]
        self.active: Dict[int, _Active] = {}             # slot -> request
        self.queue: Deque[_Pending] = collections.deque()
        self.tok = np.zeros((ecfg.slots,), np.int32)     # next token to feed
        self.pos = np.zeros((ecfg.slots,), np.int32)     # its write position


class ServeEngine:
    """Admission queue + slot pools over a model pool (attention archs).

    ``submit`` enqueues, ``step`` admits + decodes one chunk per lane,
    ``drain`` steps until idle and returns {request id: np tokens}.
    """

    def __init__(self, pool: List, ecfg: Optional[EngineConfig] = None):
        self.ecfg = ecfg or EngineConfig()
        self.pool = pool
        self._lanes: Dict[int, _Lane] = {}
        self._next_rid = 0
        self._done: Dict[int, np.ndarray] = {}

    def fits(self, n_tokens: int, max_new: int) -> bool:
        """Whether a request fits one slot region: the prefill writes its
        pow2 length bucket, decode writes whole chunks past the prompt —
        both must stay inside ``max_seq``."""
        steps = -(-max_new // self.ecfg.chunk) * self.ecfg.chunk
        return max(next_pow2(n_tokens),
                   n_tokens + steps) <= self.ecfg.max_seq

    # ------------------------------------------------------------- submit
    def submit(self, model_idx: int, toks: np.ndarray, max_new: int) -> int:
        pm = self.pool[int(model_idx)]
        if pm.cfg.arch_type in ("ssm", "hybrid"):
            raise TypeError(
                f"{pm.cfg.name}: SSM/hybrid archs integrate state over pad "
                "positions and can't share right-padded slot buckets — use "
                "RoutedServer.generate (it falls back per request)")
        toks = np.asarray(toks, np.int32).reshape(-1)
        if not self.fits(len(toks), max_new):
            raise ValueError(
                f"prompt ({len(toks)} tokens, pow2 bucket "
                f"{next_pow2(len(toks))}) + whole decode chunks for "
                f"max_new={max_new} exceed the per-slot region "
                f"max_seq={self.ecfg.max_seq} — raise EngineConfig.max_seq "
                "or shorten the request (RoutedServer.generate falls back "
                "to the per-call path automatically)")
        rid = self._next_rid
        self._next_rid += 1
        lane = self._lanes.get(int(model_idx))
        if lane is None:
            lane = self._lanes[int(model_idx)] = _Lane(pm, self.ecfg)
        lane.queue.append(_Pending(rid, toks, max_new))
        return rid

    # --------------------------------------------------------------- step
    def step(self) -> List[Tuple[int, np.ndarray]]:
        """Admit what fits, then decode one chunk on every busy lane.
        Returns the requests finished this step as (rid, tokens). Finished
        results are also buffered for ``drain()`` — up to
        ``EngineConfig.done_buffer`` of them, oldest evicted first, so a
        server that consumes step()'s return value and never drains can
        run forever without growing memory."""
        finished: List[Tuple[int, np.ndarray]] = []
        for lane in self._lanes.values():
            self._admit(lane)
            if lane.active:
                finished.extend(self._decode_chunk(lane))
        for rid, out in finished:
            self._done[rid] = out
        while len(self._done) > self.ecfg.done_buffer:
            self._done.pop(next(iter(self._done)))
        return finished

    @property
    def busy(self) -> bool:
        return any(l.queue or l.active for l in self._lanes.values())

    def drain(self, rids=None) -> Dict[int, np.ndarray]:
        """Step until completion and return {rid: tokens}. With rids=None,
        runs until every lane is idle and returns (and clears) everything;
        with an iterable of request ids, runs until exactly those finish
        and leaves other results in place (so interleaved ``submit``
        streams keep their results)."""
        if rids is None:
            # capture from step() returns as requests finish — like the
            # rids branch below, immune to done-buffer eviction when more
            # than done_buffer requests are in flight
            out = dict(self._done)
            while self.busy:
                out.update(self.step())
            out.update(self._done)
            self._done = {}
            return out
        want = set(rids)
        # collect straight from step() results (not only the _done buffer,
        # whose oldest entries step() may evict) — a wanted rid is captured
        # the moment it finishes, so any batch size is safe
        out = {r: self._done.pop(r) for r in want if r in self._done}
        while want - out.keys():
            if not self.busy:
                raise KeyError(f"unknown request ids: "
                               f"{sorted(want - out.keys())}")
            for rid, toks in self.step():
                if rid in want:
                    out[rid] = toks
                    self._done.pop(rid, None)
        return out

    # ------------------------------------------------------------ internals
    def _admit(self, lane: _Lane) -> None:
        cfg = lane.pm.cfg
        while lane.free and lane.queue:
            req = lane.queue.popleft()
            slot = lane.free.pop()
            S = len(req.toks)
            S_b = next_pow2(S)
            toks_p = np.zeros((1, S_b), np.int32)
            toks_p[0, :S] = req.toks
            tok0, kv = _prefill_fn(cfg)(lane.pm.params, jnp.asarray(toks_p),
                                        jnp.int32(S - 1))
            lane.pool = _admit_fn(cfg)(lane.pool, kv, jnp.int32(slot))
            lane.tok[slot] = int(tok0[0])
            lane.pos[slot] = S          # first decode token writes K/V at S
            lane.active[slot] = _Active(req.rid, req.max_new)

    def _decode_chunk(self, lane: _Lane) -> List[Tuple[int, np.ndarray]]:
        cfg, ecfg = lane.pm.cfg, self.ecfg
        lane.pool, tok, pos, out = _chunk_fn(cfg, ecfg.chunk)(
            lane.pm.params, lane.pool, jnp.asarray(lane.tok),
            jnp.asarray(lane.pos))
        out = np.asarray(out)
        active_mask = np.zeros((ecfg.slots,), bool)
        active_mask[list(lane.active)] = True
        # free slots keep (tok=0, pos=0). Their garbage K/V writes are safe
        # by the write-before-validity invariant: a slot's valid region
        # [0, pos+1) is always entirely written by its CURRENT occupant —
        # prefill covers [0, S_b), and each decode step writes position p
        # before validity reaches p — so stale leftovers are never attended
        lane.tok = np.where(active_mask, np.asarray(tok), 0).astype(np.int32)
        lane.pos = np.where(active_mask, np.asarray(pos), 0).astype(np.int32)
        finished = []
        for slot in list(lane.active):
            st = lane.active[slot]
            st.chunks.append(out[slot])
            st.emitted += ecfg.chunk
            if st.emitted >= st.max_new:
                tokens = np.concatenate(st.chunks)[:st.max_new]
                finished.append((st.rid, tokens))
                del lane.active[slot]
                lane.free.append(slot)
                lane.tok[slot] = 0
                lane.pos[slot] = 0
        return finished
