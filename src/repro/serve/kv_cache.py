"""KV-cache utilities for the serving path.

Two cache regimes live here:

* ``extend_cache`` — the per-request regime: a prefill-produced cache is
  pad-copied up to prompt+max_new so a single batch can decode. Kept as
  the fallback path (``RoutedServer.generate(engine=False)``).
* the **slot pool** — the continuous-batching regime (serve/engine.py):
  one persistent cache is allocated per (model config, pool shape) with a
  fixed number of sequence *slots* (the batch dim) and a fixed per-slot
  region length. Requests claim a slot at admission, their prefill K/V is
  written into the slot with ``write_slot``, and steady-state decode does
  zero cache reallocation — per-slot validity (``pos + 1``) masks whatever
  a previous occupant left behind, so freeing a slot is just returning its
  index to the free list.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def extend_cache(cache, new_len: int):
    """Pad the seq dim of attention caches (leaf names k/v, dim 3 of the
    stacked (L,B,Hkv,S,hd) head-major layout) up to new_len — used to
    continue decoding from a prefill-produced cache."""
    def leaf(path, a):
        names = [p.key for p in path if hasattr(p, "key")]
        if names[-1] in ("k", "v"):
            pad = new_len - a.shape[3]
            if pad > 0:
                a = jnp.pad(a, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        return a
    return jax.tree_util.tree_map_with_path(leaf, cache)


def alloc_slot_pool(cfg, slots: int, max_seq: int):
    """Allocate the persistent slot-pool cache for one model: the stacked
    decode cache with ``slots`` sequence rows and ``max_seq`` positions per
    slot. Zero-filled; slot contents only become attention-valid once a
    request writes them (validity is per-slot ``pos + 1``)."""
    from repro.models import model as mdl
    return mdl.init_decode_cache(cfg, slots, max_seq)


def write_slot(pool, prefill_cache, slot):
    """Copy a single-sequence prefill cache (leaves (L, 1, ...)) into row
    ``slot`` of the pool (leaves (L, slots, ...)). ``slot`` may be traced —
    one compiled program serves every slot index. Attention leaves land at
    positions [0, S_prefill) of the slot's region; anything beyond stays
    whatever the previous occupant wrote, masked off by per-slot validity.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def leaf(p, u):
        return jax.lax.dynamic_update_slice(
            p, u.astype(p.dtype), (0, slot) + (0,) * (u.ndim - 2))

    return jax.tree.map(leaf, pool, prefill_cache)
