"""KV-cache utilities for the serving path."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def extend_cache(cache, new_len: int):
    """Pad the seq dim of attention caches (leaf names k/v, dim 3 of the
    stacked (L,B,Hkv,S,hd) head-major layout) up to new_len — used to
    continue decoding from a prefill-produced cache."""
    def leaf(path, a):
        names = [p.key for p in path if hasattr(p, "key")]
        if names[-1] in ("k", "v"):
            pad = new_len - a.shape[3]
            if pad > 0:
                a = jnp.pad(a, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        return a
    return jax.tree_util.tree_map_with_path(leaf, cache)
