"""KV-cache utilities for the serving path.

Three cache regimes live here:

* ``extend_cache`` — the per-request regime: a prefill-produced cache is
  pad-copied up to prompt+max_new so a single batch can decode. Kept as
  the fallback path (``RoutedServer.generate(engine=False)``).
* the **slot pool** — the uniform continuous-batching regime: one
  persistent cache per (model config, pool shape) with a fixed number of
  sequence *slots* (the batch dim) and a fixed per-slot region length
  ``max_seq``. Requests claim a slot at admission, their prefill K/V is
  written with ``write_slot``, and steady-state decode does zero cache
  reallocation — per-slot validity (``pos + 1``) masks whatever a previous
  occupant left behind. Every slot reserves worst-case room.
* the **page pool** — the vLLM-style regime (serve/engine.py's default):
  one flat pool of fixed-size *pages* shared by every in-flight request.
  A request holds only the pages its actual length needs (its *page
  table* row maps logical blocks → pool pages), so long and short
  requests share the pool with no per-slot worst-case reservation —
  strictly more in-flight requests per byte of KV memory under mixed
  lengths. Page index 0 is the **trash page**: never handed out, the
  scatter target for inactive decode rows and the table filler past a
  request's reservation — gathers from it are masked by validity.

Speculative write-ahead (serve/engine.py draft/verify rounds) rides the
same write-before-validity invariant in BOTH pool regimes: a verify step
writes K/V for positions [pos, pos + k] before any of them is committed,
and a query only ever attends positions below its own causal bound — so
uncommitted drafts are physically present but logically invisible.
**Rollback is pure host bookkeeping**: rejecting a drafted suffix just
resets the slot's ``pos`` to the last accepted position; the stale
drafted K/V above it stays masked until the next occupant of those
positions overwrites it (each decode/verify step writes a position
strictly before validity reaches it). No device-side cache surgery, no
retrace. Overflow discipline differs per regime: the paged verify scatter
redirects positions past a row's claimed pages to the trash page, while
the uniform verify scatter drops out-of-bounds positions — either way a
speculative window poking past the region can never corrupt live
entries. ``alloc_draft_pool`` sizes the drafter's slot pool with the
write-ahead headroom so the draft model's own sequential decode never
clamps at the region end.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def extend_cache(cache, new_len: int):
    """Pad the seq dim of attention caches (leaf names k/v, dim 3 of the
    stacked (L,B,Hkv,S,hd) head-major layout) up to new_len — used to
    continue decoding from a prefill-produced cache."""
    def leaf(path, a):
        names = [p.key for p in path if hasattr(p, "key")]
        if names[-1] in ("k", "v"):
            pad = new_len - a.shape[3]
            if pad > 0:
                a = jnp.pad(a, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        return a
    return jax.tree_util.tree_map_with_path(leaf, cache)


def alloc_slot_pool(cfg, slots: int, max_seq: int):
    """Allocate the persistent slot-pool cache for one model: the stacked
    decode cache with ``slots`` sequence rows and ``max_seq`` positions per
    slot. Zero-filled; slot contents only become attention-valid once a
    request writes them (validity is per-slot ``pos + 1``)."""
    from repro.models import model as mdl
    return mdl.init_decode_cache(cfg, slots, max_seq)


def write_slot(pool, prefill_cache, slot):
    """Copy a single-sequence prefill cache (leaves (L, 1, ...)) into row
    ``slot`` of the pool (leaves (L, slots, ...)). ``slot`` may be traced —
    one compiled program serves every slot index. Attention leaves land at
    positions [0, S_prefill) of the slot's region; anything beyond stays
    whatever the previous occupant wrote, masked off by per-slot validity.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def leaf(p, u):
        return jax.lax.dynamic_update_slice(
            p, u.astype(p.dtype), (0, slot) + (0,) * (u.ndim - 2))

    return jax.tree.map(leaf, pool, prefill_cache)


def alloc_draft_pool(cfg, slots: int, max_seq: int, spec_k: int):
    """Allocate the drafter's slot pool for a speculative lane: a uniform
    pool (drafts are cheap models — page elasticity buys nothing there)
    with ``spec_k`` positions of write-ahead headroom past the target
    lane's region. The headroom matters: the draft model decodes
    sequentially through the speculative window, and its last draft for a
    request ending flush at ``max_seq`` writes at position
    ``max_seq + spec_k - 1``; without the slack a clamped
    ``dynamic_update_slice`` would smear that write over the region's live
    tail and corrupt the draft cache (costing acceptance, not
    correctness — the verify step is the sole authority on tokens)."""
    return alloc_slot_pool(cfg, slots, max_seq + spec_k)


# ---------------------------------------------------------------------------
# Paged pool
# ---------------------------------------------------------------------------


def alloc_page_pool(cfg, pages: int, page_size: int):
    """Allocate the persistent paged cache for one model: leaves
    (n_units, pages + 1, Hkv, page_size, hd) — ``pages`` allocatable pages
    plus the trash page at index 0 (never handed out; absorbs the scatter
    writes of inactive decode rows and backs unassigned page-table
    entries). Zero-filled; page contents only become attention-valid once
    a request's validity frontier (``pos + 1``) covers them."""
    from repro.models import model as mdl
    return mdl.init_paged_cache(cfg, pages + 1, page_size)


class PageTable:
    """Host-side page bookkeeping for one engine lane: a free list over
    pool pages [1, pages] (0 is the trash page) and one table row per
    decode slot mapping logical blocks → pool pages. Unassigned entries
    stay 0 — the decode gather reads the trash page there and validity
    masks it. Recycling a slot is O(pages held): its pages return to the
    free list and the row zeroes; no data movement, the next holder's
    write-before-validity discipline masks whatever was left behind."""

    def __init__(self, slots: int, pages: int, page_size: int, max_seq: int):
        self.page_size = page_size
        self.pages = pages
        self.max_pages = -(-max_seq // page_size)    # table width (static)
        self.table = np.zeros((slots, self.max_pages), np.int32)
        self.free: List[int] = list(range(pages, 0, -1))   # pop() → page 1
        self._held: Dict[int, List[int]] = {}              # slot → pages

    def pages_needed(self, region_len: int) -> int:
        return -(-region_len // self.page_size)

    @property
    def available(self) -> int:
        return len(self.free)

    def held(self, slot: int) -> int:
        """Pages currently held by ``slot`` (0 if none)."""
        return len(self._held.get(slot, ()))

    def alloc(self, slot: int, n: int) -> np.ndarray:
        """Claim n pages for ``slot``; returns their pool indices in
        logical-block order. Raises if the pool is exhausted (callers gate
        admission on ``available``)."""
        if n > len(self.free):
            raise RuntimeError(f"page pool exhausted: need {n}, "
                               f"have {len(self.free)}")
        if slot in self._held:
            raise RuntimeError(f"slot {slot} already holds pages")
        got = [self.free.pop() for _ in range(n)]
        self.table[slot, :n] = got
        self.table[slot, n:] = 0
        self._held[slot] = got
        return np.asarray(got, np.int32)

    def grow(self, slot: int, n: int) -> np.ndarray:
        """On-demand growth: append ``n`` more pages to a slot that already
        holds some (initial-reservation admission — the decode loop grows a
        request's table right before its writes cross a page boundary).
        Raises on exhaustion (callers preempt a victim first), on a slot
        holding nothing (growth is not admission), and past the static
        table width."""
        if slot not in self._held:
            raise RuntimeError(f"slot {slot} holds no pages — grow() "
                               "extends an existing reservation; use "
                               "alloc() to admit")
        held = self._held[slot]
        if len(held) + n > self.max_pages:
            raise RuntimeError(
                f"slot {slot} cannot grow to {len(held) + n} pages: the "
                f"table row is {self.max_pages} wide (max_seq-bound)")
        if n > len(self.free):
            raise RuntimeError(f"page pool exhausted: grow needs {n}, "
                               f"have {len(self.free)}")
        got = [self.free.pop() for _ in range(n)]
        self.table[slot, len(held):len(held) + n] = got
        held.extend(got)
        return np.asarray(got, np.int32)

    def release(self, slot: int) -> bool:
        """Return a slot's pages to the free list and zero its row.

        Deterministic under the cancellation/expiry/preemption paths that
        may race completion: releasing a slot that holds nothing (double
        release included) is a NO-OP returning False — pages are never
        re-added to the free list, so it cannot be corrupted. A slot index
        outside the table raises IndexError (that is a caller bug, not a
        race). Pinned by tests/test_engine_resilience.py."""
        if not 0 <= int(slot) < self.table.shape[0]:
            raise IndexError(
                f"slot {slot} outside the page table "
                f"(slots={self.table.shape[0]})")
        pages = self._held.pop(slot, None)
        if pages is None:
            return False
        self.free.extend(pages)
        self.table[slot] = 0
        return True


def write_prefill_pages(pool, prefill_cache, pages_mat):
    """Scatter a batched prefill cache (leaves (L, B, Hkv, S_b, hd)) into
    the page pool (leaves (L, P, Hkv, ps, hd)): row b's logical positions
    [i*ps, (i+1)*ps) land in pool page ``pages_mat[b, i]``. ``pages_mat``
    is (B, n_pp) with n_pp = ceil(S_b / ps); pad rows of a coalesced batch
    point every entry at the trash page (0). S_b not a multiple of ps is
    zero-padded up — the tail stays masked until decode overwrites it
    (write-before-validity, same invariant as the slot pool)."""
    pages_mat = jnp.asarray(pages_mat, jnp.int32)
    n_pp = pages_mat.shape[1]

    def leaf(p, u):
        L, B, Hkv, S_b, hd = u.shape
        ps = p.shape[3]
        if S_b < n_pp * ps:
            u = jnp.pad(u, ((0, 0), (0, 0), (0, 0),
                            (0, n_pp * ps - S_b), (0, 0)))
        u = u.reshape(L, B, Hkv, n_pp, ps, hd)
        u = jnp.moveaxis(u, 3, 2)                # (L, B, n_pp, Hkv, ps, hd)
        return p.at[:, pages_mat].set(u.astype(p.dtype))

    return jax.tree.map(leaf, pool, prefill_cache)
