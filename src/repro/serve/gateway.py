"""RoutedServer: the paper's router in front of an actual model pool.

A request batch is (i) embedded by the encoder stub, (ii) routed by a
trained router (MLP or K-means; the fused Pallas ``router_utility`` kernel
is the decision hot-path), (iii) grouped per chosen model, and (iv) served
by that model's prefill + decode loop. This is the deployment shape the
paper targets: per-request model selection under an accuracy/cost trade-off
λ chosen at inference time (§3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import mlp_router as R
from repro.data.encoder import encode
from repro.kernels import ops as kops
from repro.models import model as mdl
from repro.serve.kv_cache import extend_cache


@dataclasses.dataclass
class PoolModel:
    name: str
    cfg: ModelConfig
    params: dict
    cost_per_token: float


class RoutedServer:
    """λ is a per-request knob — no router retraining needed (§3)."""

    def __init__(self, pool: List[PoolModel], router_params: dict,
                 d_emb: int = 64, predict_fn: Optional[Callable] = None):
        self.pool = pool
        self.router = router_params
        self.d_emb = d_emb
        self._predict = predict_fn  # optional non-parametric router

    def route(self, prompts: List[str], lam: float) -> np.ndarray:
        x = jnp.asarray(encode(prompts, self.d_emb))
        if self._predict is not None:
            A, C = self._predict(x)
            return np.asarray(jnp.argmax(A - lam * C, axis=-1))
        h = R.trunk_apply(self.router, x)
        hd = self.router["heads"]
        choice, _ = kops.router_utility(h, hd["acc_w"], hd["acc_b"],
                                        hd["cost_w"], hd["cost_b"], lam)
        return np.asarray(choice)

    def generate(self, prompts: List[str], *, lam: float = 0.5,
                 max_new_tokens: int = 16,
                 tokenize: Optional[Callable] = None) -> Dict:
        """Route, group by model, serve each group batched."""
        choice = self.route(prompts, lam)
        results = [None] * len(prompts)
        cost = 0.0
        for m_idx in np.unique(choice):
            pm = self.pool[int(m_idx) % len(self.pool)]
            idx = np.where(choice == m_idx)[0]
            toks = self._tokenize([prompts[i] for i in idx], pm.cfg, tokenize)
            out = self._serve_batch(pm, toks, max_new_tokens)
            for j, i in enumerate(idx):
                results[i] = {"model": pm.name, "tokens": out[j].tolist()}
            cost += pm.cost_per_token * max_new_tokens * len(idx)
        return {"results": results, "total_cost": cost,
                "routing": choice.tolist()}

    @staticmethod
    def _tokenize(prompts, cfg, tokenize):
        if tokenize is not None:
            return tokenize(prompts)
        # stub tokenizer: stable hash per word
        L = max(max(len(p.split()) for p in prompts), 1)
        out = np.zeros((len(prompts), L), np.int32)
        for i, p in enumerate(prompts):
            for j, w in enumerate(p.split()):
                out[i, j] = hash(w) % (cfg.vocab - 1) + 1
        return out

    @staticmethod
    def _serve_batch(pm: PoolModel, toks: np.ndarray, max_new: int):
        cfg = pm.cfg
        B, S = toks.shape
        toks_j = jnp.asarray(toks)
        logits, _, cache = mdl.forward(pm.params, cfg, tokens=toks_j,
                                       logits_last_only=True,
                                       return_cache=True, q_chunk=64)
        cache = extend_cache(cache, S + max_new)
        out = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        step = jax.jit(lambda p, c, t, pos: mdl.decode_step(
            p, c, cfg, tokens=t, pos=pos))
        for t in range(max_new):
            out[:, t] = np.asarray(tok[:, 0])
            logits_t, cache = step(pm.params, cache, tok,
                                   jnp.int32(S + t))
            tok = jnp.argmax(logits_t[:, 0], axis=-1)[:, None].astype(jnp.int32)
        return out
