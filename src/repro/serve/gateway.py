"""RoutedServer: the paper's router in front of an actual model pool.

A request is (i) embedded by the encoder stub, (ii) routed by one
``repro.routers.Router`` — the MLP family decides via the fused Pallas
``router_utility`` kernel, the K-means family via the ``kmeans_assign``
kernel + cluster-level utility — and (iii) served by the chosen model.
This is the deployment shape the paper targets: per-request model
selection under an accuracy/cost trade-off λ chosen at inference time
(§3).

Serving runs through the continuous-batching engine by default
(``repro.serve.engine``): concurrent requests share one paged KV pool per
model (vLLM-style fixed-size pages + per-request page tables — each
request reserves only what its own length needs), same-bucket admissions
coalesce into one batched prefill, and everything decodes together in
chunked scans. ``EngineConfig(page_size=None)`` selects the uniform slot
pool (every slot reserves ``max_seq``). ``generate(engine=False)`` keeps
the original per-call path — the whole prompt batch group-padded per
model and decoded as one ``lax.scan`` (``scan_decode=False`` further
drops to the per-token debugging loop). SSM/hybrid archs always take the
per-call path (their state integrates over pad positions, so prompts are
served unpadded).

Hot-path discipline: every jitted function here is built ONCE per
(model config, static shape) and cached at module level — nothing is
re-jitted per request. Batch sizes and prompt lengths are bucketed to
powers of two so repeated traffic reuses compiled programs, and greedy
decode returns whole token matrices in one device→host transfer (no
per-token sync).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
import zlib
from typing import TYPE_CHECKING, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.data.encoder import encode
from repro.models import model as mdl

if TYPE_CHECKING:  # repro.fed is the higher layer — type-only import keeps
    from repro.fed.faults import FaultPlan  # serve → fed one-directional
    from repro.fed.harvest import HarvestStore
from repro.routers import Router
# TRACE_LOG lives in engine.py (bounded deque) and is re-exported here so
# `gateway.TRACE_LOG` keeps working for tests and callers; same for
# reset_trace_log.
from repro.serve.engine import (CANCELLED, EXPIRED, SHED, EngineConfig,
                                Outcome, ServeEngine, TRACE_LOG)
from repro.serve.engine import next_pow2 as _next_pow2
from repro.serve.engine import reset_trace_log  # noqa: F401
from repro.serve.kv_cache import extend_cache

#: un-reported harvest entries kept per server (submit → report_outcome);
#: oldest evicted beyond this so feedback-less traffic can't grow memory.
PENDING_EVAL_CAP = 8192


@dataclasses.dataclass
class PoolModel:
    name: str
    cfg: ModelConfig
    params: dict
    cost_per_token: float


@functools.lru_cache(maxsize=None)
def _decode_step_fn(cfg: ModelConfig):
    """Jitted single-token decode step, cached per model config (the
    per-token fallback path — never rebuilt per request batch)."""
    def step(params, cache, tok, pos):
        TRACE_LOG.append(("decode_step", cfg.name, tok.shape))
        return mdl.decode_step(params, cache, cfg, tokens=tok, pos=pos)
    return jax.jit(step)


@functools.lru_cache(maxsize=None)
def _serve_fn(cfg: ModelConfig, max_new: int):
    """Jitted prefill + greedy ``lax.scan`` decode, cached per
    (model config, max_new); distinct (B, S) buckets land in the jit
    tracing cache, so same-bucket traffic compiles nothing.

    ``last_pos`` (traced) is the true last prompt position inside the
    padded S bucket; decode continues from ``last_pos + 1`` and the cache
    slots holding pad prefill K/V are overwritten before they ever become
    attention-valid (validity is ``pos + 1``).
    """
    def serve(params, toks, last_pos):
        TRACE_LOG.append(("serve", cfg.name, toks.shape, max_new))
        S = toks.shape[1]
        logits, _, cache = mdl.forward(params, cfg, tokens=toks,
                                       logits_last_only=True,
                                       last_pos=last_pos,
                                       return_cache=True, q_chunk=64)
        cache = extend_cache(cache, S + max_new)
        tok0 = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

        def body(carry, t):
            tok, cache = carry
            logits_t, cache = mdl.decode_step(params, cache, cfg,
                                              tokens=tok,
                                              pos=last_pos + 1 + t)
            nxt = jnp.argmax(logits_t[:, 0], axis=-1)[:, None]
            return (nxt.astype(jnp.int32), cache), tok[:, 0]

        _, out = jax.lax.scan(body, (tok0, cache),
                              jnp.arange(max_new, dtype=jnp.int32))
        return out.T                                  # (B, max_new)

    return jax.jit(serve)


class RoutedServer:
    """λ is a per-request knob — no router retraining needed (§3).

    Takes ONE fitted ``Router`` (any registered family); the router's model
    dimension M must match the pool, checked at construction so a mismatch
    fails loudly instead of silently wrapping indices at serve time.
    """

    def __init__(self, pool: List[PoolModel], router: Router,
                 d_emb: Optional[int] = None,
                 engine_cfg: Optional[EngineConfig] = None,
                 harvest: "Optional[HarvestStore]" = None,
                 fault_plan: "Optional[FaultPlan]" = None,
                 max_retries: int = 2, retry_backoff: float = 0.0,
                 mesh=None):
        if not isinstance(router, Router):
            raise TypeError(
                "RoutedServer takes a repro.routers.Router — build one with "
                "routers.make(...) + routers.fit_federated(...) or "
                "routers.load(...)")
        if not router.initialized:
            raise ValueError("router has no fitted state — fit or load it "
                             "before serving")
        if router.num_models != len(pool):
            raise ValueError(
                f"router predicts over M={router.num_models} models but the "
                f"pool has {len(pool)} — onboard the missing models "
                "(router.onboard_model) or fix the pool")
        if d_emb is not None and d_emb != router.rcfg.d_emb:
            raise ValueError(
                f"d_emb={d_emb} does not match the router's embedding "
                f"dimension {router.rcfg.d_emb} — drop d_emb= to use the "
                "router's own")
        self.pool = pool
        self.router = router
        self.d_emb = router.rcfg.d_emb
        # One jitted decision function per router object. State and λ are
        # traced arguments — not baked-in constants — so in-place state
        # swaps and per-request λ never recompile or go stale; batch sizes
        # are bucketed below so repeat traffic hits the tracing cache.
        # A replaced router object (e.g. a different family swapped in)
        # rebuilds the function on the next route().
        self._route_fn = self._make_route_fn(router)
        self._route_fn_router = router
        # One continuous-batching engine per server: per-model slot pools
        # are allocated lazily on first traffic to that model. ``mesh``
        # selects cross-silo execution — KV pools sharded over the mesh's
        # "data"/"heads" axes, decode dispatched as one mesh program (see
        # ServeEngine); the per-request fallback path stays solo.
        self.mesh = mesh
        self.engine = ServeEngine(pool, engine_cfg, mesh=mesh)
        # Harvest layer (repro.fed): per-client EvalBuffers fed by routed
        # traffic. Outcome scores arrive asynchronously via
        # report_outcome(); un-reported entries wait (bounded) in
        # _pending_evals.
        self.harvest = harvest
        self._pending_evals: Dict[int, tuple] = {}
        # Bounded tombstones so unknown-rid errors can say WHY the rid is
        # gone (evicted by the pending-cap vs already reported) instead of
        # a bare KeyError.
        self._evicted_rids = collections.deque(maxlen=4096)
        self._reported_rids = collections.deque(maxlen=4096)
        #: bumped by every swap_router_state/add_model — the "versioned
        #: router state" the FedLoop publishes into the route path.
        self.router_version = 0
        # Fault tolerance: an optional FaultPlan (repro.fed.faults, duck-
        # typed — serve stays importable without fed) makes submit()
        # consult backend_fails() per attempt; failures retry with
        # exponential backoff, then degrade gracefully by re-routing to
        # the next-best model under the router's own utility.
        self.fault_plan = fault_plan
        self.max_retries = int(max_retries)
        self.retry_backoff = float(retry_backoff)
        self._submit_seq = 0
        #: observability counters for the resilience bench/tests.
        self.backend_failures = 0
        self.retries = 0
        self.failovers = 0
        #: expiries count as backend failures for harvest purposes (the
        #: router should learn an overloaded backend the same way it
        #: learns a crashed one); the tombstones make unknown-rid errors
        #: actionable and dedupe the expiry→failure accounting.
        self.expiry_failures = 0
        self._failed_rids = collections.deque(maxlen=4096)
        self._terminated_rids = collections.deque(maxlen=4096)

    @staticmethod
    def _make_route_fn(router: Router):
        def route_fn(state, x, lam):
            TRACE_LOG.append(("route", type(router).__name__, x.shape))
            return router.with_state(state).route(x, lam)
        return jax.jit(route_fn)

    def _route_x(self, x: np.ndarray, lam: float) -> np.ndarray:
        """Route pre-encoded query embeddings x: (B, d_emb) → (B,) model
        indices. The jitted decision fn takes state and λ as traced
        arguments, so hot-swapped router state and per-request λ hit the
        same compiled program (TRACE_LOG-pinned)."""
        if self.router is not self._route_fn_router:
            self._route_fn = self._make_route_fn(self.router)
            self._route_fn_router = self.router
        B = x.shape[0]
        B_b = _next_pow2(B)
        if B_b != B:
            x = np.concatenate([x, np.zeros((B_b - B, x.shape[1]),
                                            x.dtype)])
        choice = self._route_fn(self.router.state, jnp.asarray(x),
                                jnp.float32(lam))
        return np.asarray(choice)[:B]

    def route(self, prompts: List[str], lam: float) -> np.ndarray:
        return self._route_x(encode(prompts, self.d_emb), lam)

    # ----------------------------------------------------- router lifecycle
    def swap_router_state(self, state) -> None:
        """Hot-swap fitted router state under live traffic. The new state
        must be the same family and pytree structure (same-shape buffers),
        so it enters the cached route jit as a traced argument — ZERO
        retraces, no decode interruption; in-flight requests keep decoding
        against their already-routed models. Bumps ``router_version``."""
        new_router = self.router.with_state(state)
        old_l, old_s = jax.tree.flatten(self.router.state)
        new_l, new_s = jax.tree.flatten(state)
        shapes_match = (old_s == new_s and len(old_l) == len(new_l) and all(
            getattr(a, "shape", None) == getattr(b, "shape", None)
            and getattr(a, "dtype", None) == getattr(b, "dtype", None)
            for a, b in zip(old_l, new_l)))
        if not shapes_match:
            raise ValueError(
                "swap_router_state got a different state structure or "
                "buffer shapes — a structural change (new family, expanded "
                "pool) is an add_model/replacement, not a hot swap")
        self.router = new_router
        # keep the cached jit: the route fn closes over the old router
        # object only for with_state(), which rebuilds by class + rcfg —
        # identical for a same-family swap.
        self._route_fn_router = new_router
        self.router_version += 1

    def add_model(self, pm: PoolModel, router: Router) -> None:
        """Onboard a new pool model mid-run (§6.3): append it to the pool
        (the engine shares the list — its lane and compiled programs build
        lazily on first traffic) and install the expanded router. The route
        program re-traces ONCE for the new head shape; every decode
        program of existing models is untouched."""
        if router.num_models != len(self.pool) + 1:
            raise ValueError(
                f"add_model expects a router expanded to M={len(self.pool) + 1}"
                f" (got M={router.num_models}) — onboard the router first "
                "(router.onboard_model)")
        self.pool.append(pm)
        self.router = router
        # rebuild the route program for the new router object — the head
        # shape changed, so a retrace is due anyway, and a replacement of a
        # different family must not run through the old closure
        self._route_fn = self._make_route_fn(router)
        self._route_fn_router = router
        self.router_version += 1

    # -------------------------------------------------- engine streaming API
    def submit(self, prompt: str, *, lam: float = 0.5,
               max_new_tokens: int = 16,
               tokenize: Optional[Callable] = None,
               client_id: Optional[int] = None,
               x: Optional[np.ndarray] = None,
               deadline: Optional[int] = None,
               draft_model: Optional[int] = None) -> int:
        """Route one prompt and enqueue it on the continuous-batching
        engine; returns a request id. The request joins the routed model's
        shared decode batch at the next free slot — call ``step()`` to
        advance in-flight decoding or ``drain()`` to run to completion.

        ``x`` supplies a pre-computed query embedding (simulators, callers
        with a real encoder) instead of the stub ``encode``. With a
        ``harvest`` store attached and ``client_id`` given, the request is
        registered for evaluation harvesting: ``routed_model(rid)`` exposes
        the choice and ``report_outcome(rid, ...)`` appends the completed
        (x, model, outcome, cost) observation to that client's EvalBuffer.

        With a ``fault_plan`` attached, a failing backend is retried
        ``max_retries`` times with exponential backoff, then the request
        degrades gracefully: it re-routes to the next-best model under the
        router's own utility A − λ·C (excluding failed backends), counts
        the failover, and the harvest records the model that actually
        served it — the realized outcome, not the intended route.

        On a speculative engine (``EngineConfig.spec_k > 0``) the request
        is paired with a **drafter** from the same pool: ``draft_model``
        pins one by pool index; otherwise the gateway walks the router's
        own utility ranking A − λ·C over the pool and picks the
        highest-utility model that is strictly cheaper than the target
        (vocab-compatible attention archs only — the engine's
        constraints), falling back to the target itself. The router
        already ranks models by predicted quality on THIS query, so its
        best cheap model is exactly the drafter most likely to agree with
        the target and keep acceptance high.

        ``deadline`` bounds the request's lifetime in engine steps (see
        ``ServeEngine.submit``); an EXPIRED request counts as a backend
        failure for harvest purposes (zero-score outcome recorded against
        the routed model). A submit SHED by a full lane queue still
        returns its rid but is never harvest-registered — nothing was
        served, nothing to learn."""
        x_arr = (encode([prompt], self.d_emb)[0] if x is None
                 else np.asarray(x, np.float32).reshape(self.d_emb))
        m_idx = int(self._route_x(x_arr[None], lam)[0])
        if self.fault_plan is not None:
            m_idx = self._submit_with_failover(m_idx, x_arr, lam)
        toks = self._tokenize([prompt], self.pool[m_idx].cfg, tokenize)[0]
        if self.engine.ecfg.spec_k:
            draft = (int(draft_model) if draft_model is not None
                     else self._pick_draft(m_idx, x_arr, lam))
        elif draft_model is not None:
            raise ValueError("submit(draft_model=...) needs a speculative "
                             "engine — set EngineConfig.spec_k > 0")
        else:
            draft = None
        rid = self.engine.submit(m_idx, toks, max_new_tokens,
                                 deadline=deadline, draft=draft)
        if self.engine._status.get(rid) == SHED:
            self._terminated_rids.append(rid)
            return rid
        if self.harvest is not None and client_id is not None:
            cost_est = self.pool[m_idx].cost_per_token * max_new_tokens
            self._pending_evals[rid] = (int(client_id), x_arr, m_idx,
                                        cost_est)
            while len(self._pending_evals) > PENDING_EVAL_CAP:
                old = next(iter(self._pending_evals))
                self._pending_evals.pop(old)
                self._evicted_rids.append(old)
        return rid

    def _submit_with_failover(self, m_idx: int, x_arr: np.ndarray,
                              lam: float) -> int:
        """Resolve the backend that will actually serve this submission:
        retry transient failures with backoff, then walk down the router's
        utility ranking past hard failures. Raises RuntimeError only when
        every pool backend has failed."""
        seq = self._submit_seq
        self._submit_seq += 1
        plan = self.fault_plan
        failed: set = set()
        order = None
        attempt = 0
        while plan.backend_fails(m_idx, seq, attempt):
            self.backend_failures += 1
            if attempt < self.max_retries:
                attempt += 1
                self.retries += 1
                if self.retry_backoff > 0.0:
                    time.sleep(self.retry_backoff * 2.0 ** (attempt - 1))
                continue
            failed.add(m_idx)
            if len(failed) == len(self.pool):
                raise RuntimeError(
                    f"all {len(self.pool)} pool backends failed request "
                    f"#{seq} — nothing left to re-route to")
            if order is None:  # rank once, off the hot path
                A, C = self.router.predict(jnp.asarray(x_arr[None]))
                util = np.asarray(A[0] - lam * C[0])
                order = [int(i) for i in np.argsort(-util)]
            m_idx = next(i for i in order if i not in failed)
            self.failovers += 1
            attempt = 0
        return m_idx

    def _pick_draft(self, m_idx: int, x_arr: np.ndarray,
                    lam: float) -> int:
        """Router-paired drafter selection (speculative engines): among
        pool models that can legally draft for the target — attention
        archs sharing its vocab — and are strictly cheaper per token, pick
        the one the router itself ranks highest under A − λ·C on this
        query. Falls back to the target (self-speculation: always correct,
        never faster) when nothing cheaper qualifies. One predict() call
        per submit, same ranking the failover path uses."""
        tgt = self.pool[m_idx]
        cand = [i for i, pm in enumerate(self.pool)
                if i != m_idx
                and pm.cost_per_token < tgt.cost_per_token
                and pm.cfg.vocab == tgt.cfg.vocab
                and pm.cfg.arch_type not in ("ssm", "hybrid")]
        if not cand:
            return m_idx
        A, C = self.router.predict(jnp.asarray(x_arr[None]))
        util = np.asarray(A[0] - lam * C[0])
        return max(cand, key=lambda i: util[i])

    def _unknown_rid(self, rid: int) -> ValueError:
        """A specific, actionable error for a rid with no pending eval:
        says which rid and *why* it is unknown."""
        if rid in self._evicted_rids:
            why = (f"it was evicted by the pending-eval cap "
                   f"(PENDING_EVAL_CAP={PENDING_EVAL_CAP}) — report "
                   "outcomes sooner or raise the cap")
        elif rid in self._reported_rids:
            why = "its outcome was already reported (each rid reports once)"
        elif rid in self._failed_rids:
            why = ("it EXPIRED past its deadline — the gateway already "
                   "recorded the expiry as a zero-score backend failure")
        elif rid in self._terminated_rids:
            why = ("it was cancelled or shed before serving — nothing was "
                   "generated, so there is no outcome to report")
        else:
            why = ("it was never harvest-registered — submit() it with "
                   "client_id= and attach a HarvestStore to track routing "
                   "outcomes")
        return ValueError(f"request {rid} has no pending evaluation: {why}")

    def routed_model(self, rid: int) -> int:
        """Model index a harvest-registered request was routed to.
        Raises ValueError for an unknown/already-reported/evicted rid."""
        try:
            return self._pending_evals[rid][2]
        except KeyError:
            raise self._unknown_rid(rid) from None

    def report_outcome(self, rid: int, score: float,
                       cost: Optional[float] = None) -> None:
        """Client feedback closes the harvest loop: append the completed
        (query embedding, routed model, outcome score, cost) observation to
        the submitting client's EvalBuffer. ``cost`` defaults to the
        submit-time estimate (cost_per_token × max_new). Raises ValueError
        for an unknown/already-reported/evicted rid."""
        try:
            client_id, x_arr, m_idx, cost_est = self._pending_evals.pop(rid)
        except KeyError:
            raise self._unknown_rid(rid) from None
        self._reported_rids.append(rid)
        self.harvest.record(client_id, x_arr, m_idx, float(score),
                            float(cost if cost is not None else cost_est))

    def cancel(self, rid: int) -> str:
        """Cancel an engine request (see ``ServeEngine.cancel``) and drop
        its pending harvest registration — nothing was served, so there is
        no outcome to report. Returns the request's typed status."""
        status = self.engine.cancel(rid)
        if self._pending_evals.pop(rid, None) is not None:
            self._terminated_rids.append(rid)
        return status

    def status(self, rid: int) -> str:
        """Typed lifecycle status of an engine request (see
        ``ServeEngine.status``)."""
        return self.engine.status(rid)

    def _absorb_outcomes(self, results) -> None:
        """React to typed non-completion terminals from the engine.
        EXPIRED is a backend failure for harvest purposes: the overloaded
        backend gets a zero-score outcome recorded against it (the router
        learns to avoid it, exactly like a crashed backend in the PR 7
        failover path) and ``backend_failures``/``expiry_failures`` bump.
        CANCELLED / SHED just drop the pending registration — nothing was
        served, nothing to learn."""
        for rid, payload in results:
            if not isinstance(payload, Outcome):
                continue
            if payload.status == EXPIRED:
                if rid in self._failed_rids:
                    continue
                self._failed_rids.append(rid)
                self.backend_failures += 1
                self.expiry_failures += 1
                ent = self._pending_evals.pop(rid, None)
                if ent is not None and self.harvest is not None:
                    client_id, x_arr, m_idx, cost_est = ent
                    self.harvest.record(client_id, x_arr, m_idx, 0.0,
                                        cost_est)
            elif payload.status in (CANCELLED, SHED):
                if self._pending_evals.pop(rid, None) is not None:
                    self._terminated_rids.append(rid)

    def step(self):
        """Advance every busy engine lane one chunk (admissions happen at
        chunk boundaries). Returns [(request id, result)] for requests
        that reached a terminal state — np tokens for completions, a typed
        ``Outcome`` for expired/cancelled/shed requests (absorbed into the
        harvest accounting, see ``_absorb_outcomes``)."""
        finished = self.engine.step()
        self._absorb_outcomes(finished)
        return finished

    def drain(self, rids=None) -> Dict[int, np.ndarray]:
        """Run the engine until idle; returns {request id: result} (np
        tokens, or a typed ``Outcome`` for non-completions). ``rids``
        passes through to ``ServeEngine.drain``: an iterable of request
        ids drains until exactly those terminate, leaving other in-flight
        streams' results in place. (The passthrough was dropped when the
        engine grew the parameter — callers interleaving submit streams
        through the gateway silently drained, and CLEARED, everything.)"""
        out = self.engine.drain(rids)
        self._absorb_outcomes(out.items())
        return out

    # ------------------------------------------------------------- generate
    def generate(self, prompts: List[str], *, lam: float = 0.5,
                 max_new_tokens: int = 16,
                 tokenize: Optional[Callable] = None,
                 scan_decode: bool = True, engine: bool = True) -> Dict:
        """Route, then serve every prompt as its own request through the
        continuous-batching engine (per-model slot pools, chunked shared
        decode). Each prompt is prefilled at its own pow2 length bucket, so
        results are bit-identical to serving it alone.

        engine=False restores the per-call grouped path: each model's
        prompts are padded to one (B, S) batch and decoded together —
        shorter prompts then attend pad positions of the group's longest.
        scan_decode=False (with engine=False) further selects the
        per-token fallback loop (one host sync per token) — same tokens as
        the grouped scan, kept for debugging/comparison. SSM/hybrid models
        always take the per-call path (no padded slot sharing).
        """
        choice = self.route(prompts, lam)
        results = [None] * len(prompts)
        cost = 0.0
        rid_to_slot = {}
        for m_idx in np.unique(choice):
            pm = self.pool[int(m_idx)]
            idx = np.where(choice == m_idx)[0]
            use_engine = (engine and scan_decode
                          and pm.cfg.arch_type not in ("ssm", "hybrid"))
            if use_engine:
                for i in idx:
                    toks_i = self._tokenize([prompts[i]], pm.cfg, tokenize)[0]
                    if not self.engine.fits(len(toks_i), max_new_tokens):
                        # request exceeds a slot region — serve it per-call
                        # (extend_cache path), like the pre-engine gateway
                        out = self._serve_batch(pm, toks_i[None],
                                                max_new_tokens)
                        results[i] = {"model": pm.name,
                                      "tokens": out[0].tolist()}
                        continue
                    rid = self.engine.submit(int(m_idx), toks_i,
                                             max_new_tokens)
                    rid_to_slot[rid] = (int(i), pm.name)
            else:
                toks = self._tokenize([prompts[i] for i in idx], pm.cfg,
                                      tokenize)
                out = self._serve_batch(pm, toks, max_new_tokens,
                                        scan_decode=scan_decode)
                for j, i in enumerate(idx):
                    results[i] = {"model": pm.name, "tokens": out[j].tolist()}
            cost += pm.cost_per_token * max_new_tokens * len(idx)
        if rid_to_slot:
            for rid, toks in self.engine.drain(rid_to_slot).items():
                i, name = rid_to_slot[rid]
                results[i] = {"model": name, "tokens": toks.tolist()}
        return {"results": results, "total_cost": cost,
                "routing": choice.tolist()}

    @staticmethod
    def _tokenize(prompts, cfg, tokenize):
        if tokenize is not None:
            return tokenize(prompts)
        # stub tokenizer: crc32 is stable across processes (unlike builtin
        # hash, which varies with PYTHONHASHSEED)
        L = max(max(len(p.split()) for p in prompts), 1)
        out = np.zeros((len(prompts), L), np.int32)
        for i, p in enumerate(prompts):
            for j, w in enumerate(p.split()):
                out[i, j] = zlib.crc32(w.encode("utf-8")) % (cfg.vocab - 1) + 1
        return out

    @staticmethod
    def _serve_batch(pm: PoolModel, toks: np.ndarray, max_new: int, *,
                     scan_decode: bool = True):
        cfg = pm.cfg
        B, S = toks.shape
        if scan_decode:
            # Bucket (B, S, max_new) to powers of two so repeat traffic
            # reuses the compiled program and the program cache stays
            # bounded. Greedy decode is prefix-stable, so decoding to the
            # bucket length and slicing changes nothing. SSM/hybrid states
            # integrate over every prefill position, so their prompts are
            # served unpadded (cache hits still cover repeated lengths).
            B_b = _next_pow2(B)
            S_b = S if cfg.arch_type in ("ssm", "hybrid") else _next_pow2(S)
            toks_p = np.zeros((B_b, S_b), np.int32)
            toks_p[:B, :S] = toks
            out = _serve_fn(cfg, _next_pow2(max_new))(
                pm.params, jnp.asarray(toks_p), jnp.int32(S - 1))
            return np.asarray(out)[:B, :max_new]

        # fallback: per-token Python loop (cached jitted step)
        step = _decode_step_fn(cfg)
        toks_j = jnp.asarray(toks)
        logits, _, cache = mdl.forward(pm.params, cfg, tokens=toks_j,
                                       logits_last_only=True,
                                       return_cache=True, q_chunk=64)
        cache = extend_cache(cache, S + max_new)
        out = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for t in range(max_new):
            out[:, t] = np.asarray(tok[:, 0])
            logits_t, cache = step(pm.params, cache, tok, jnp.int32(S + t))
            tok = jnp.argmax(logits_t[:, 0], axis=-1)[:, None].astype(jnp.int32)
        return out
