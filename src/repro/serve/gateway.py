"""RoutedServer: the paper's router in front of an actual model pool.

A request batch is (i) embedded by the encoder stub, (ii) routed by one
``repro.routers.Router`` — the MLP family decides via the fused Pallas
``router_utility`` kernel, the K-means family via the ``kmeans_assign``
kernel + cluster-level utility — (iii) grouped per chosen model, and (iv)
served by that model's prefill + decode loop. This is the deployment shape
the paper targets: per-request model selection under an accuracy/cost
trade-off λ chosen at inference time (§3).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.data.encoder import encode
from repro.models import model as mdl
from repro.routers import Router
from repro.serve.kv_cache import extend_cache


@dataclasses.dataclass
class PoolModel:
    name: str
    cfg: ModelConfig
    params: dict
    cost_per_token: float


class RoutedServer:
    """λ is a per-request knob — no router retraining needed (§3).

    Takes ONE fitted ``Router`` (any registered family); the router's model
    dimension M must match the pool, checked at construction so a mismatch
    fails loudly instead of silently wrapping indices at serve time.
    """

    def __init__(self, pool: List[PoolModel], router: Router,
                 d_emb: Optional[int] = None):
        if not isinstance(router, Router):
            raise TypeError(
                "RoutedServer takes a repro.routers.Router — build one with "
                "routers.make(...) + routers.fit_federated(...) or "
                "routers.load(...)")
        if not router.initialized:
            raise ValueError("router has no fitted state — fit or load it "
                             "before serving")
        if router.num_models != len(pool):
            raise ValueError(
                f"router predicts over M={router.num_models} models but the "
                f"pool has {len(pool)} — onboard the missing models "
                "(router.onboard_model) or fix the pool")
        if d_emb is not None and d_emb != router.rcfg.d_emb:
            raise ValueError(
                f"d_emb={d_emb} does not match the router's embedding "
                f"dimension {router.rcfg.d_emb} — drop d_emb= to use the "
                "router's own")
        self.pool = pool
        self.router = router
        self.d_emb = router.rcfg.d_emb

    def route(self, prompts: List[str], lam: float) -> np.ndarray:
        x = jnp.asarray(encode(prompts, self.d_emb))
        return np.asarray(self.router.route(x, lam))

    def generate(self, prompts: List[str], *, lam: float = 0.5,
                 max_new_tokens: int = 16,
                 tokenize: Optional[Callable] = None) -> Dict:
        """Route, group by model, serve each group batched."""
        choice = self.route(prompts, lam)
        results = [None] * len(prompts)
        cost = 0.0
        for m_idx in np.unique(choice):
            pm = self.pool[int(m_idx)]
            idx = np.where(choice == m_idx)[0]
            toks = self._tokenize([prompts[i] for i in idx], pm.cfg, tokenize)
            out = self._serve_batch(pm, toks, max_new_tokens)
            for j, i in enumerate(idx):
                results[i] = {"model": pm.name, "tokens": out[j].tolist()}
            cost += pm.cost_per_token * max_new_tokens * len(idx)
        return {"results": results, "total_cost": cost,
                "routing": choice.tolist()}

    @staticmethod
    def _tokenize(prompts, cfg, tokenize):
        if tokenize is not None:
            return tokenize(prompts)
        # stub tokenizer: crc32 is stable across processes (unlike builtin
        # hash, which varies with PYTHONHASHSEED)
        L = max(max(len(p.split()) for p in prompts), 1)
        out = np.zeros((len(prompts), L), np.int32)
        for i, p in enumerate(prompts):
            for j, w in enumerate(p.split()):
                out[i, j] = zlib.crc32(w.encode("utf-8")) % (cfg.vocab - 1) + 1
        return out

    @staticmethod
    def _serve_batch(pm: PoolModel, toks: np.ndarray, max_new: int):
        cfg = pm.cfg
        B, S = toks.shape
        toks_j = jnp.asarray(toks)
        logits, _, cache = mdl.forward(pm.params, cfg, tokens=toks_j,
                                       logits_last_only=True,
                                       return_cache=True, q_chunk=64)
        cache = extend_cache(cache, S + max_new)
        out = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        step = jax.jit(lambda p, c, t, pos: mdl.decode_step(
            p, c, cfg, tokens=t, pos=pos))
        for t in range(max_new):
            out[:, t] = np.asarray(tok[:, 0])
            logits_t, cache = step(pm.params, cache, tok,
                                   jnp.int32(S + t))
            tok = jnp.argmax(logits_t[:, 0], axis=-1)[:, None].astype(jnp.int32)
        return out
