"""``repro.evalbench`` — RouterBench-style evaluation harness.

The paper's headline claim (federation widens effective model coverage and
improves the accuracy–cost frontier) needs more than hand-rolled frontier
plots to be credible. This package supplies the RouterBench-style
evidence chain:

  * **many-model pools** (``pools``) — corpora with enough models that no
    single one dominates the frontier;
  * **frontier sweeps + AIQ** (``metrics``) — λ-swept accuracy–cost
    frontiers collapsed to a scalar (Average Improvement in Quality:
    normalized area under the frontier's upper envelope), plus the
    zero-router / best-single / random / oracle reference points;
  * **robustness scenarios** (``perturb``) — seed-deterministic
    paraphrase-style embedding drift and adversarial queries that flip
    routing decisions within a norm budget;
  * **harness** (``harness``) — runs every registered router family
    federated vs client-local over the scenarios, offline over splits or
    online through the ``FedLoop`` — the engine behind
    ``BENCH_routerbench.json``.
"""
from repro.evalbench.harness import (  # noqa: F401
    eval_scenarios,
    offline_routerbench,
    online_routerbench,
)
from repro.evalbench.metrics import (  # noqa: F401
    aiq,
    reference_points,
    sweep,
)
from repro.evalbench.perturb import (  # noqa: F401
    adversarial_queries,
    paraphrase_drift,
)
from repro.evalbench.pools import make_pool_corpus, pool_table  # noqa: F401
