"""Robustness scenarios: perturbed and adversarial queries.

"How Robust Are Router-LLMs?" shows routing decisions are brittle under
paraphrase and adversarial rephrasing. The real text channel is stubbed in
this repro (queries arrive as embeddings), so both scenarios act in
embedding space:

  * ``paraphrase_drift`` — Gaussian jitter of the query embedding: the
    encoder-space effect of a meaning-preserving rewrite (sentence-encoder
    neighborhoods are locally isotropic at small radii). Scoring keeps the
    query's true tables: the router sees a moved representation of the
    same underlying task.
  * ``adversarial_queries`` — minimal interpolations toward a "donor"
    query that the router sends elsewhere, binary-searched to the decision
    boundary and kept only within a relative norm budget. Family-agnostic
    (needs only ``route``), fully deterministic, and measures exactly the
    failure RouterBench-style robustness audits probe: how small a
    representation change flips the routing decision.
"""
from __future__ import annotations

import jax
import numpy as np


def paraphrase_drift(key, x, sigma: float):
    """Seed-deterministic embedding perturbation: x + σ·ε, ε ~ N(0, I)."""
    return x + sigma * jax.random.normal(key, np.shape(x))


def adversarial_queries(router, x, lam: float, *, budget: float = 0.35,
                        steps: int = 10) -> tuple[np.ndarray, dict]:
    """Adversarial routing-flip queries within a relative L2 budget.

    For every query, take the nearest donor query the router routes to a
    *different* model at the same λ, binary-search the smallest
    interpolation toward it that still flips the decision (the donor
    endpoint flips by construction), and keep the perturbed query iff
    ‖δ‖ ≤ budget·‖x‖. Queries with no donor or over budget stay clean.

    Returns (x_adv (Q,d), {"flip_rate", "mean_rel_norm"}). Deterministic:
    no randomness, only the router's own decision boundary.
    """
    x = np.asarray(x, np.float64)
    Q = x.shape[0]
    m0 = np.asarray(router.route(x, lam))

    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    same = m0[:, None] == m0[None, :]
    d2 = np.where(same, np.inf, d2)
    donor = d2.argmin(axis=1)
    has_donor = np.isfinite(d2[np.arange(Q), donor])
    xd = x[donor]

    lo = np.zeros(Q)
    hi = np.ones(Q)
    for _ in range(steps):
        mid = 0.5 * (lo + hi)
        xm = x + mid[:, None] * (xd - x)
        flips = np.asarray(router.route(xm, lam)) != m0
        hi = np.where(flips, mid, hi)
        lo = np.where(flips, lo, mid)

    delta = hi[:, None] * (xd - x)
    rel = np.linalg.norm(delta, axis=1) / np.maximum(
        np.linalg.norm(x, axis=1), 1e-12)
    keep = has_donor & (rel <= budget)
    x_adv = np.where(keep[:, None], x + delta, x)
    flipped = keep & (np.asarray(router.route(x_adv, lam)) != m0)
    x_adv = np.where(flipped[:, None], x_adv, x)
    return x_adv.astype(np.float32), {
        "flip_rate": float(flipped.mean()),
        "mean_rel_norm": float(rel[flipped].mean()) if flipped.any() else 0.0,
    }
