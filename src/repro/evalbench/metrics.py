"""Frontier sweeps and AIQ-style scalar summaries (RouterBench, Hu et al.).

AIQ here is the normalized area under the non-decreasing upper envelope of
the λ-swept accuracy–cost frontier — ``core.policy.frontier_auc`` — i.e.
the average quality a router buys per unit of the observed cost range.
A single point (a fixed model, a random router) degenerates to its
accuracy, so every reference point lives on the same scale as the routers.

Reference points (RouterBench's "zero router" analysis):
  * ``zero_router`` — the frontier of the *models themselves*: each model
    is one (mean cost, mean acc) point; routing must beat the upper
    envelope of linear interpolations between them to be worth running;
  * ``best_single`` — the highest-accuracy single model;
  * ``random`` — uniform-random routing (mean of the model means);
  * ``oracle`` — routing with the true tables (the ceiling).
"""
from __future__ import annotations

import numpy as np

from repro.core import policy


def aiq(costs, accs) -> float:
    """Scalar frontier summary: normalized area under the upper envelope
    of accuracy as a function of cost (degenerates to the accuracy itself
    for a single point)."""
    return policy.frontier_auc(costs, accs)


def sweep(predict_fn, test: dict, *, lams=None, x=None) -> dict:
    """λ-swept frontier of one router on one test draw.

    test: {"x": (Q,d), "acc_table": (Q,M), "cost_table": (Q,M)} — route
    with the router's *estimates*, score with the *true* tables. ``x``
    overrides the routed embeddings (perturbation scenarios route on the
    perturbed view while scoring keeps following the true per-query
    tables). Returns {"costs", "accs", "aiq"}.
    """
    x_in = test["x"] if x is None else x
    costs, accs, auc = policy.eval_router(
        predict_fn, x_in, test["acc_table"], test["cost_table"], lams)
    return {"costs": costs, "accs": accs, "aiq": float(auc)}


def reference_points(test: dict, *, lams=None) -> dict:
    """The router-free reference points for one test draw (see module
    docstring). Returns {"zero_router_aiq", "best_single_aiq",
    "random_aiq", "oracle_aiq", "models": [(cost, acc), ...]}."""
    acc_t = np.asarray(test["acc_table"], np.float64)
    cost_t = np.asarray(test["cost_table"], np.float64)
    m_acc = acc_t.mean(axis=0)                      # (M,)
    m_cost = cost_t.mean(axis=0)
    zero = aiq(m_cost, m_acc)
    best_single = float(m_acc.max())
    random = float(m_acc.mean())
    o_costs, o_accs = policy.frontier(test["acc_table"], test["cost_table"],
                                      test["acc_table"], test["cost_table"],
                                      lams)
    return {
        "zero_router_aiq": zero,
        "best_single_aiq": best_single,
        "random_aiq": random,
        "oracle_aiq": aiq(o_costs, o_accs),
        "models": [(float(c), float(a)) for c, a in zip(m_cost, m_acc)],
    }
