"""The RouterBench-style harness: every family, federated vs client-local,
clean vs perturbed — offline over splits or online through the FedLoop.

Offline protocol (``offline_routerbench``): one many-model corpus, one
federated split; per router family, fit once federated over all clients
and once per client on its own slice (the no-federation deployment), then
score both on the global test draw under each robustness scenario. The
paper's claim is the gap: sparse per-client coverage starves the local
fits on models they never logged, while federation pools the coverage —
and the gap should *survive perturbation* (a router that only memorized
exact embeddings loses its frontier under drift).

Online protocol (``online_routerbench``): the same comparison live —
``fed.scenarios.run_online_vs_frozen`` with embedding-perturbation drift
switched on, any cold-startable family.

Everything is keyed, so both protocols are bit-deterministic: CI enforces
"federated AIQ ≥ client-local AIQ" on the smoke run without tolerance
fudge (see benchmarks/perf_suite.py and ci.yml).
"""
from __future__ import annotations

import zlib
from typing import Optional, Sequence

import jax
import numpy as np

from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.data.partition import client_slice, federated_split
from repro.evalbench.metrics import reference_points, sweep
from repro.evalbench.perturb import adversarial_queries, paraphrase_drift
from repro.evalbench.pools import make_pool_corpus, pool_table

SCENARIOS = ("clean", "paraphrase", "adversarial")


def eval_scenarios(router, test: dict, key, *, sigma: float = 0.25,
                   adv_budget: float = 0.35, adv_lam: float = 0.5,
                   lams=None) -> dict:
    """Score one fitted router on one test draw under every scenario.

    Routing always runs on the scenario's view of the embeddings; scoring
    always uses the clean queries' true tables (the task didn't change —
    its representation did). Returns {scenario: {"aiq", ...}} with the
    full frontier curves included per scenario.
    """
    out = {"clean": sweep(router.predict, test, lams=lams)}
    xp = paraphrase_drift(key, test["x"], sigma)
    out["paraphrase"] = sweep(router.predict, test, x=xp, lams=lams)
    x_adv, info = adversarial_queries(router, test["x"], adv_lam,
                                      budget=adv_budget)
    out["adversarial"] = {**sweep(router.predict, test, x=x_adv, lams=lams),
                          **info}
    return out


def _aiq_only(res: dict) -> dict:
    """Strip the frontier curves down to JSON-friendly scalars."""
    keep = ("aiq", "flip_rate", "mean_rel_norm")
    return {sc: {k: v for k, v in d.items() if k in keep}
            for sc, d in res.items()}


def offline_routerbench(key, *, rcfg: RouterConfig, fcfg: FedConfig,
                        families: Optional[Sequence[str]] = None,
                        corpus: Optional[dict] = None,
                        sigma: float = 0.25, adv_budget: float = 0.35,
                        adv_lam: float = 0.5, local_steps: int = 400,
                        lams=None) -> dict:
    """The offline benchmark: {family: {"federated": {scenario: {"aiq"}},
    "client_local": {scenario: {"aiq"}}}} plus pool/reference context.

    ``client_local`` scenario AIQs are means over the per-client fits,
    each scored on the same global test draw — the deployment where every
    client is on its own. The paraphrase perturbation is drawn once per
    benchmark (same drifted embeddings for every router, fair comparison);
    the adversarial scenario attacks each router at its *own* decision
    boundary (per-router worst case, the robustness-audit convention).
    """
    k_corpus, k_split, k_pert, k_fit = jax.random.split(key, 4)
    if corpus is None:
        corpus = make_pool_corpus(k_corpus, n_models=rcfg.num_models,
                                  d_emb=rcfg.d_emb)
    split = federated_split(k_split, corpus, fcfg)
    test = split["test_global"]
    results = {
        "n_models": int(corpus["n_models"]),
        "n_clients": int(fcfg.num_clients),
        "pool": pool_table(corpus),
        "reference": reference_points(test, lams=lams),
        "families": {},
    }
    for name in (families if families is not None else routers.available()):
        # crc32, not hash(): str hashing is salted per process and would
        # break run-to-run determinism
        k_fed, k_loc = jax.random.split(
            jax.random.fold_in(k_fit, zlib.crc32(name.encode()) % (2 ** 31)))
        fed, _ = routers.fit_federated(routers.make(name, rcfg),
                                       split["train"], fcfg, key=k_fed)
        fed_res = eval_scenarios(fed, test, k_pert, sigma=sigma,
                                 adv_budget=adv_budget, adv_lam=adv_lam,
                                 lams=lams)
        local_kw = ({"steps": local_steps}
                    if routers.get(name).parametric else {})
        per_client = []
        for c in range(fcfg.num_clients):
            data_c = client_slice(split["train"], c)
            if float(np.asarray(data_c["w"]).sum()) < 2:
                continue  # a starved client has nothing to fit on
            loc, _ = routers.fit_local(routers.make(name, rcfg), data_c,
                                       fcfg, key=jax.random.fold_in(k_loc, c),
                                       **local_kw)
            per_client.append(eval_scenarios(loc, test, k_pert, sigma=sigma,
                                             adv_budget=adv_budget,
                                             adv_lam=adv_lam, lams=lams))
        local_mean = {sc: {"aiq": float(np.mean([r[sc]["aiq"]
                                                 for r in per_client]))}
                      for sc in SCENARIOS}
        results["families"][name] = {
            "federated": _aiq_only(fed_res),
            "client_local": local_mean,
            "clients_fit": len(per_client),
        }
    return results


def online_routerbench(*, family: str = "mf", embed_sigma: float = 0.5,
                       cfg=None, seed: int = 0, **kw) -> dict:
    """The online benchmark: live traffic with embedding-perturbation
    drift (phases ≥ 1 route on a moved representation), FedLoop-maintained
    router vs frozen client-local fits. Thin front-end over
    ``fed.scenarios.run_online_vs_frozen`` — AUC ≡ AIQ here (both are the
    normalized frontier area)."""
    from repro.fed.scenarios import ScenarioConfig, run_online_vs_frozen
    if cfg is None:
        cfg = ScenarioConfig(embed_sigma=embed_sigma)
    res = run_online_vs_frozen(cfg, family=family, seed=seed, **kw)
    return {"family": family, "embed_sigma": float(cfg.embed_sigma), **res}
