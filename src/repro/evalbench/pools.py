"""Many-model pool construction for RouterBench-style evaluation.

RouterBench's credibility argument starts with pool size: with only a
handful of models, a degenerate "always pick the big one" policy looks
like routing. ``make_pool_corpus`` builds corpora whose model pool is
wide enough (default 16 > RouterBench's 11) that the frontier has many
non-dominated price points, and ``pool_table`` summarizes the pool the
way RouterBench's model table does — so a benchmark report can show *what*
was routed over, not just the headline number.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import make_eval_corpus


def make_pool_corpus(key, *, n_models: int = 16, n_queries: int = 4000,
                     n_tasks: int = 8, d_emb: int = 64, **kw) -> dict:
    """A synthetic evaluation corpus with a many-model pool (defaults
    upsized from the paper's 11-model RouterBench pool). Extra keywords
    forward to ``data.synthetic.make_eval_corpus``."""
    return make_eval_corpus(key, n_queries=n_queries, n_tasks=n_tasks,
                            n_models=n_models, d_emb=d_emb, **kw)


def pool_table(corpus: dict) -> list:
    """Per-model pool summary: [{"model", "mean_acc", "mean_cost",
    "wins"}] where "wins" counts the queries the model tops on true
    accuracy — a pool is routing-worthy iff wins spread over many models."""
    acc = np.asarray(corpus["acc_table"], np.float64)
    cost = np.asarray(corpus["cost_table"], np.float64)
    winners = acc.argmax(axis=1)
    return [{
        "model": m,
        "mean_acc": float(acc[:, m].mean()),
        "mean_cost": float(cost[:, m].mean()),
        "wins": int((winners == m).sum()),
    } for m in range(acc.shape[1])]
