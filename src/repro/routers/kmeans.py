"""Nonparametric K-Means-Router behind the unified interface (§4.2, Alg. 2).

Wraps ``core/kmeans_router.py``. Fitting is the one-shot federated
statistics protocol — there are no rounds and no loss. The decision hot
path (``route``) is the Pallas ``kmeans_assign`` kernel followed by a
cluster-level utility argmax: the (K, M) utility table collapses to one
best model per cluster, so routing a query is assign + gather.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import kmeans_router as KR
from repro.kernels import ops as kops
from repro.routers.base import Router
from repro.routers.registry import register


@register("kmeans")
class KMeansRouter(Router):
    parametric = False

    # ------------------------------------------------------------- interface

    def init(self, key) -> "KMeansRouter":
        """One-shot family: there is no pre-fit state. Returns self so
        ``make(...).init(key)`` is family-agnostic at call sites."""
        return self

    def predict(self, x):
        self._require_state()
        return KR.predict(self.state, x)

    def route(self, x, lam):
        """Hot path: nearest global center (Pallas kernel) → precomputed
        per-cluster best model under U_λ."""
        self._require_state()
        assign = kops.kmeans_assign(x, self.state["centroids"])
        best = jnp.argmax(self.state["A"] - lam * self.state["C"], axis=-1)
        return best[assign]

    def _state_num_models(self) -> int:
        return int(self.state["A"].shape[1])

    # ------------------------------------------------------------ onboarding

    def onboard_model(self, calib, **kw) -> "KMeansRouter":
        """§6.3, training-free: estimate the new model's per-cluster stats
        from calibration evals {"x","acc","cost","w"}."""
        self._require_state()
        return self.with_state(
            KR.add_model_stats(self.state, calib, c_max=self.rcfg.c_max))

    def onboard_clients(self, data_new, **kw) -> "KMeansRouter":
        """App. D.3, training-free: count-weighted merge of the new
        clients' statistics against the existing centers."""
        self._require_state()
        return self.with_state(
            KR.merge_client_stats(self.state, data_new, self.rcfg,
                                  num_models=self.num_models))

    # --------------------------------------------------------------- fitting

    def _fit_federated(self, key, data, fcfg, *, rounds=None, eval_fn=None,
                       mesh=None, client_mask=None, **kw):
        """Alg. 2: one-shot — local K-means upload, server K-means over
        centroids, one statistics round. ``rounds`` does not apply (and is
        ignored); fcfg is accepted for signature parity with parametric
        families. ``mesh=Mesh(..., ("clients",))`` runs the per-client
        local stage device-parallel under ``shard_map`` — bit-for-bit the
        in-process protocol on a fixed key (no client_mask on that path);
        parametric-only knobs are rejected rather than silently dropped."""
        if kw:
            raise ValueError("kmeans fit_federated got unsupported "
                             f"options: {', '.join(sorted(kw))}")
        if mesh is not None:
            if client_mask is not None:
                raise ValueError("the kmeans mesh path supports only the "
                                 "plain protocol — drop client_mask= or "
                                 "mesh=")
            state = KR.fed_kmeans_router_sharded(
                key, data, self.rcfg, num_models=self._num_models,
                mesh=mesh)
        else:
            state = KR.fed_kmeans_router(key, data, self.rcfg,
                                         num_models=self._num_models,
                                         client_mask=client_mask)
        new = self.with_state(state)
        hist = {"loss": [], "eval": [eval_fn(new)] if eval_fn else []}
        return new, hist

    def _fit_local(self, key, data_i, fcfg, *, k=None, **kw):
        """Client-local (no-FL) baseline: own K-means + own statistics.
        With ``k=rcfg.k_global`` on pooled data this is the centralized
        baseline."""
        state = KR.local_kmeans_router(key, data_i, self.rcfg,
                                       num_models=self._num_models, k=k)
        return self.with_state(state), {"loss": []}
