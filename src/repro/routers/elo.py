"""Nonparametric similarity-weighted Elo/ranking router (one-shot, Alg. 2).

Wraps ``core/elo_router.py``. Fitting is the one-shot federated statistics
protocol — federated K-means anchors, then one round of similarity-weighted
evaluation sums whose server aggregation is plain addition. The decision
hot path reuses the fused Pallas ``router_utility`` kernel with the anchor
similarity weights as features: A = sigmoid(s·R / s_elo) and C = s·C are
both linear heads over s, exactly the kernel's contract.

Unlike the K-means family, ``init(key)`` returns a *fitted* uninformative
prior state (flat ratings over random anchors) with the same pytree
structure as any real fit, so a gateway can serve from a cold start and
hot-swap the first real fit in without retracing.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import elo_router as EL
from repro.kernels import ops as kops
from repro.routers.base import Router
from repro.routers.registry import register


@register("elo")
class EloRouter(Router):
    parametric = False

    # ------------------------------------------------------------- interface

    def init(self, key) -> "EloRouter":
        """Cold-start prior state (see ``core.elo_router.prior_state``) —
        shape-compatible with every fit of the same (k_global, M)."""
        return self.with_state(
            EL.prior_state(key, self.rcfg, num_models=self._num_models))

    def predict(self, x):
        self._require_state()
        return EL.predict(self.state, x)

    def route(self, x, lam):
        """Hot path: anchor similarities → fused utility argmax."""
        self._require_state()
        s = EL.kernel_weights(x, self.state["anchors"], self.state["tau"])
        zeros = jnp.zeros((self.state["rating"].shape[1],))
        choice, _ = kops.router_utility(s, self.state["rating"] / EL.ELO_SCALE,
                                        zeros, self.state["C"], zeros, lam)
        return choice

    def _state_num_models(self) -> int:
        return int(self.state["rating"].shape[1])

    # ------------------------------------------------------------ onboarding

    def onboard_model(self, calib, **kw) -> "EloRouter":
        """§6.3, training-free: rate the new model from calibration evals
        {"x","acc","cost","w"} (one new rating column, re-finalized)."""
        self._require_state()
        return self.with_state(
            EL.add_model_stats(self.state, calib, self.rcfg))

    def onboard_clients(self, data_new, **kw) -> "EloRouter":
        """App. D.3, training-free: add the new clients' similarity-weighted
        sums against the existing anchors (exact — raw sums are in state)."""
        self._require_state()
        return self.with_state(
            EL.merge_client_stats(self.state, data_new, self.rcfg,
                                  num_models=self.num_models))

    # --------------------------------------------------------------- fitting

    def _fit_federated(self, key, data, fcfg, *, rounds=None, eval_fn=None,
                       mesh=None, client_mask=None, **kw):
        """Alg. 2: one-shot — no rounds, no loss. ``rounds`` does not apply
        (and is ignored); fcfg is accepted for signature parity with
        parametric families. ``mesh`` and parametric-only knobs are
        rejected rather than silently dropped."""
        if mesh is not None:
            raise ValueError("the elo family is one-shot: there is no "
                             "sharded fitting path — drop mesh=")
        if kw:
            raise ValueError("elo fit_federated got unsupported "
                             f"options: {', '.join(sorted(kw))}")
        state = EL.fed_elo_router(key, data, self.rcfg,
                                  num_models=self._num_models,
                                  client_mask=client_mask)
        new = self.with_state(state)
        hist = {"loss": [], "eval": [eval_fn(new)] if eval_fn else []}
        return new, hist

    def _fit_local(self, key, data_i, fcfg, *, k=None, **kw):
        """Client-local (no-FL) baseline: own anchors + own ratings. With
        ``k=rcfg.k_global`` on pooled data this is the centralized
        baseline."""
        state = EL.local_elo_router(key, data_i, self.rcfg,
                                    num_models=self._num_models, k=k)
        return self.with_state(state), {"loss": []}
