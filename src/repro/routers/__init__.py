"""``repro.routers`` — the single public API for routing.

One ``Router`` interface for every family, a string registry, and one
federated-fit entry point:

    from repro import routers

    router = routers.make("mlp", rcfg)          # or "kmeans"/"mf"/"elo"
    router, hist = routers.fit_federated(router, split["train"], fcfg,
                                         key=jax.random.PRNGKey(0))
    A, C = router.predict(x)                    # estimates (Q, M)
    m = router.route(x, lam=0.5)                # fused decision hot path
    router.save("router.msgpack")
    router = routers.load("router.msgpack", rcfg)

Families: "mlp" and "mf" (parametric, Alg. 1 FedAvg — iterative,
scan-fused, aggregator-pluggable), "kmeans" and "elo" (nonparametric,
Alg. 2 — one-shot statistics aggregation). New families subclass
``Router`` and ``@register("name")`` themselves.
"""
from repro.routers.base import Router  # noqa: F401
from repro.routers.elo import EloRouter  # noqa: F401
from repro.routers.fit import fit_federated, fit_local  # noqa: F401
from repro.routers.kmeans import KMeansRouter  # noqa: F401
from repro.routers.mf import MFRouter  # noqa: F401
from repro.routers.mlp import MLPRouter  # noqa: F401
from repro.routers.registry import (  # noqa: F401
    available,
    get,
    load,
    make,
    register,
)
