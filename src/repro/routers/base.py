"""The unified ``Router`` interface (public API of ``repro.routers``).

Every router family — parametric (MLP, Alg. 1) or nonparametric (K-means,
Alg. 2) — is exposed through the same small surface:

  * ``init(key)``                 fresh state (no-op for one-shot families)
  * ``predict(x) -> (A, C)``      per-query accuracy / cost estimates
  * ``route(x, lam) -> m``        argmax_m A − λ·C on the family's hot path
  * ``loss(batch)``               training loss (parametric families only)
  * ``onboard_model(calib, ...)`` §6.3 pool expansion
  * ``onboard_clients(data, ...)``App. D.3 client expansion
  * ``state``                     serializable pytree; ``save``/``load``
                                  round-trips through train/checkpoint

Routers are value-style containers: fitting and onboarding return a *new*
``Router`` carrying the updated state, so the objects compose with jit'd
code the same way raw pytrees do.
"""
from __future__ import annotations

import abc
from typing import Any, ClassVar, Optional

import jax.numpy as jnp

from repro.config import RouterConfig
from repro.train import checkpoint as ckpt


class Router(abc.ABC):
    """One member of the router family zoo (see ``repro.routers.make``)."""

    #: registry key ("mlp", "kmeans", ...) — set by @register
    name: ClassVar[str] = ""
    #: True for gradient-trained families (iterative FedAvg, Alg. 1);
    #: False for one-shot statistics families (Alg. 2).
    parametric: ClassVar[bool] = True

    def __init__(self, rcfg: RouterConfig, *,
                 num_models: Optional[int] = None, state: Any = None):
        self.rcfg = rcfg
        self._num_models = (num_models if num_models is not None
                            else rcfg.num_models)
        self.state = state

    # ------------------------------------------------------------- interface

    @abc.abstractmethod
    def init(self, key) -> "Router":
        """Return a router with freshly initialized state."""

    @abc.abstractmethod
    def predict(self, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """x: (Q, d_emb) → (A (Q, M) in [0,1], C (Q, M))."""

    def route(self, x: jnp.ndarray, lam: float) -> jnp.ndarray:
        """argmax_m U_λ(x, m) = A − λ·C → chosen model indices (Q,).

        Subclasses override with their fused decision hot path; this
        default goes through ``predict``.
        """
        A, C = self.predict(x)
        return jnp.argmax(A - lam * C, axis=-1)

    def loss(self, batch: dict, *, rng=None) -> jnp.ndarray:
        """Per-batch training loss. Only parametric families have one."""
        raise NotImplementedError(
            f"{type(self).__name__} is nonparametric: it has no training "
            "loss — fit it with repro.routers.fit_federated (one-shot).")

    @abc.abstractmethod
    def onboard_model(self, calib: dict, **kw) -> "Router":
        """§6.3: expand the pool with new model(s) from calibration evals."""

    @abc.abstractmethod
    def onboard_clients(self, data_new: dict, **kw) -> "Router":
        """App. D.3: fold newly joined clients into the router."""

    # -------------------------------------------------------- fitting hooks
    # Called by repro.routers.fit_federated / fit_local — part of the
    # family contract so incomplete subclasses fail at instantiation, not
    # deep inside a fit call.

    @abc.abstractmethod
    def _fit_federated(self, key, data: dict, fcfg, *, rounds=None,
                       eval_fn=None, mesh=None, **kw) -> tuple["Router", dict]:
        """Federated fit → (fitted router, {"loss": [...], "eval": [...]})."""

    @abc.abstractmethod
    def _fit_local(self, key, data_i: dict, fcfg,
                   **kw) -> tuple["Router", dict]:
        """No-FL baseline fit on one flat dataset → (router, history)."""

    # ------------------------------------------------------------- state mgmt

    @property
    def initialized(self) -> bool:
        return self.state is not None

    @property
    def num_models(self) -> int:
        """M — the model-pool dimension of the predict/route outputs."""
        if self.state is not None:
            return self._state_num_models()
        return self._num_models

    @abc.abstractmethod
    def _state_num_models(self) -> int:
        """M as recorded in the fitted state (pool may have been expanded)."""

    def with_state(self, state: Any) -> "Router":
        """Value-style update: same config, new state pytree."""
        return type(self)(self.rcfg, num_models=self._num_models,
                          state=state)

    def _require_state(self):
        if self.state is None:
            raise ValueError(
                f"{type(self).__name__} has no state — call init()/"
                "fit_federated() or load() a checkpoint first.")

    # ---------------------------------------------------------- persistence

    def save(self, path) -> None:
        """Checkpoint the router (family tag + state pytree, msgpack)."""
        self._require_state()
        ckpt.save(path, {"kind": self.name, "state": self.state})

    @staticmethod
    def load_state(path) -> tuple[str, Any]:
        """Low-level restore → (family name, state). Prefer
        ``repro.routers.load`` which also rebuilds the Router object."""
        blob = ckpt.restore(path)
        return blob["kind"], blob["state"]

    def __repr__(self) -> str:
        st = "fitted" if self.initialized else "uninitialized"
        return (f"{type(self).__name__}(name={self.name!r}, M="
                f"{self.num_models}, {st})")
