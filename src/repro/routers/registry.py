"""String registry: configs, benchmarks, and the gateway select router
families by name — ``routers.make("mlp", rcfg)`` — so slotting in a new
family is one decorated class, not N call-site edits."""
from __future__ import annotations

from typing import Callable, Dict, Optional, Type

from repro.config import RouterConfig
from repro.routers.base import Router

_REGISTRY: Dict[str, Type[Router]] = {}


def register(name: str) -> Callable[[Type[Router]], Type[Router]]:
    """Class decorator: ``@register("mlp")`` adds a family to the zoo."""
    def deco(cls: Type[Router]) -> Type[Router]:
        if not issubclass(cls, Router):
            raise TypeError(f"{cls.__name__} must subclass Router")
        if name in _REGISTRY and _REGISTRY[name] is not cls:
            raise ValueError(f"router family {name!r} already registered "
                             f"({_REGISTRY[name].__name__})")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Type[Router]:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown router family {name!r} — registered families: "
            f"{', '.join(available())}")
    return _REGISTRY[name]


def make(name: str, rcfg: RouterConfig, *, num_models: Optional[int] = None,
         state=None, **kw) -> Router:
    """Build an (unfitted, unless ``state`` is given) router by name."""
    return get(name)(rcfg, num_models=num_models, state=state, **kw)


def load(path, rcfg: RouterConfig) -> Router:
    """Restore a router checkpoint written by ``Router.save``: the family
    tag stored alongside the state picks the class from the registry."""
    kind, state = Router.load_state(path)
    return make(kind, rcfg, state=state)
