"""Unified fitting entry points for every router family.

``fit_federated`` is the one federated-training call sites use: it
dispatches to iterative FedAvg rounds (Alg. 1 — including the sharded
``shard_map`` path via ``mesh=``) for parametric routers and to the
one-shot statistics-aggregation protocol (Alg. 2) for nonparametric ones.
Both return the same ``(router, history)`` contract with
``history = {"loss": [...], "eval": [...]}`` — one entry per round for
iterative families, at most one for one-shot families.

``fit_local`` is the matching no-FL baseline (client-local or, on pooled
data, centralized ERM / pooled K-means).
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.config import FedConfig
from repro.routers.base import Router


def _normalize_hist(hist: dict) -> dict:
    hist.setdefault("loss", [])
    hist.setdefault("eval", [])
    return hist


def fit_federated(router: Router, data: dict, fcfg: FedConfig, *, key,
                  rounds: Optional[int] = None,
                  eval_fn: Optional[Callable[[Router], object]] = None,
                  mesh=None, **family_kw) -> tuple[Router, dict]:
    """Fit ``router`` on stacked, padded client data (see federated.py for
    the layout). Returns a NEW fitted router plus the history dict.

    eval_fn, when given, receives a fitted ``Router`` (called per round for
    iterative families, once for one-shot families). ``mesh`` selects the
    shard_map path for families that support it. ``family_kw`` forwards
    family-specific knobs (optimizer=, distill=, client_mask=, dp_sigma=,
    aggregator= — a ``repro.fed.aggregators`` strategy for the server
    aggregation step, ...). With a fixed ``key`` the parametric path
    reproduces the legacy ``core.federated.fedavg`` results bit-for-bit,
    and the nonparametric path ``core.kmeans_router.fed_kmeans_router``.
    """
    new_router, hist = router._fit_federated(key, data, fcfg, rounds=rounds,
                                             eval_fn=eval_fn, mesh=mesh,
                                             **family_kw)
    return new_router, _normalize_hist(hist)


def fit_local(router: Router, data_i: dict, fcfg: FedConfig, *, key,
              **family_kw) -> tuple[Router, dict]:
    """No-FL baseline on one flat dataset {"x","m","acc","cost","w"}:
    minibatch ERM for parametric families (steps=, optimizer=), local
    K-means + own statistics for nonparametric ones (k=). Run on pooled
    data this is the centralized baseline."""
    new_router, hist = router._fit_local(key, data_i, fcfg, **family_kw)
    return new_router, _normalize_hist(hist)
