"""Parametric matrix-factorization router behind the unified interface.

Wraps ``core/mf_router.py``: query embeddings project into a rank-r latent
space where each model carries a learned factor per head — the direct
factorization of the sparse (query × model) evaluation matrix the paper's
non-uniform-coverage setting produces.

Federated fitting is iterative FedAvg — the *same* ``core.federated``
machinery as the MLP family (scan-fused rounds, compiled-fit caches,
pluggable aggregation strategies), selected via its ``loss_fn`` hook. The
decision hot path reuses the fused Pallas ``router_utility`` kernel with
the latent factors in place of trunk features: the params carry the same
``heads`` layout, so one kernel serves both parametric families.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import expansion as E
from repro.core import federated as F
from repro.core import mf_router as MF
from repro.kernels import ops as kops
from repro.routers.base import Router
from repro.routers.registry import register


@register("mf")
class MFRouter(Router):
    parametric = True

    # ------------------------------------------------------------- interface

    def init(self, key) -> "MFRouter":
        return self.with_state(
            MF.init_mf_router(key, self.rcfg, num_models=self._num_models))

    def predict(self, x):
        self._require_state()
        return MF.apply_mf_router(self.state, x)

    def route(self, x, lam):
        """Fused Pallas hot path: latent factors → utility argmax."""
        self._require_state()
        z = MF.factor_apply(self.state, x)
        hd = self.state["heads"]
        choice, _ = kops.router_utility(z, hd["acc_w"], hd["acc_b"],
                                        hd["cost_w"], hd["cost_b"], lam)
        return choice

    def loss(self, batch, *, rng=None):
        self._require_state()
        return MF.mf_loss(self.state, batch, self.rcfg, rng=rng)

    def _state_num_models(self) -> int:
        return int(self.state["heads"]["acc_b"].shape[0])

    # ------------------------------------------------------------ onboarding

    def onboard_model(self, calib, *, key=None, fcfg=None, n_new: int = 1,
                      steps: int = 300) -> "MFRouter":
        """§6.3: append fresh factor columns, train ONLY those columns on
        the calibration evals (projection + existing factors frozen)."""
        self._require_state()
        if key is None or fcfg is None:
            raise ValueError("MF model onboarding trains the new factors: "
                             "pass key= and fcfg=")
        params, _ = E.onboard_models_mf(key, self.state, calib, self.rcfg,
                                        fcfg, n_new, steps=steps)
        return self.with_state(params)

    def onboard_clients(self, data_new, *, key=None, fcfg=None,
                        rounds: int = 15, beta: float = 1.0) -> "MFRouter":
        """App. D.3: continued FedAvg on the new clients only, anchored by
        a distillation penalty toward the frozen pre-join factorization."""
        self._require_state()
        if key is None or fcfg is None:
            raise ValueError("MF client onboarding continues FedAvg: pass "
                             "key= and fcfg=")
        params, _ = E.onboard_clients_mf(key, self.state, data_new,
                                         self.rcfg, fcfg, rounds=rounds,
                                         beta=beta)
        return self.with_state(params)

    # --------------------------------------------------------------- fitting

    def _init_for_fit(self, key):
        """Initial params for a fit entry point. Unlike the MLP family
        there is no legacy trainer to defer to, so an unfitted router
        always inits here — with the same (key, k_init = split(key)) key
        convention the legacy entry points use."""
        if self.state is not None:
            return self.state
        _, k_init = jax.random.split(key)
        return MF.init_mf_router(k_init, self.rcfg,
                                 num_models=self._num_models)

    def _fit_federated(self, key, data, fcfg, *, rounds=None, eval_fn=None,
                       mesh=None, **kw):
        """Alg. 1 via ``core.federated.fedavg`` with the MF loss — kw
        forwards optimizer/full_batch/freeze/distill/client_mask/dp_sigma/
        aggregator/cohort/eval_every exactly like the MLP family.
        ``mesh=`` selects the same ``shard_map`` fit the MLP family uses
        (the sharded round is family-agnostic through ``loss_fn``),
        bit-for-bit the in-process fit on a fixed key."""
        wrapped = (None if eval_fn is None
                   else lambda p: eval_fn(self.with_state(p)))
        params, hist = F.fedavg(key, data, self.rcfg, fcfg, rounds=rounds,
                                init=self._init_for_fit(key), mesh=mesh,
                                eval_fn=wrapped, loss_fn=MF.mf_loss, **kw)
        return self.with_state(params), hist

    def _fit_local(self, key, data_i, fcfg, *, steps: int = 400,
                   optimizer: str = "adamw", **kw):
        """Client-local / centralized ERM baseline (flat dataset)."""
        params, losses = F.sgd_train(key, data_i, self.rcfg, fcfg,
                                     steps=steps, optimizer=optimizer,
                                     init=self._init_for_fit(key),
                                     loss_fn=MF.mf_loss, **kw)
        return self.with_state(params), {"loss": [float(l) for l in
                                                  np.asarray(losses)]}
