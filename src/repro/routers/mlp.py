"""Parametric MLP-Router behind the unified interface (paper §4.1, Alg. 1).

Wraps the math in ``core/mlp_router.py`` / ``core/federated.py`` /
``core/expansion.py``. The decision hot path (``route``) runs the fused
Pallas ``router_utility`` kernel: one pass over the trunk features computes
both heads and the λ-utility argmax without materializing A and C.

Federated fitting is iterative FedAvg. With ``mesh=None`` it is exactly
``core.federated.fedavg`` (bit-for-bit on a fixed key); with a 1-D client
mesh it is the ``shard_map`` variant (``fedavg_round_sharded``) where each
device trains its own block of the stacked client slab and the server
aggregation runs replicated on the all-gathered update stack — every
``Aggregator`` strategy, cohort sampling, dp_sigma, and staleness ride it,
bit-for-bit the in-process fit on a fixed key.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import expansion as E
from repro.core import federated as F
from repro.core import mlp_router as R
from repro.kernels import ops as kops
from repro.routers.base import Router
from repro.routers.registry import register


@register("mlp")
class MLPRouter(Router):
    parametric = True

    # ------------------------------------------------------------- interface

    def init(self, key) -> "MLPRouter":
        return self.with_state(
            R.init_mlp_router(key, self.rcfg, num_models=self._num_models))

    def predict(self, x):
        self._require_state()
        return R.apply_mlp_router(self.state, x)

    def route(self, x, lam):
        """Fused Pallas hot path: trunk features → utility argmax."""
        self._require_state()
        h = R.trunk_apply(self.state, x)
        hd = self.state["heads"]
        choice, _ = kops.router_utility(h, hd["acc_w"], hd["acc_b"],
                                        hd["cost_w"], hd["cost_b"], lam)
        return choice

    def loss(self, batch, *, rng=None):
        self._require_state()
        return R.router_loss(self.state, batch, self.rcfg, rng=rng)

    def _state_num_models(self) -> int:
        return int(self.state["heads"]["acc_b"].shape[0])

    # ------------------------------------------------------------ onboarding

    def onboard_model(self, calib, *, key=None, fcfg=None, n_new: int = 1,
                      steps: int = 300) -> "MLPRouter":
        """§6.3: append fresh head columns, train ONLY those columns on the
        calibration evals (trunk + existing heads frozen)."""
        self._require_state()
        if key is None or fcfg is None:
            raise ValueError("MLP model onboarding trains the new heads: "
                             "pass key= and fcfg=")
        params, _ = E.onboard_models_mlp(key, self.state, calib, self.rcfg,
                                         fcfg, n_new, steps=steps)
        return self.with_state(params)

    def onboard_clients(self, data_new, *, key=None, fcfg=None,
                        rounds: int = 15, beta: float = 1.0) -> "MLPRouter":
        """App. D.3: continued FedAvg on the new clients only, anchored by a
        distillation penalty toward the frozen pre-join router."""
        self._require_state()
        if key is None or fcfg is None:
            raise ValueError("MLP client onboarding continues FedAvg: pass "
                             "key= and fcfg=")
        params, _ = E.onboard_clients_mlp(key, self.state, data_new,
                                          self.rcfg, fcfg, rounds=rounds,
                                          beta=beta)
        return self.with_state(params)

    # --------------------------------------------------------------- fitting

    def _init_for_fit(self, key):
        """Initial params for a fit entry point: the existing state, or —
        when make(..., num_models=) overrides the config — a fresh init
        with the overridden M. Mirrors the key handling of the legacy
        trainers (key, k_init = split(key); init from k_init) so the
        default M path stays bit-for-bit identical to them."""
        if self.state is not None:
            return self.state
        if self._num_models == self.rcfg.num_models:
            return None  # let the legacy trainer init — bit-for-bit parity
        _, k_init = jax.random.split(key)
        return R.init_mlp_router(k_init, self.rcfg,
                                 num_models=self._num_models)

    def _fit_federated(self, key, data, fcfg, *, rounds=None, eval_fn=None,
                       mesh=None, **kw):
        """Alg. 1. mesh=None → in-process vmap simulation (≡ legacy
        ``fedavg``; kw forwards optimizer/full_batch/freeze/distill/
        client_mask/dp_sigma/aggregator/cohort/staleness).
        mesh=Mesh(..., ("clients",)) → shard_map across devices,
        bit-for-bit the in-process fit on a fixed key; it carries every
        knob except the pytree-valued ones (freeze/distill/client_mask,
        rejected in ``F.fedavg``)."""
        init = self._init_for_fit(key)
        wrapped = (None if eval_fn is None
                   else lambda p: eval_fn(self.with_state(p)))
        params, hist = F.fedavg(key, data, self.rcfg, fcfg,
                                rounds=rounds, init=init, mesh=mesh,
                                eval_fn=wrapped, **kw)
        return self.with_state(params), hist

    def _fit_local(self, key, data_i, fcfg, *, steps: int = 400,
                   optimizer: str = "adamw", **kw):
        """Client-local / centralized ERM baseline (flat dataset)."""
        params, losses = F.sgd_train(key, data_i, self.rcfg, fcfg,
                                     steps=steps, optimizer=optimizer,
                                     init=self._init_for_fit(key), **kw)
        return self.with_state(params), {"loss": [float(l) for l in
                                                  np.asarray(losses)]}
