"""Parametric MLP-Router behind the unified interface (paper §4.1, Alg. 1).

Wraps the math in ``core/mlp_router.py`` / ``core/federated.py`` /
``core/expansion.py``. The decision hot path (``route``) runs the fused
Pallas ``router_utility`` kernel: one pass over the trunk features computes
both heads and the λ-utility argmax without materializing A and C.

Federated fitting is iterative FedAvg. With ``mesh=None`` it is exactly
``core.federated.fedavg`` (bit-for-bit on a fixed key); with a 1-D client
mesh it is the ``shard_map`` variant where each device runs its local
clients' updates and the server aggregation is a weighted ``psum``.
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # moved out of experimental in newer jax
    from jax import shard_map
except ImportError:  # jax<=0.4.x
    from jax.experimental.shard_map import shard_map

from repro.core import expansion as E
from repro.core import federated as F
from repro.core import mlp_router as R
from repro.kernels import ops as kops
from repro.routers.base import Router
from repro.routers.registry import register

# the "replication check" kwarg was renamed check_rep → check_vma
_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(shard_map).parameters else "check_rep")


@register("mlp")
class MLPRouter(Router):
    parametric = True

    # ------------------------------------------------------------- interface

    def init(self, key) -> "MLPRouter":
        return self.with_state(
            R.init_mlp_router(key, self.rcfg, num_models=self._num_models))

    def predict(self, x):
        self._require_state()
        return R.apply_mlp_router(self.state, x)

    def route(self, x, lam):
        """Fused Pallas hot path: trunk features → utility argmax."""
        self._require_state()
        h = R.trunk_apply(self.state, x)
        hd = self.state["heads"]
        choice, _ = kops.router_utility(h, hd["acc_w"], hd["acc_b"],
                                        hd["cost_w"], hd["cost_b"], lam)
        return choice

    def loss(self, batch, *, rng=None):
        self._require_state()
        return R.router_loss(self.state, batch, self.rcfg, rng=rng)

    def _state_num_models(self) -> int:
        return int(self.state["heads"]["acc_b"].shape[0])

    # ------------------------------------------------------------ onboarding

    def onboard_model(self, calib, *, key=None, fcfg=None, n_new: int = 1,
                      steps: int = 300) -> "MLPRouter":
        """§6.3: append fresh head columns, train ONLY those columns on the
        calibration evals (trunk + existing heads frozen)."""
        self._require_state()
        if key is None or fcfg is None:
            raise ValueError("MLP model onboarding trains the new heads: "
                             "pass key= and fcfg=")
        params, _ = E.onboard_models_mlp(key, self.state, calib, self.rcfg,
                                         fcfg, n_new, steps=steps)
        return self.with_state(params)

    def onboard_clients(self, data_new, *, key=None, fcfg=None,
                        rounds: int = 15, beta: float = 1.0) -> "MLPRouter":
        """App. D.3: continued FedAvg on the new clients only, anchored by a
        distillation penalty toward the frozen pre-join router."""
        self._require_state()
        if key is None or fcfg is None:
            raise ValueError("MLP client onboarding continues FedAvg: pass "
                             "key= and fcfg=")
        params, _ = E.onboard_clients_mlp(key, self.state, data_new,
                                          self.rcfg, fcfg, rounds=rounds,
                                          beta=beta)
        return self.with_state(params)

    # --------------------------------------------------------------- fitting

    def _init_for_fit(self, key):
        """Initial params for a fit entry point: the existing state, or —
        when make(..., num_models=) overrides the config — a fresh init
        with the overridden M. Mirrors the key handling of the legacy
        trainers (key, k_init = split(key); init from k_init) so the
        default M path stays bit-for-bit identical to them."""
        if self.state is not None:
            return self.state
        if self._num_models == self.rcfg.num_models:
            return None  # let the legacy trainer init — bit-for-bit parity
        _, k_init = jax.random.split(key)
        return R.init_mlp_router(k_init, self.rcfg,
                                 num_models=self._num_models)

    def _fit_federated(self, key, data, fcfg, *, rounds=None, eval_fn=None,
                       mesh=None, **kw):
        """Alg. 1. mesh=None → in-process vmap simulation (≡ legacy
        ``fedavg``; kw forwards optimizer/full_batch/freeze/distill/
        client_mask/dp_sigma/aggregator). mesh=Mesh(..., ("clients",)) →
        shard_map across devices; that path supports only optimizer= of
        the kw (its aggregation is a fixed weighted psum)."""
        init = self._init_for_fit(key)
        wrapped = (None if eval_fn is None
                   else lambda p: eval_fn(self.with_state(p)))
        if mesh is not None:
            unsupported = sorted(set(kw) - {"optimizer", "eval_every"})
            if unsupported:
                raise ValueError(
                    f"the mesh path supports only optimizer=/eval_every= "
                    f"(got {', '.join(unsupported)}) — drop mesh= to use "
                    "the in-process simulation with those knobs")
            params, hist = _fedavg_sharded(
                key, data, self.rcfg, fcfg,
                rounds=rounds if rounds is not None else fcfg.rounds,
                mesh=mesh, init=init, num_models=self._num_models,
                eval_fn=wrapped, **kw)
        else:
            params, hist = F.fedavg(key, data, self.rcfg, fcfg,
                                    rounds=rounds, init=init,
                                    eval_fn=wrapped, **kw)
        return self.with_state(params), hist

    def _fit_local(self, key, data_i, fcfg, *, steps: int = 400,
                   optimizer: str = "adamw", **kw):
        """Client-local / centralized ERM baseline (flat dataset)."""
        params, losses = F.sgd_train(key, data_i, self.rcfg, fcfg,
                                     steps=steps, optimizer=optimizer,
                                     init=self._init_for_fit(key), **kw)
        return self.with_state(params), {"loss": [float(l) for l in
                                                  np.asarray(losses)]}


# ---------------------------------------------------------------------------
# shard_map FedAvg (moved here from launch/fed_train.py so every entry point
# reaches it through fit_federated(mesh=...))
# ---------------------------------------------------------------------------


def fedavg_round_sharded(params, data, key, rcfg, fcfg, opt, max_steps,
                         mesh: Mesh):
    """One FedAvg round with clients sharded across devices: local vmap per
    device, server aggregation (Alg. 1 line 11) as a weighted psum."""
    N = data["x"].shape[0]
    n_dev = mesh.shape["clients"]
    assert N % n_dev == 0, "num_clients must divide the client-mesh size"
    key, k_sel, k_cli = jax.random.split(key, 3)
    n_active = max(1, int(round(fcfg.participation * N)))
    perm = jax.random.permutation(k_sel, N)
    active = jnp.zeros((N,)).at[perm[:n_active]].set(1.0)
    keys = jax.random.split(k_cli, N)

    upd = functools.partial(F.client_update, rcfg=rcfg, fcfg=fcfg, opt=opt,
                            max_steps=max_steps)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P("clients"), P("clients"), P("clients")),
        out_specs=(P(), P()),
        **{_CHECK_KW: False})
    def round_fn(params, data_shard, keys_shard, active_shard):
        # local clients on this device
        cp, closs = jax.vmap(lambda d, k: upd(params, d, k)[0:2],
                             in_axes=(0, 0))(data_shard, keys_shard)
        w = jnp.sum(data_shard["w"], axis=-1) * active_shard
        wsum = jax.lax.psum(jnp.sum(w), "clients")
        agg = jax.tree.map(
            lambda s: jax.lax.psum(
                jnp.tensordot(w, s.astype(jnp.float32), axes=1), "clients")
            / jnp.maximum(wsum, 1e-12),
            cp)
        loss = jax.lax.psum(jnp.sum(closs * w), "clients") / jnp.maximum(
            wsum, 1e-12)
        return agg, loss

    new_params, loss = round_fn(params, data, keys, active)
    return jax.tree.map(lambda a, b: a.astype(b.dtype), new_params,
                        params), loss


@functools.lru_cache(maxsize=16)
def _sharded_scan_fit_cached(rcfg, fcfg, optimizer, max_steps, mesh: Mesh,
                             rounds, donate):
    """Compiled scan-fused sharded fit, reused across repeated fits with
    the same config/mesh (Mesh and the frozen configs are hashable)."""
    round_fn = functools.partial(
        fedavg_round_sharded, rcfg=rcfg, fcfg=fcfg,
        opt=F._make_opt(fcfg, optimizer), max_steps=max_steps, mesh=mesh)
    return F._make_scan_fit(round_fn, rounds, donate=donate)


def _fedavg_sharded(key, data, rcfg, fcfg, *, rounds: int, mesh: Mesh,
                    init=None, num_models=None, optimizer: str = "adamw",
                    eval_fn=None, eval_every: int = 1):
    D_max = data["x"].shape[1]
    # same local-work budget as the in-process path (F.fedavg)
    max_steps = max(1, int(np.ceil(D_max / fcfg.batch_size))) \
        * fcfg.local_epochs
    key, k_init = jax.random.split(key)
    params = init if init is not None else R.init_mlp_router(
        k_init, rcfg, num_models=num_models)
    if eval_fn is None:  # fuse the round loop — one dispatch, one host sync
        fit = _sharded_scan_fit_cached(rcfg, fcfg, optimizer, max_steps,
                                       mesh, rounds, init is None)
        params, _, losses = fit(params, key, data)
        return params, {"loss": np.asarray(losses).tolist(), "eval": []}

    if eval_every > 1:  # chunked-eval: scan E rounds per eval sync
        return F.chunked_eval_fit(
            lambda E: _sharded_scan_fit_cached(rcfg, fcfg, optimizer,
                                               max_steps, mesh, E, False),
            params, key, data, rounds, eval_every, eval_fn)

    step = jax.jit(functools.partial(
        fedavg_round_sharded, rcfg=rcfg, fcfg=fcfg,
        opt=F._make_opt(fcfg, optimizer), max_steps=max_steps, mesh=mesh))
    hist = {"loss": [], "eval": []}
    for _ in range(rounds):
        key, k_r = jax.random.split(key)
        params, loss = step(params, data, k_r)
        hist["loss"].append(float(loss))
        hist["eval"].append(eval_fn(params))
    return params, hist
