from repro.models.model import (  # noqa: F401
    init_params,
    forward,
    loss_fn,
    init_decode_cache,
    decode_step,
    param_count,
    active_param_count,
)
