"""Attention layer: MHA/GQA with RoPE, qk-norm, optional QKV bias.

Paths:
  * ``attn_forward``     — train / prefill attention, computed in query
    chunks (``lax.scan`` over blocks, mask generated on the fly) so the
    S×S score matrix is never materialized — pure-JAX flash-style memory
    behaviour; the Pallas kernel in ``repro.kernels.flash_attention`` is the
    TPU hot-spot version of the same schedule.
  * ``attn_decode_step`` — one-token decode against a KV cache; supports a
    rolling (sliding-window) cache for long contexts.

Logical sharding: batch → ("pod","data"), flat head dim → "model",
batch=1 decode-cache seq → "data" (see launch/sharding.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.sharding import constrain


def init_attn(key, cfg: ModelConfig) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = L.dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": L._normal(k1, (d, hq * hd), s, dt),
        "wk": L._normal(k2, (d, hkv * hd), s, dt),
        "wv": L._normal(k3, (d, hkv * hd), s, dt),
        "wo": L._normal(k4, (hq * hd, d), (hq * hd) ** -0.5, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(hd, dt)
        p["k_norm"] = L.init_rmsnorm(hd, dt)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = constrain(x @ p["wq"], ("batch", "seq", "heads"))
    k = constrain(x @ p["wk"], ("batch", "seq", "kv_heads"))
    v = constrain(x @ p["wv"], ("batch", "seq", "kv_heads"))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, hq, hd)
    k = k.reshape(B, S, hkv, hd)
    v = v.reshape(B, S, hkv, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      q_chunk: int = 512, layout: str = "grouped"):
    """Query-chunked attention; no (S,S) materialization.

    q: (B,S,Hq,hd); k,v: (B,Sk,Hkv,hd). Returns (B,S,Hq*hd).

    layout="grouped" keeps KV unexpanded (B,Sk,Hkv,g,…) — minimal memory,
    but the (Hkv, g) split is unshardable when Hq doesn't divide the TP
    axis. layout="flat" repeats KV to Hq heads and shards the head dim
    *unevenly* ("heads!") over the TP axis — the §Perf fix for archs like
    yi-34b (56 heads on a 16-way axis): scores stay head-local, so the
    per-chunk score all-reduce disappears.
    """
    B, S, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qc = min(q_chunk, S)
    while S % qc:
        qc //= 2
    nq = S // qc
    scale = hd ** -0.5
    kpos = jnp.arange(Sk)

    if layout == "flat":
        k = constrain(jnp.repeat(k, g, axis=2),
                      ("batch", "seq", "heads4d!", None))
        v = constrain(jnp.repeat(v, g, axis=2),
                      ("batch", "seq", "heads4d!", None))
        q = constrain(q, ("batch", "seq", "heads4d!", None))
        qg = jnp.moveaxis(q.reshape(B, nq, qc, Hq, hd), 1, 0)

        def body(_, inp):
            q_blk, idx = inp
            qpos = idx * qc + jnp.arange(qc)
            scores = jnp.einsum("bqhd,bkhd->bhqk",
                                q_blk.astype(jnp.float32),
                                k.astype(jnp.float32)) * scale
            scores = constrain(scores, ("batch", "heads4d!", None, None))
            if causal:
                m = kpos[None, :] <= qpos[:, None]
                if window is not None:
                    m &= (qpos[:, None] - kpos[None, :]) < window
                scores = jnp.where(m[None, None], scores, jnp.float32(-1e30))
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
            return None, out.reshape(B, qc, Hq * hd)

        _, outs = jax.lax.scan(body, None, (qg, jnp.arange(nq)))
        return jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq * hd)

    qg = q.reshape(B, nq, qc, Hkv, g, hd)
    qg = jnp.moveaxis(qg, 1, 0)  # (nq, B, qc, Hkv, g, hd)

    def body(_, inp):
        q_blk, idx = inp
        qpos = idx * qc + jnp.arange(qc)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        if causal:
            m = kpos[None, :] <= qpos[:, None]
            if window is not None:
                m &= (qpos[:, None] - kpos[None, :]) < window
            scores = jnp.where(m[None, None, None], scores, jnp.float32(-1e30))
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
        return None, out.reshape(B, qc, Hq * hd)

    _, outs = jax.lax.scan(body, None, (qg, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq * hd)


def attn_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                 positions: jnp.ndarray, *, window: Optional[int] = None,
                 q_chunk: int = 512, return_kv: bool = False,
                 layout: str = "grouped"):
    q, k, v = _project_qkv(p, x, cfg, positions)
    win = window if window is not None else (
        cfg.sliding_window if cfg.sliding_window_always else None)
    out = chunked_attention(q, k, v, causal=cfg.causal, window=win,
                            q_chunk=q_chunk, layout=layout)
    out = constrain(out, ("batch", "seq", "heads"))
    out = out @ p["wo"]
    if return_kv:  # prefill: post-RoPE k/v become the decode cache
        return out, {"k": jnp.moveaxis(k, 1, 2), "v": jnp.moveaxis(v, 1, 2)}
    return out


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    """Cache layout (B, Hkv, S, hd) — head-major so the decode dot consumes
    it without a per-step full-cache layout transpose (§Perf H3 iter 3)."""
    dt = dtype or L.dtype_of(cfg)
    shape = (batch, cfg.n_kv_heads, cache_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def attn_decode_step(p: dict, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
                     cfg: ModelConfig, *, rolling: bool) -> tuple:
    """x: (B, 1, d). pos: int32 absolute position → (out, new_cache).

    pos may be a scalar (all rows at the same position — the classic
    same-age batch) or a (B,) vector (continuous batching: every cache row
    is a pool *slot* holding a different request at its own position; RoPE,
    the cache write, and the attention-validity mask are all per-slot).

    rolling=True → cache length W is a sliding window written at ``pos % W``;
    RoPE is applied before caching, so slot order is irrelevant.
    """
    B = x.shape[0]
    W = cache["k"].shape[2]
    per_slot = jnp.ndim(pos) == 1
    positions = (pos[:, None].astype(jnp.int32) if per_slot
                 else jnp.full((1, 1), pos, dtype=jnp.int32))
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    slot = (pos % W if rolling else pos).astype(jnp.int32)
    k_new = jnp.moveaxis(k_new, 1, 2)  # (B, Hkv, 1, hd)
    v_new = jnp.moveaxis(v_new, 1, 2)
    if per_slot:
        upd = jax.vmap(lambda c, u, s:
                       jax.lax.dynamic_update_slice(c, u, (0, s, 0)))
        k_cache = upd(cache["k"], k_new, slot)
        v_cache = upd(cache["v"], v_new, slot)
    else:
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                               (0, 0, slot, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                               (0, 0, slot, 0))
    k_cache = constrain(k_cache, ("batch", None, "kv_seq", None))
    v_cache = constrain(v_cache, ("batch", None, "kv_seq", None))
    # Validity: before the window wraps, only slots [0, pos] are filled —
    # per row when pos is a vector ((B, W)), shared otherwise ((1, W)).
    n_valid = jnp.minimum(pos + 1, W)

    Hkv, hd, g = cfg.n_kv_heads, cfg.head_dim, cfg.q_per_kv
    qg = q.reshape(B, Hkv, g, hd)
    from repro.kernels import ops as kops
    if (kops._default_impl() == "pallas" and W % 8 == 0
            and W % min(512, W) == 0):
        # TPU: stream the cache through the flash-decoding kernel (online
        # softmax, f32 accumulation, no f32 cache copy) — the same kernel
        # the paged path dispatches to; the engine's uniform decode scan
        # rides this too. The guards keep odd extend_cache lengths (e.g.
        # S_bucket + max_new = 12: not sublane-aligned; 544: not a
        # multiple of the 512 seq block) on the jnp path — pool caches
        # are pow2 and always qualify. The kernel shares this path's
        # dtype discipline (cache-dtype dots, f32 accumulation), so
        # greedy tokens agree on bf16 caches (tests/test_kernels.py).
        out = kops.decode_attention(qg, k_cache, v_cache, n_valid)
        out = out.astype(v_cache.dtype)
    else:
        valid = (jnp.arange(W)[None, :] < n_valid[:, None] if per_slot
                 else jnp.arange(W)[None, :] < n_valid)
        out = _masked_grouped_attn(qg, k_cache, v_cache, valid)
    out = out.reshape(B, 1, cfg.n_heads * hd)
    return out @ p["wo"], {"k": k_cache, "v": v_cache}


def _masked_grouped_attn(qg, k_cache, v_cache, valid):
    """The decode attention block shared by the contiguous and paged (CPU
    fallback) paths — ONE definition so the engine-vs-solo token-parity
    guarantee can't silently split across copies. qg: (B, Hkv, g, hd);
    caches (B, Hkv, K, hd); valid: (B|1, K) bool. Dot in the cache dtype
    with f32 accumulation: upcasting the cache (k.astype(f32)) makes XLA
    materialize an f32 copy of the whole cache every step — measured 60%
    of decode HBM traffic (§Perf H3 iter 2). Returns (B, Hkv, g, hd) in
    the cache dtype."""
    hd = qg.shape[-1]
    scores = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(k_cache.dtype), k_cache,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    scores = jnp.where(valid[:, None, None], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgk,bhkd->bhgd", probs.astype(v_cache.dtype), v_cache,
                      preferred_element_type=jnp.float32).astype(v_cache.dtype)


def _masked_grouped_attn_multi(qg, k_cache, v_cache, valid):
    """Multi-position variant of ``_masked_grouped_attn`` for the
    speculative verify step: T query positions per row, folded into the
    query-group axis so the einsum strings — and therefore the per-row
    contraction discipline the token-parity guarantee rests on — are
    IDENTICAL to the single-token path (each folded query row is the same
    dot over hd, masked softmax over K, and dot over K as a lone decode
    query; only the causal bound varies per offset). qg:
    (B, Hkv, T, g, hd); caches (B, Hkv, K, hd); valid: (B, T, K) bool
    (query offset t attends keys below its own bound). Returns
    (B, Hkv, T, g, hd) in the cache dtype."""
    B, Hkv, T, g, hd = qg.shape
    K = k_cache.shape[2]
    qf = qg.reshape(B, Hkv, T * g, hd)
    scores = jnp.einsum("bhgd,bhkd->bhgk", qf.astype(k_cache.dtype), k_cache,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    mask = jnp.broadcast_to(valid[:, None, :, None, :], (B, Hkv, T, g, K))
    scores = jnp.where(mask.reshape(B, Hkv, T * g, K), scores,
                       jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32).astype(v_cache.dtype)
    return out.reshape(B, Hkv, T, g, hd)


# ---------------------------------------------------------------------------
# Paged decode (vLLM-style page pool — serve/kv_cache.alloc_page_pool)
# ---------------------------------------------------------------------------


def init_paged_kv_cache(cfg: ModelConfig, pages: int, page_size: int,
                        dtype=None):
    """One layer's page pool: (pages, Hkv, page_size, hd) page-major — the
    slot-pool layout with the batch dim reinterpreted as a flat pool of
    fixed-size pages shared by every in-flight request."""
    return init_kv_cache(cfg, pages, page_size, dtype)


def attn_decode_step_paged(p: dict, x: jnp.ndarray, cache: dict,
                           page_table: jnp.ndarray, pos: jnp.ndarray,
                           cfg: ModelConfig) -> tuple:
    """One-token decode against the paged pool. x: (B, 1, d);
    cache leaves (P, Hkv, page_size, hd) shared by all rows; page_table:
    (B, npg) int32 — row b's i-th entry is the pool page holding its
    logical positions [i*page_size, (i+1)*page_size); pos: (B,) int32
    absolute positions (always per-row — paging exists for continuous
    batching). Returns (out, new_cache).

    The new K/V lands at (page_table[b, pos_b // ps], pos_b % ps); rows
    whose table entry is the trash page (index 0 by serve/kv_cache
    convention) scatter harmlessly there. On TPU attention runs the
    scalar-prefetch Pallas kernel (``paged_decode_attention_pallas`` —
    pages DMA'd by table lookup, gather never materialized); elsewhere it
    gathers the pages and reuses ``attn_decode_step``'s exact einsum
    discipline — dot in the cache dtype with f32 accumulation — so engine
    tokens stay bit-identical to the solo scan path on every dtype (a
    blanket f32 upcast diverges from the contiguous path on bf16 models).
    """
    from repro.kernels import ops as kops
    B = x.shape[0]
    ps = cache["k"].shape[2]
    npg = page_table.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None]
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    k_new = jnp.moveaxis(k_new, 1, 2)[:, :, 0]        # (B, Hkv, hd)
    v_new = jnp.moveaxis(v_new, 1, 2)[:, :, 0]
    pages = jnp.take_along_axis(page_table, (pos // ps)[:, None], axis=1)[:, 0]
    off = pos % ps
    # scatter each row's token into its page; duplicate targets only ever
    # happen on the trash page (inactive rows), where any value is fine
    k_cache = cache["k"].at[pages, :, off].set(k_new.astype(cache["k"].dtype))
    v_cache = cache["v"].at[pages, :, off].set(v_new.astype(cache["v"].dtype))

    Hkv, hd, g = cfg.n_kv_heads, cfg.head_dim, cfg.q_per_kv
    qg = q.reshape(B, Hkv, g, hd)
    if kops._default_impl() == "pallas":
        out = kops.paged_decode_attention(qg, k_cache, v_cache, page_table,
                                          pos + 1)
    else:
        # Deliberately the GATHER formulation, not the copy-free
        # segment-summed one (ref.paged_decode_attention_seg_ref, the CPU
        # fallback of kops.paged_decode_attention): the engine's tokens
        # must stay bit-identical to solo serving, and that requires the
        # softmax normalizer and V contraction to reduce in the same
        # logical-position order as _masked_grouped_attn — the seg form
        # reduces pool-major and differs in the last ulp.
        from repro.kernels.ref import paged_gather_ref
        k_g = paged_gather_ref(k_cache, page_table)   # (B, Hkv, npg*ps, hd)
        v_g = paged_gather_ref(v_cache, page_table)
        n_valid = jnp.minimum(pos + 1, npg * ps)
        valid = jnp.arange(npg * ps)[None, :] < n_valid[:, None]
        out = _masked_grouped_attn(qg, k_g, v_g, valid)
    out = out.astype(v_cache.dtype).reshape(B, 1, cfg.n_heads * hd)
    return out @ p["wo"], {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Speculative multi-position verify (serve/engine.py draft/verify rounds)
# ---------------------------------------------------------------------------


def attn_decode_verify(p: dict, x: jnp.ndarray, cache: dict,
                       pos: jnp.ndarray, cfg: ModelConfig) -> tuple:
    """Multi-position decode against the uniform slot pool: row b carries
    T consecutive tokens at absolute positions pos_b .. pos_b+T-1 (the
    last committed token plus the drafted window). x: (B, T, d); pos: (B,)
    int32 base positions. All T K/V entries are written BEFORE attention
    (write-ahead — the cache's validity convention is per-query causal
    masking, so query offset t sees exactly positions < pos_b+t+1,
    including the drafts written by this same dispatch), and the write is
    a scatter with out-of-bounds DROP: near the region end the
    write-ahead window may poke past the pool's seq extent, and those
    positions are never committed — dropping them keeps in-bounds cache
    contents intact where a clamped ``dynamic_update_slice`` would smear
    over live positions. Rollback of a rejected suffix is pure host
    bookkeeping (the engine resets ``pos``): stale drafted K/V above the
    new position is masked by validity and overwritten — each later step
    writes a position before any query's bound reaches it. Returns
    (out (B, T, d), new_cache)."""
    B, T, _ = x.shape
    W = cache["k"].shape[2]
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)   # (B, T, Hkv, hd)
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
    k_cache = cache["k"].at[b_idx, :, positions].set(
        k_new.astype(cache["k"].dtype), mode="drop")
    v_cache = cache["v"].at[b_idx, :, positions].set(
        v_new.astype(cache["v"].dtype), mode="drop")
    k_cache = constrain(k_cache, ("batch", None, "kv_seq", None))
    v_cache = constrain(v_cache, ("batch", None, "kv_seq", None))
    valid = jnp.arange(W)[None, None, :] < (positions + 1)[:, :, None]

    Hkv, hd, g = cfg.n_kv_heads, cfg.head_dim, cfg.q_per_kv
    qg = jnp.moveaxis(q.reshape(B, T, Hkv, g, hd), 1, 2)  # (B, Hkv, T, g, hd)
    out = _masked_grouped_attn_multi(qg, k_cache, v_cache, valid)
    out = jnp.moveaxis(out, 2, 1).reshape(B, T, cfg.n_heads * hd)
    return out @ p["wo"], {"k": k_cache, "v": v_cache}


def attn_decode_verify_paged(p: dict, x: jnp.ndarray, cache: dict,
                             page_table: jnp.ndarray, pos: jnp.ndarray,
                             cfg: ModelConfig) -> tuple:
    """Multi-position decode against the paged pool — the paged twin of
    ``attn_decode_verify``. x: (B, T, d); page_table: (B, npg) int32;
    pos: (B,) int32 base positions. Write-ahead targets each position's
    own page; positions past the table's logical extent — and positions
    whose page was never claimed (table entry 0) — scatter into the trash
    page by the serve/kv_cache convention, so speculative overflow can
    never corrupt a live page. Attention gathers the pages and reuses the
    single-token path's exact einsum discipline (dot in the cache dtype,
    f32 accumulation) with a per-offset causal bound. Returns
    (out (B, T, d), new_cache)."""
    from repro.kernels.ref import paged_gather_ref
    B, T, _ = x.shape
    ps = cache["k"].shape[2]
    npg = page_table.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)   # (B, T, Hkv, hd)
    in_bounds = positions < npg * ps
    blk = jnp.minimum(positions // ps, npg - 1)
    pages = jnp.take_along_axis(page_table, blk, axis=1)   # (B, T)
    pages = jnp.where(in_bounds, pages, 0)                 # overflow → trash
    off = positions % ps
    k_cache = cache["k"].at[pages, :, off].set(k_new.astype(cache["k"].dtype))
    v_cache = cache["v"].at[pages, :, off].set(v_new.astype(cache["v"].dtype))

    Hkv, hd, g = cfg.n_kv_heads, cfg.head_dim, cfg.q_per_kv
    qg = jnp.moveaxis(q.reshape(B, T, Hkv, g, hd), 1, 2)  # (B, Hkv, T, g, hd)
    k_g = paged_gather_ref(k_cache, page_table)           # (B, Hkv, npg*ps, hd)
    v_g = paged_gather_ref(v_cache, page_table)
    valid = (jnp.arange(npg * ps)[None, None, :]
             < jnp.minimum(positions + 1, npg * ps)[:, :, None])
    out = _masked_grouped_attn_multi(qg, k_g, v_g, valid)
    out = jnp.moveaxis(out, 2, 1).reshape(B, T, cfg.n_heads * hd)
    return out @ p["wo"], {"k": k_cache, "v": v_cache}
