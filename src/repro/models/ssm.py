"""Mamba2 (state-space duality / SSD) block [arXiv:2405.21060].

TPU adaptation notes (DESIGN.md §3): the SSD chunked form maps naturally onto
the MXU — intra-chunk terms are small dense matmuls (chunk × chunk decay-masked
"attention"), inter-chunk recurrence is a ``lax.scan`` over chunk states
(compiled once). The recurrent state (B,H,hd,state) is the decode cache.

Single B/C group (G=1), broadcast across heads, as in the 370m reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state  # x, B, C all pass the depthwise conv
    return d_inner, n_heads, conv_dim


def init_mamba(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, conv_dim = _dims(cfg)
    dt = L.dtype_of(cfg)
    proj_dim = 2 * d_inner + 2 * s.d_state + H  # z, x, B, C, dt
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": L._normal(k1, (d, proj_dim), d ** -0.5, dt),
        "conv_w": L._normal(k2, (s.d_conv, conv_dim), 0.1, dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),        # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": L.init_rmsnorm(d_inner, dt),
        "out_proj": L._normal(k3, (d_inner, d), d_inner ** -0.5, dt),
    }


def _split_proj(proj, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, d_inner + d_inner + 2 * s.d_state],
                           axis=-1)
    return z, xBC, dt  # dt: (..., H)


def _split_xBC(xBC, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, _, _ = _dims(cfg)
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + s.d_state], axis=-1)
    return x, Bm, Cm


def _causal_conv(xBC, w, b):
    """Depthwise causal conv over time. xBC: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b)


def mamba_forward(p: dict, x_in: jnp.ndarray, cfg: ModelConfig, *,
                  return_state: bool = False):
    """Full-sequence (train / prefill) chunked-SSD forward. x_in: (B,S,d).
    return_state=True also returns the decode cache ({"conv", "state"})."""
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    hd, st = s.head_dim, s.d_state
    B_, S, _ = x_in.shape
    Q = min(s.chunk, S)
    while S % Q:  # shrink to a divisor of S (smoke tests use tiny seqs)
        Q //= 2
    nc = S // Q

    proj = x_in @ p["in_proj"]
    z, xBC_raw, dt_raw = _split_proj(proj, cfg)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = _split_xBC(xBC, cfg)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    xh = xs.reshape(B_, S, H, hd).astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)  # (B,S,st) single group
    Cm = Cm.astype(jnp.float32)

    # chunk
    def ch(a, extra=()):
        return a.reshape((B_, nc, Q) + a.shape[2:])

    dt_c = ch(dt)                      # (B,nc,Q,H)
    adt = dt_c * A                     # (B,nc,Q,H)  (= A·dt, negative)
    x_c = ch(xh)                       # (B,nc,Q,H,hd)
    B_c = ch(Bm)                       # (B,nc,Q,st)
    C_c = ch(Cm)                       # (B,nc,Q,st)
    xdt = x_c * dt_c[..., None]        # input scaled by dt

    acum = jnp.cumsum(adt, axis=2)                     # (B,nc,Q,H)
    # intra-chunk decay matrix  Lmat[q,k] = exp(acum_q - acum_k) for q>=k
    diff = acum[:, :, :, None, :] - acum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    Lmat = jnp.where(tri, jnp.exp(diff), 0.0)
    # scores: (B,nc,Q,Q) per head via C_q · B_k  (single group → no head dim)
    cb = jnp.einsum("bnqs,bnks->bnqk", C_c, B_c)
    y_diag = jnp.einsum("bnqk,bnqkh,bnkhd->bnqhd", cb, Lmat, xdt)

    # per-chunk end states and inter-chunk recurrence
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)          # (B,nc,Q,H)
    chunk_state = jnp.einsum("bnqs,bnqh,bnqhd->bnhds", B_c,
                             decay_to_end, xdt)                 # (B,nc,H,hd,st)
    chunk_decay = jnp.exp(acum[:, :, -1, :])                    # (B,nc,H)

    def scan_fn(h, inp):
        st_n, dec_n = inp  # (B,H,hd,st), (B,H)
        h_prev = h
        h = h * dec_n[:, :, None, None] + st_n
        return h, h_prev

    h0 = jnp.zeros((B_, H, hd, st), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                       # (B,nc,H,hd,st)

    decay_from_start = jnp.exp(acum)                            # (B,nc,Q,H)
    y_off = jnp.einsum("bnqs,bnqh,bnhds->bnqhd", C_c,
                       decay_from_start, h_prevs)

    y = (y_diag + y_off).reshape(B_, S, H, hd)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B_, S, d_inner).astype(x_in.dtype)
    # gated RMSNorm then output projection
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = y @ p["out_proj"]
    if return_state:
        tail = xBC_raw[:, S - (s.d_conv - 1):, :]  # last K−1 raw conv inputs
        return y, {"conv": tail, "state": h_final}
    return y


# ---------------------------------------------------------------------------
# Decode (single-token) with recurrent state cache
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=None):
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim),
                          dtype or L.dtype_of(cfg)),
        "state": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    }


def mamba_decode_step(p: dict, x_in: jnp.ndarray, cache: dict,
                      cfg: ModelConfig) -> tuple:
    """x_in: (B,1,d). Returns (y, new_cache)."""
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    hd, st = s.head_dim, s.d_state
    B_ = x_in.shape[0]

    proj = x_in[:, 0] @ p["in_proj"]           # (B, proj)
    z, xBC, dt_raw = _split_proj(proj, cfg)
    # causal conv over (cached history, current)
    hist = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv_out).astype(x_in.dtype)
    xs, Bm, Cm = _split_xBC(xBC, cfg)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(A * dt)                                             # (B,H)
    xh = xs.reshape(B_, H, hd).astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)                                       # (B,st)
    Cf = Cm.astype(jnp.float32)

    h = cache["state"] * dec[:, :, None, None] + jnp.einsum(
        "bh,bhd,bs->bhds", dt, xh, Bf)
    y = jnp.einsum("bhds,bs->bhd", h, Cf) + p["D"][None, :, None] * xh
    y = y.reshape(B_, d_inner).astype(x_in.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = (y @ p["out_proj"])[:, None, :]
    return y, {"conv": hist[:, 1:, :], "state": h}
