"""Composable model definition: dense / MoE / SSM / hybrid / encoder-only.

A model is a stack of identical *scan units*; ``block_pattern`` describes the
layers inside one unit (for most archs a unit is one layer; for hybrids it is
one attention + (P−1) Mamba layers so the stack stays scan-homogeneous).
``jax.lax.scan`` + ``jax.checkpoint`` over stacked unit params keeps compile
time depth-independent and activation memory O(1 unit).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# Block pattern
# ---------------------------------------------------------------------------


def block_pattern(cfg: ModelConfig) -> Tuple[int, List[Tuple[str, Optional[str]]]]:
    """Returns (n_units, [(mixer, ffn), ...] for one unit)."""
    if cfg.arch_type == "ssm":
        return cfg.n_layers, [("mamba", None)]
    if cfg.arch_type == "hybrid":
        P = cfg.hybrid_attn_period
        assert cfg.n_layers % P == 0
        pat = []
        for i in range(P):
            mixer = "attn" if i == 0 else "mamba"
            ffn = "moe" if (cfg.moe and i % cfg.moe_period == cfg.moe_period - 1) else "mlp"
            pat.append((mixer, ffn))
        return cfg.n_layers // P, pat
    ffn = "moe" if cfg.moe else "mlp"
    return cfg.n_layers, [("attn", ffn)]


def _init_norm(cfg: ModelConfig, d: int):
    dt = L.dtype_of(cfg)
    return L.init_layernorm(d, dt) if cfg.encoder_only else L.init_rmsnorm(d, dt)


def _norm(cfg: ModelConfig, p, x):
    return (L.layernorm if cfg.encoder_only else L.rmsnorm)(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_unit(key, cfg: ModelConfig) -> dict:
    _, pat = block_pattern(cfg)
    keys = jax.random.split(key, 2 * len(pat))
    unit = {}
    for i, (mixer, ffn) in enumerate(pat):
        lk, fk = keys[2 * i], keys[2 * i + 1]
        lp = {"norm1": _init_norm(cfg, cfg.d_model)}
        lp["mixer"] = (A.init_attn(lk, cfg) if mixer == "attn"
                       else S.init_mamba(lk, cfg))
        if ffn is not None:
            lp["norm2"] = _init_norm(cfg, cfg.d_model)
            lp["ffn"] = (M.init_moe(fk, cfg) if ffn == "moe"
                         else L.init_mlp(fk, cfg))
        unit[f"l{i}"] = lp
    return unit


def init_params(key, cfg: ModelConfig) -> dict:
    n_units, _ = block_pattern(cfg)
    k_emb, k_blocks = jax.random.split(key)
    dt = L.dtype_of(cfg)
    params = {"final_norm": _init_norm(cfg, cfg.d_model)}
    ke1, ke2 = jax.random.split(k_emb)
    emb = {}
    # Token table: text archs always; VLMs too (decode generates text tokens
    # — only the vision patches are stubbed). The audio encoder never embeds
    # tokens (its vocab is a classification codebook).
    if cfg.frontend is None or cfg.supports_decode:
        emb["tok"] = L._normal(ke1, (cfg.vocab, cfg.d_model), 0.02, dt)
    emb["unembed"] = L._normal(ke2, (cfg.d_model, cfg.vocab),
                               cfg.d_model ** -0.5, dt)
    params["embed"] = emb
    params["blocks"] = jax.vmap(lambda k: _init_unit(k, cfg))(
        jax.random.split(k_blocks, n_units))
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(params: dict, cfg: ModelConfig, *, tokens=None, embeds=None,
            moe_mode: str = "dense", q_chunk: int = 512,
            window: Optional[int] = None, remat: bool = True,
            logits_last_only: bool = False, last_pos=None,
            return_cache: bool = False, attn_layout: str = "grouped"):
    """Returns (logits, aux_loss[, cache]).

    logits_last_only — serving prefill: only the final position is
    unembedded (avoids a (B,S,V) logits tensor).
    last_pos — with logits_last_only, a traced index selecting the
    position to unembed instead of S−1: lets the gateway right-pad prompts
    into shape buckets without recompiling per true length. A scalar
    selects one position for the whole batch; a (B,) vector selects
    per-row positions (coalesced prefill: requests of different true
    lengths batched into one bucket — serve/engine.py).
    return_cache — also emit the decode cache (per-unit KV / SSM state as
    scan ys), i.e. this call doubles as ``prefill``.
    """
    if embeds is None:
        embeds = jnp.take(params["embed"]["tok"], tokens, axis=0)
    x = constrain(embeds.astype(L.dtype_of(cfg)), ("batch", "seq", "embed"))
    B, Sq, _ = x.shape
    positions = jnp.arange(Sq)[None, :]
    _, pat = block_pattern(cfg)

    def unit(carry, unit_params):
        x, aux = carry
        cache = {}
        for i, (mixer, ffn) in enumerate(pat):
            lp = unit_params[f"l{i}"]
            h = _norm(cfg, lp["norm1"], x)
            if mixer == "attn":
                h = A.attn_forward(lp["mixer"], h, cfg, positions,
                                   window=window, q_chunk=q_chunk,
                                   return_kv=return_cache,
                                   layout=attn_layout)
                if return_cache:
                    h, cache[f"l{i}"] = h
            else:
                h = S.mamba_forward(lp["mixer"], h, cfg,
                                    return_state=return_cache)
                if return_cache:
                    h, cache[f"l{i}"] = h
            x = x + h
            if ffn is not None:
                h = _norm(cfg, lp["norm2"], x)
                if ffn == "moe":
                    h, a = M.moe_forward(lp["ffn"], h, cfg, mode=moe_mode)
                    aux = aux + a
                else:
                    h = L.mlp(lp["ffn"], h, cfg)
                x = x + h
            x = constrain(x, ("batch", "seq", "embed"))
        return (x, aux), cache

    fn = jax.checkpoint(unit) if remat else unit
    (x, aux), cache = jax.lax.scan(fn, (x, jnp.float32(0.0)),
                                   params["blocks"])
    x = _norm(cfg, params["final_norm"], x)
    if logits_last_only:
        if last_pos is None:
            x = x[:, -1:, :]
        elif jnp.ndim(last_pos) == 1:      # per-row (coalesced prefill)
            x = x[jnp.arange(B)[:, None],
                  jnp.asarray(last_pos, jnp.int32)[:, None]]
        else:
            x = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
    logits = x @ params["embed"]["unembed"]
    logits = constrain(logits, ("batch", "seq", "vocab"))
    if return_cache:
        return logits, aux, cache
    return logits, aux


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *,
            moe_mode: str = "dense", q_chunk: int = 512,
            remat: bool = True, attn_layout: str = "grouped"):
    """batch: {"tokens" or "embeds", "labels", optional "mask"}."""
    logits, aux = forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        moe_mode=moe_mode, q_chunk=q_chunk, remat=remat,
        attn_layout=attn_layout)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = batch.get("mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    return jnp.sum(nll) / denom + aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _unit_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    _, pat = block_pattern(cfg)
    c = {}
    for i, (mixer, _) in enumerate(pat):
        c[f"l{i}"] = (A.init_kv_cache(cfg, batch, cache_len, dtype)
                      if mixer == "attn" else S.init_ssm_cache(cfg, batch, dtype))
    return c


def init_decode_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=None) -> dict:
    """Stacked (n_units leading dim) decode cache."""
    n_units, _ = block_pattern(cfg)
    unit = _unit_cache(cfg, batch, cache_len, dtype)
    return jax.tree.map(
        lambda a: jnp.zeros((n_units,) + a.shape, a.dtype), unit)


def init_paged_cache(cfg: ModelConfig, pages: int, page_size: int,
                     dtype=None) -> dict:
    """Stacked paged decode cache: leaves (n_units, pages, Hkv, page_size,
    hd) — one flat page pool per unit, shared by every in-flight request
    via per-request page tables (see serve/kv_cache). Attention-only: SSM
    state is not positional, so SSM/hybrid archs cannot be paged (they
    stay on the per-request gateway path)."""
    if cfg.arch_type in ("ssm", "hybrid"):
        raise TypeError(f"{cfg.name}: paged KV pools require attention-only "
                        "archs — SSM state has no per-position pages")
    n_units, pat = block_pattern(cfg)
    unit = {f"l{i}": A.init_paged_kv_cache(cfg, pages, page_size, dtype)
            for i, (mixer, _) in enumerate(pat) if mixer == "attn"}
    return jax.tree.map(
        lambda a: jnp.zeros((n_units,) + a.shape, a.dtype), unit)


def decode_step(params: dict, cache: dict, cfg: ModelConfig, *,
                tokens=None, embeds=None, pos, rolling: bool = False,
                moe_mode: str = "dense"):
    """One-token decode. tokens: (B,1) int or embeds: (B,1,d).

    pos: scalar int32 (whole batch at one position) or a (B,) int32 vector
    (continuous batching — each cache row is a slot serving a request at
    its own position; see serve/engine.py). Returns (logits (B,1,V),
    new_cache)."""
    if embeds is None:
        embeds = jnp.take(params["embed"]["tok"], tokens, axis=0)
    x = constrain(embeds.astype(L.dtype_of(cfg)), ("batch", None, None))
    _, pat = block_pattern(cfg)
    pos = jnp.asarray(pos, jnp.int32)

    def unit(x, xs):
        unit_params, unit_cache = xs
        new_cache = {}
        for i, (mixer, ffn) in enumerate(pat):
            lp = unit_params[f"l{i}"]
            h = _norm(cfg, lp["norm1"], x)
            if mixer == "attn":
                h, new_cache[f"l{i}"] = A.attn_decode_step(
                    lp["mixer"], h, unit_cache[f"l{i}"], pos, cfg,
                    rolling=rolling)
            else:
                h, new_cache[f"l{i}"] = S.mamba_decode_step(
                    lp["mixer"], h, unit_cache[f"l{i}"], cfg)
            x = x + h
            if ffn is not None:
                h = _norm(cfg, lp["norm2"], x)
                if ffn == "moe":
                    h, _ = M.moe_forward(lp["ffn"], h, cfg, mode=moe_mode)
                else:
                    h = L.mlp(lp["ffn"], h, cfg)
                x = x + h
        return x, new_cache

    x, new_cache = jax.lax.scan(unit, x, (params["blocks"], cache))
    x = _norm(cfg, params["final_norm"], x)
    logits = x @ params["embed"]["unembed"]
    return constrain(logits, ("batch", None, "vocab")), new_cache


def decode_step_paged(params: dict, cache: dict, cfg: ModelConfig, *,
                      tokens=None, embeds=None, page_table, pos,
                      moe_mode: str = "dense"):
    """One-token decode against the paged KV pool (init_paged_cache).
    tokens: (B,1) int or embeds: (B,1,d); page_table: (B, npg) int32 pool
    page ids per logical block, shared by every unit/layer; pos: (B,)
    int32 per-row absolute positions. Returns (logits (B,1,V), new_cache).
    """
    if embeds is None:
        embeds = jnp.take(params["embed"]["tok"], tokens, axis=0)
    x = constrain(embeds.astype(L.dtype_of(cfg)), ("batch", None, None))
    _, pat = block_pattern(cfg)
    pos = jnp.asarray(pos, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)

    def unit(x, xs):
        unit_params, unit_cache = xs
        new_cache = {}
        for i, (mixer, ffn) in enumerate(pat):
            lp = unit_params[f"l{i}"]
            h = _norm(cfg, lp["norm1"], x)
            h, new_cache[f"l{i}"] = A.attn_decode_step_paged(
                lp["mixer"], h, unit_cache[f"l{i}"], page_table, pos, cfg)
            x = x + h
            if ffn is not None:
                h = _norm(cfg, lp["norm2"], x)
                if ffn == "moe":
                    h, _ = M.moe_forward(lp["ffn"], h, cfg, mode=moe_mode)
                else:
                    h = L.mlp(lp["ffn"], h, cfg)
                x = x + h
        return x, new_cache

    x, new_cache = jax.lax.scan(unit, x, (params["blocks"], cache))
    x = _norm(cfg, params["final_norm"], x)
    logits = x @ params["embed"]["unembed"]
    return constrain(logits, ("batch", None, "vocab")), new_cache


def decode_verify(params: dict, cache: dict, cfg: ModelConfig, *,
                  tokens, pos, moe_mode: str = "dense"):
    """Speculative verify: decode T consecutive positions per row in ONE
    dispatch. tokens: (B, T) int32 — row b's last committed token followed
    by its T-1 drafted tokens; pos: (B,) int32 base positions (the write
    position of tokens[:, 0]). K/V for all T positions is written ahead;
    each query offset attends only below its own causal bound, so the
    returned logits (B, T, V) are position-for-position the same greedy
    signal the single-token ``decode_step`` chain produces — the engine
    compares their argmax against the drafted tokens to find the accepted
    prefix. Attention-only archs (the engine's lanes). Returns
    (logits (B, T, V), new_cache)."""
    if cfg.arch_type in ("ssm", "hybrid"):
        raise TypeError(f"{cfg.name}: speculative verify needs per-position "
                        "KV — SSM state cannot roll back a rejected suffix")
    embeds = jnp.take(params["embed"]["tok"], tokens, axis=0)
    x = constrain(embeds.astype(L.dtype_of(cfg)), ("batch", None, None))
    _, pat = block_pattern(cfg)
    pos = jnp.asarray(pos, jnp.int32)

    def unit(x, xs):
        unit_params, unit_cache = xs
        new_cache = {}
        for i, (mixer, ffn) in enumerate(pat):
            lp = unit_params[f"l{i}"]
            h = _norm(cfg, lp["norm1"], x)
            h, new_cache[f"l{i}"] = A.attn_decode_verify(
                lp["mixer"], h, unit_cache[f"l{i}"], pos, cfg)
            x = x + h
            if ffn is not None:
                h = _norm(cfg, lp["norm2"], x)
                if ffn == "moe":
                    h, _ = M.moe_forward(lp["ffn"], h, cfg, mode=moe_mode)
                else:
                    h = L.mlp(lp["ffn"], h, cfg)
                x = x + h
        return x, new_cache

    x, new_cache = jax.lax.scan(unit, x, (params["blocks"], cache))
    x = _norm(cfg, params["final_norm"], x)
    logits = x @ params["embed"]["unembed"]
    return constrain(logits, ("batch", None, "vocab")), new_cache


def decode_verify_paged(params: dict, cache: dict, cfg: ModelConfig, *,
                        tokens, page_table, pos, moe_mode: str = "dense"):
    """Paged twin of ``decode_verify`` (pool from ``init_paged_cache``).
    tokens: (B, T) int32; page_table: (B, npg) int32; pos: (B,) int32 base
    positions. Speculative overflow past a row's claimed pages scatters
    into the trash page (never a live one). Returns (logits (B, T, V),
    new_cache)."""
    embeds = jnp.take(params["embed"]["tok"], tokens, axis=0)
    x = constrain(embeds.astype(L.dtype_of(cfg)), ("batch", None, None))
    _, pat = block_pattern(cfg)
    pos = jnp.asarray(pos, jnp.int32)
    page_table = jnp.asarray(page_table, jnp.int32)

    def unit(x, xs):
        unit_params, unit_cache = xs
        new_cache = {}
        for i, (mixer, ffn) in enumerate(pat):
            lp = unit_params[f"l{i}"]
            h = _norm(cfg, lp["norm1"], x)
            h, new_cache[f"l{i}"] = A.attn_decode_verify_paged(
                lp["mixer"], h, unit_cache[f"l{i}"], page_table, pos, cfg)
            x = x + h
            if ffn is not None:
                h = _norm(cfg, lp["norm2"], x)
                if ffn == "moe":
                    h, _ = M.moe_forward(lp["ffn"], h, cfg, mode=moe_mode)
                else:
                    h = L.mlp(lp["ffn"], h, cfg)
                x = x + h
        return x, new_cache

    x, new_cache = jax.lax.scan(unit, x, (params["blocks"], cache))
    x = _norm(cfg, params["final_norm"], x)
    logits = x @ params["embed"]["unembed"]
    return constrain(logits, ("batch", None, "vocab")), new_cache


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE experts scaled by top_k/E)."""
    total = 0
    frac = (cfg.moe.top_k / cfg.moe.num_experts) if cfg.moe else 1.0
    for leaf in jax.tree.leaves(params):
        if leaf.ndim == 4:  # stacked expert weights (n_units, E, d, f)
            total += int(leaf.size * frac)
        else:
            total += leaf.size
    return total
