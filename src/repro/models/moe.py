"""Mixture-of-Experts layer with top-k token-choice routing.

Two dispatch implementations (selected by ``mode``):

  * ``dense``    — computes every expert for every token and masks by the
                   top-k gate. Semantically exact (no token dropping), but
                   does E/top_k × extra FLOPs. This is the naive baseline the
                   §Perf log starts from.
  * ``capacity`` — Switch/GShard-style: tokens are sorted by expert id and
                   scattered into an (E, C, d) buffer (capacity
                   C = ceil(T·top_k·cf / E)); experts run as batched matmuls
                   (MXU-friendly); outputs are gathered back and combined
                   with the gate weights. Overflowing tokens are dropped —
                   the production-realistic TPU dispatch (pre-megablox).

Expert weights carry a leading E dim and are sharded over the "model" mesh
axis (expert parallelism); token dims shard over ("pod","data").

The router load-balance auxiliary loss (Switch eq. 4) is returned alongside.
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.sharding import constrain

try:                                   # jax >= 0.5 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                    # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
# the "replication check" kwarg was renamed check_rep → check_vma
_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map).parameters else "check_rep")


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_expert, m.num_experts
    dt = L.dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": L._normal(k1, (d, E), s_in, jnp.float32),
        "wg": L._normal(k2, (E, d, f), s_in, dt),
        "wu": L._normal(k3, (E, d, f), s_in, dt),
        "wd": L._normal(k4, (E, f, d), s_out, dt),
    }


def _router_probs(p, x, cfg: ModelConfig):
    """x: (T, d) → top-k (weights (T,k), ids (T,k)), full probs (T,E)."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, cfg.moe.top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    return top_w, top_ids, probs


def _aux_loss(probs, top_ids, cfg: ModelConfig):
    E = cfg.moe.num_experts
    # fraction of tokens dispatched to each expert (first choice proxy)
    counts = jnp.mean(jax.nn.one_hot(top_ids[:, 0], E, dtype=jnp.float32), 0)
    imp = jnp.mean(probs, axis=0)
    return E * jnp.sum(counts * imp)


def moe_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                mode: str = "dense", capacity_factor: float = 1.25) -> tuple:
    """x: (B, S, d) → (out (B,S,d), aux_loss scalar).

    On a mesh (active sharding rules), mode="capacity" runs the dispatch
    inside ``shard_map``: the sort/scatter machinery stays LOCAL to each
    data shard and each model-column computes only its expert slice; the
    only cross-chip traffic is the FSDP weight all-gather and one psum of
    the (T_loc, d) outputs over the expert axis. (A naive pjit capacity
    dispatch makes XLA all-gather the global sort — measured 50× worse;
    see EXPERIMENTS.md §Perf H1.)
    """
    import repro.sharding as shd

    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    if mode == "capacity" and shd.active():
        out, aux = _capacity_shard_map(p, xt, cfg, capacity_factor)
        return out.reshape(B, S, d).astype(x.dtype), aux * cfg.moe.aux_coef
    top_w, top_ids, probs = _router_probs(p, xt, cfg)
    aux = _aux_loss(probs, top_ids, cfg) * cfg.moe.aux_coef
    if mode == "dense":
        out = _dense_dispatch(p, xt, top_w, top_ids, cfg)
    elif mode == "capacity":
        out = _capacity_dispatch(p, xt, top_w, top_ids, cfg, capacity_factor)
    else:
        raise ValueError(f"unknown moe mode {mode!r}")
    return out.reshape(B, S, d).astype(x.dtype), aux


def _expert_mlp(p, xe):
    """xe: (E, C, d) → (E, C, d); batched SwiGLU over the expert dim."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    return jnp.einsum("ecf,efd->ecd", g * u, p["wd"])


def _dense_dispatch(p, xt, top_w, top_ids, cfg: ModelConfig):
    E = cfg.moe.num_experts
    T, d = xt.shape
    # gate (T, E): top-k weights scattered into full expert dim
    gate = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], top_ids].add(top_w)
    # all-experts compute: (T, E, f) intermediate
    g = jax.nn.silu(constrain(jnp.einsum("td,edf->tef", xt, p["wg"]),
                              ("tokens", "experts", None)))
    u = constrain(jnp.einsum("td,edf->tef", xt, p["wu"]),
                  ("tokens", "experts", None))
    y = constrain(jnp.einsum("tef,efd->ted", g * u, p["wd"]),
                  ("tokens", "experts", None))
    return jnp.einsum("ted,te->td", y, gate.astype(y.dtype))


def _capacity_shard_map(p, xt, cfg: ModelConfig, cf: float):
    """Expert-parallel capacity dispatch under shard_map (see moe_forward).

    Layout: tokens sharded over the batch axes, experts over the expert
    ("model") axis, expert weights FSDP-sharded on d over "data" and
    all-gathered inside the block (the per-layer FSDP gather).
    """
    import functools

    import repro.sharding as shd
    from jax.sharding import PartitionSpec as P

    mesh, rules = shd._CURRENT
    tok_ax = rules.get("tokens")
    exp_ax = rules.get("experts")
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    n_exp_shards = mesh.shape[exp_ax] if exp_ax else 1
    if exp_ax is None or E % n_exp_shards != 0:
        # cannot expert-shard — fall back to the single-block path
        top_w, top_ids, probs = _router_probs(p, xt, cfg)
        return (_capacity_dispatch(p, xt, top_w, top_ids, cfg, cf),
                _aux_loss(probs, top_ids, cfg))

    fsdp_ax = "data"
    w_specs = {
        "router": P(None, None),
        "wg": P(exp_ax, fsdp_ax, None),
        "wu": P(exp_ax, fsdp_ax, None),
        "wd": P(exp_ax, None, fsdp_ax),
    }

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(w_specs, P(tok_ax, None)),
        out_specs=(P(tok_ax, None), P()),
        **{_CHECK_KW: False})
    def block(w, xt_loc):
        # FSDP all-gather of this layer's expert-shard weights
        wg = jax.lax.all_gather(w["wg"], fsdp_ax, axis=1, tiled=True)
        wu = jax.lax.all_gather(w["wu"], fsdp_ax, axis=1, tiled=True)
        wd = jax.lax.all_gather(w["wd"], fsdp_ax, axis=2, tiled=True)
        E_loc = wg.shape[0]
        T_loc = xt_loc.shape[0]

        top_w, top_ids, probs = _router_probs(w, xt_loc, cfg)
        lo = jax.lax.axis_index(exp_ax) * E_loc
        local = (top_ids >= lo) & (top_ids < lo + E_loc)
        ids_loc = jnp.where(local, top_ids - lo, E_loc)  # E_loc = drop bucket
        w_loc = jnp.where(local, top_w, 0.0)

        C = max(1, int(T_loc * k * cf) // E)
        flat_e = ids_loc.reshape(-1)
        flat_w = w_loc.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T_loc), k)
        order = jnp.argsort(flat_e)
        se, sw, stk = flat_e[order], flat_w[order], flat_t[order]
        counts = jnp.bincount(flat_e, length=E_loc + 1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(T_loc * k) - starts[se]
        keep = (pos < C) & (se < E_loc)
        pos_c = jnp.where(keep, pos, 0)
        se_c = jnp.where(keep, se, 0)

        buf = jnp.zeros((E_loc, C, xt_loc.shape[1]), xt_loc.dtype)
        buf = buf.at[se_c, pos_c].add(
            jnp.where(keep[:, None], xt_loc[stk], 0), mode="drop")
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        ye = jnp.einsum("ecf,efd->ecd", g * u, wd)
        y_tok = ye[se_c, pos_c] * jnp.where(keep, sw, 0.0)[:, None].astype(
            ye.dtype)
        out = jnp.zeros_like(xt_loc).at[stk].add(y_tok, mode="drop")
        out = jax.lax.psum(out, exp_ax)
        # aux loss (Switch eq. 4) is bilinear in two means — pmean the means
        # over token shards BEFORE the product, so it matches the global term
        counts = jnp.mean(jax.nn.one_hot(top_ids[:, 0], E,
                                         dtype=jnp.float32), 0)
        imp = jnp.mean(probs, axis=0)
        if tok_ax:
            counts = jax.lax.pmean(counts, tok_ax)
            imp = jax.lax.pmean(imp, tok_ax)
        aux = E * jnp.sum(counts * imp)
        return out, aux

    return block(p, xt)


def _capacity_dispatch(p, xt, top_w, top_ids, cfg: ModelConfig, cf: float):
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    T, d = xt.shape
    C = max(1, int(T * k * cf) // E)

    flat_e = top_ids.reshape(-1)                       # (T*k,)
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_e)                        # stable
    se, sw, stk = flat_e[order], flat_w[order], flat_t[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts               # exclusive cumsum
    pos = jnp.arange(T * k) - starts[se]               # position within expert
    keep = pos < C
    pos_c = jnp.where(keep, pos, 0)

    buf = jnp.zeros((E, C, d), xt.dtype)
    buf = buf.at[se, pos_c].add(
        jnp.where(keep[:, None], xt[stk], 0), mode="drop")
    buf = constrain(buf, ("experts", None, None))
    ye = constrain(_expert_mlp(p, buf), ("experts", None, None))  # (E, C, d)
    y_tok = ye[se, pos_c] * jnp.where(keep, sw, 0.0)[:, None].astype(ye.dtype)
    out = jnp.zeros((T, d), ye.dtype).at[stk].add(y_tok, mode="drop")
    return out
