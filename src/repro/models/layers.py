"""Shared neural-net building blocks (pure JAX, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; init fns take a PRNGKey.
  * activations run in ``cfg.dtype``; norms/softmax accumulate in f32.
  * weight layout: x @ W with W of shape (in, out).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    # (head_dim/2,) inverse frequencies, f32.
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU for decoder archs, GELU for the encoder-only audio arch)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    if cfg.encoder_only:  # GELU MLP (hubert / w2v2 style)
        return {
            "wi": _normal(k1, (d, f), s_in, dt),
            "bi": jnp.zeros((f,), dt),
            "wo": _normal(k2, (f, d), s_out, dt),
            "bo": jnp.zeros((d,), dt),
        }
    return {  # SwiGLU
        "wg": _normal(k1, (d, f), s_in, dt),
        "wu": _normal(k2, (d, f), s_in, dt),
        "wd": _normal(k3, (f, d), s_out, dt),
    }


def mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if "wi" in p:  # GELU
        h = jax.nn.gelu(x @ p["wi"] + p["bi"])
        return h @ p["wo"] + p["bo"]
    g = jax.nn.silu(x @ p["wg"])
    return (g * (x @ p["wu"])) @ p["wd"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": _normal(k1, (cfg.vocab, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = _normal(k2, (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dt)
    return p


def embed(p: dict, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    w = p["unembed"] if "unembed" in p else p["tok"].T
    return x @ w
