"""End-to-end LM training driver (deliverable b).

Trains a ~100M-parameter dense GQA model on the synthetic Markov LM stream
for a few hundred steps with the full substrate: scan-over-layers model,
AdamW + cosine schedule + clipping, checkpointing. ``--quick`` shrinks the
model/steps so the run finishes in a couple of minutes on this CPU
container; the default 100M config is sized for a real accelerator.

  PYTHONPATH=src python examples/train_lm.py --quick
"""
import argparse
import dataclasses

from repro.config import ModelConfig
from repro.launch.train import train_loop

# ~126M params: 12L · d768 · ff3072 · 8k vocab
CFG_100M = ModelConfig(
    name="repro-100m", arch_type="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=3072, vocab=8192, head_dim=64,
    dtype="float32")

CFG_QUICK = dataclasses.replace(
    CFG_100M, name="repro-12m", n_layers=4, d_model=256, d_ff=1024,
    n_heads=8, n_kv_heads=4, head_dim=32, vocab=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_lm.msgpack")
    args = ap.parse_args()
    cfg = CFG_QUICK if args.quick else CFG_100M
    steps = args.steps or (60 if args.quick else 300)
    batch, seq = (8, 128) if args.quick else (16, 512)
    from repro.models.model import param_count, init_params
    import jax
    n = param_count(init_params(jax.random.PRNGKey(0), cfg))
    print(f"model {cfg.name}: {n/1e6:.1f}M params, {steps} steps, "
          f"batch {batch} × seq {seq}")
    _, hist = train_loop(cfg, steps=steps, batch=batch, seq=seq,
                         lr=1e-3, ckpt_path=args.ckpt)
    print(f"loss {hist[0]:.3f} → {hist[-1]:.3f} "
          f"({'improved' if hist[-1] < hist[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
