"""Quickstart: federated LLM-router training in ~60 seconds on CPU.

Everything goes through the unified ``repro.routers`` API: build a router
by name (``routers.make``), fit it with the one federated entry point
(``routers.fit_federated`` — iterative FedAvg for the parametric "mlp"
family, one-shot statistics aggregation for the nonparametric "kmeans"
family), then ``predict``/``route``. Builds a synthetic RouterBench-like
corpus, partitions it across 10 heterogeneous clients (Dirichlet α=0.6,
one logged model per query), trains both federated router families, and
compares their accuracy–cost frontiers against client-local baselines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.core import policy
from repro.data.partition import client_slice, federated_split
from repro.data.synthetic import make_eval_corpus


def main():
    key = jax.random.PRNGKey(0)
    rcfg = RouterConfig(d_emb=48, num_models=11)
    fcfg = FedConfig(rounds=20)

    print("== building synthetic evaluation corpus (11 models, 8 tasks) ==")
    corpus = make_eval_corpus(key, n_queries=4000, d_emb=rcfg.d_emb)
    split = federated_split(jax.random.PRNGKey(1), corpus, fcfg)
    tg = split["test_global"]

    def auc(router):
        *_, a = policy.eval_router(router.predict, tg["x"], tg["acc_table"],
                                   tg["cost_table"])
        return a

    print("== federated MLP-Router (Algorithm 1, 20 rounds) ==")
    fed_mlp, hist = routers.fit_federated(routers.make("mlp", rcfg),
                                          split["train"], fcfg,
                                          key=jax.random.PRNGKey(2))
    print(f"   round loss {hist['loss'][0]:.3f} → {hist['loss'][-1]:.3f}")

    print("== federated K-Means-Router (Algorithm 2, one-shot) ==")
    fed_km, _ = routers.fit_federated(routers.make("kmeans", rcfg),
                                      split["train"], fcfg,
                                      key=jax.random.PRNGKey(3))

    print("== client-local baselines (3 representative clients) ==")
    loc_aucs = []
    for i in range(3):
        loc_i, _ = routers.fit_local(routers.make("mlp", rcfg),
                                     client_slice(split["train"], i), fcfg,
                                     key=jax.random.PRNGKey(10 + i),
                                     steps=300)
        loc_aucs.append(auc(loc_i))

    class _Oracle:
        predict = staticmethod(lambda x: (tg["acc_table"], tg["cost_table"]))

    print("\nglobal-test frontier AUC:")
    print(f"  federated MLP-Router     {auc(fed_mlp):.3f}")
    print(f"  federated K-Means-Router {auc(fed_km):.3f}")
    print(f"  client-local mean        {np.mean(loc_aucs):.3f}")
    print(f"  oracle                   {auc(_Oracle):.3f}")

    print("\n== routing a few queries at different λ ==")
    for lam in (0.0, 1.0, 100.0):
        m = fed_mlp.route(tg["x"][:5], lam)
        print(f"  λ={lam:<6}→ models {m.tolist()}")


if __name__ == "__main__":
    main()
