"""Quickstart: federated LLM-router training in ~60 seconds on CPU.

Builds a synthetic RouterBench-like corpus, partitions it across 10
heterogeneous clients (Dirichlet α=0.6, one logged model per query), trains
the federated MLP-Router (Alg. 1) and the federated K-Means-Router (Alg. 2),
and compares their accuracy–cost frontiers against client-local baselines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.config import FedConfig, RouterConfig
from repro.core import federated as F
from repro.core import kmeans_router as KR
from repro.core import mlp_router as R
from repro.core import policy
from repro.data.partition import client_slice, federated_split
from repro.data.synthetic import make_eval_corpus


def main():
    key = jax.random.PRNGKey(0)
    rcfg = RouterConfig(d_emb=48, num_models=11)
    fcfg = FedConfig(rounds=20)

    print("== building synthetic evaluation corpus (11 models, 8 tasks) ==")
    corpus = make_eval_corpus(key, n_queries=4000, d_emb=rcfg.d_emb)
    split = federated_split(jax.random.PRNGKey(1), corpus, fcfg)
    tg = split["test_global"]

    def auc(pred):
        *_, a = policy.eval_router(pred, tg["x"], tg["acc_table"],
                                   tg["cost_table"])
        return a

    print("== federated MLP-Router (Algorithm 1, 20 rounds) ==")
    fed_mlp, hist = F.fedavg(jax.random.PRNGKey(2), split["train"], rcfg,
                             fcfg)
    print(f"   round loss {hist['loss'][0]:.3f} → {hist['loss'][-1]:.3f}")

    print("== federated K-Means-Router (Algorithm 2, one-shot) ==")
    fed_km = KR.fed_kmeans_router(jax.random.PRNGKey(3), split["train"],
                                  rcfg)

    print("== client-local baselines (3 representative clients) ==")
    loc_aucs = []
    for i in range(3):
        p_i, _ = F.sgd_train(jax.random.PRNGKey(10 + i),
                             client_slice(split["train"], i), rcfg, fcfg,
                             steps=300)
        loc_aucs.append(auc(lambda x, p=p_i: R.apply_mlp_router(p, x)))

    a_fed = auc(lambda x: R.apply_mlp_router(fed_mlp, x))
    a_km = auc(lambda x: KR.predict(fed_km, x))
    a_oracle = auc(lambda x: (tg["acc_table"], tg["cost_table"]))
    print(f"\nglobal-test frontier AUC:")
    print(f"  federated MLP-Router     {a_fed:.3f}")
    print(f"  federated K-Means-Router {a_km:.3f}")
    print(f"  client-local mean        {np.mean(loc_aucs):.3f}")
    print(f"  oracle                   {a_oracle:.3f}")

    print("\n== routing a few queries at different λ ==")
    A_est, C_est = R.apply_mlp_router(fed_mlp, tg["x"][:5])
    for lam in (0.0, 1.0, 100.0):
        print(f"  λ={lam:<6}→ models {policy.route(A_est, C_est, lam).tolist()}")


if __name__ == "__main__":
    main()
