"""Routed serving end-to-end (deliverable b).

Builds a pool of two real (reduced) models from the assigned architectures,
trains a federated router on synthetic evaluations of that pool through the
unified ``repro.routers`` API, then serves a batch of prompts through the
RoutedServer gateway — which takes the fitted ``Router`` directly:
per-request model selection on the fused Pallas hot path, batched prefill +
decode, λ chosen at request time.

  PYTHONPATH=src python examples/routed_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.configs import get_config
from repro.data.encoder import encode
from repro.models import init_params
from repro.serve.gateway import PoolModel, RoutedServer

PROMPTS = [
    "translate this sentence to french please",
    "prove that the sum of two even numbers is even",
    "write a short poem about autumn leaves",
    "derive the gradient of the softmax cross entropy loss",
    "summarize the plot of the odyssey in two lines",
    "solve the recurrence t(n) = 2 t(n/2) + n",
]


def main():
    d_emb = 64
    print("== building model pool (reduced assigned architectures) ==")
    pool = []
    for i, (arch, cost) in enumerate([("qwen2-1.5b", 0.05),
                                      ("yi-6b", 0.4)]):
        cfg = get_config(arch).reduced()
        pool.append(PoolModel(arch, cfg,
                              init_params(jax.random.PRNGKey(i), cfg), cost))
        print(f"   {arch}: cost/token {cost}")

    print("== synthesizing per-client evaluations of the pool ==")
    # easy prompts (short) → cheap model fine; hard prompts → big model only
    rng = np.random.default_rng(0)
    N, D = 4, 200
    rcfg = RouterConfig(d_emb=d_emb, num_models=len(pool), hidden=(64, 64))
    fcfg = FedConfig(num_clients=N, rounds=15, batch_size=32)
    words_easy = ["summarize", "translate", "poem", "short", "lines"]
    words_hard = ["prove", "derive", "solve", "gradient", "recurrence"]
    data = {k: np.zeros((N, D) + s, np.float32) for k, s in
            [("x", (d_emb,)), ("m", ()), ("acc", ()), ("cost", ()), ("w", ())]}
    for i in range(N):
        for j in range(D):
            hard = rng.random() < 0.5
            vocab = words_hard if hard else words_easy
            text = " ".join(rng.choice(vocab, size=5))
            data["x"][i, j] = encode([text], d_emb)[0]
            m = int(rng.integers(0, len(pool)))
            p_correct = (0.9 if m == 1 else (0.25 if hard else 0.85))
            data["m"][i, j] = m
            data["acc"][i, j] = float(rng.random() < p_correct)
            data["cost"][i, j] = pool[m].cost_per_token
            data["w"][i, j] = 1.0
    data = {k: jnp.asarray(v) for k, v in data.items()}
    data["m"] = data["m"].astype(jnp.int32)

    print("== federated router training over the pool evaluations ==")
    router, hist = routers.fit_federated(routers.make("mlp", rcfg), data,
                                         fcfg, key=jax.random.PRNGKey(2))
    print(f"   loss {hist['loss'][0]:.3f} → {hist['loss'][-1]:.3f}")

    srv = RoutedServer(pool, router)
    for lam in (0.0, 2.0):
        out = srv.generate(PROMPTS, lam=lam, max_new_tokens=4)
        print(f"\n== λ={lam}: total cost {out['total_cost']:.2f} ==")
        for p, r in zip(PROMPTS, out["results"]):
            print(f"   [{r['model']:<12}] {p[:48]}")


if __name__ == "__main__":
    main()
