"""Fig. 5 / §6.4: adaptive personalization under extreme heterogeneity
(Dirichlet α = 0.03). Per-client local-test AUC for federated, client-local,
and the adaptive federated/local mixture.

Deviation from the paper, documented in EXPERIMENTS.md: the paper calibrates
on the SAME training points used to fit the local router; with our tiny
extreme-α clients the local MLP memorizes its binary accuracy labels
(train-MAE → 0), which collapses the mixture weight onto the overfit local
router. We therefore hold out 20% of each client's training rows for
calibration (still the client's own offline data — no extra model calls),
which restores the paper's qualitative result. Both variants are emitted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro import routers
from repro.core import personalization as P
from repro.data.partition import client_slice


def _holdout(di, frac=0.2, seed=0):
    """Split one client's rows into fit/calibration via the w mask."""
    rng = np.random.default_rng(seed)
    w = np.asarray(di["w"])
    idx = np.where(w > 0)[0]
    rng.shuffle(idx)
    n_cal = max(1, int(len(idx) * frac))
    cal_idx = idx[:n_cal]
    w_fit, w_cal = w.copy(), np.zeros_like(w)
    w_fit[cal_idx] = 0.0
    w_cal[cal_idx] = 1.0
    fit = dict(di); cal = dict(di)
    fit["w"] = jnp.asarray(w_fit)
    cal["w"] = jnp.asarray(w_cal)
    return fit, cal


def run():
    _, split, fcfg = C.corpus_and_split(alpha=0.03, seed=7)
    t = C.Timer()
    fed_mlp, _ = C.train_fed_mlp(split, fcfg)
    locals_mlp = C.train_local_mlps(split, fcfg)
    km_fed = C.train_fed_kmeans(split, fcfg)

    rows = {"fed": [], "loc": [], "ada": [], "ada_paper": [],
            "kfed": [], "kloc": [], "kada": []}
    for i, test_i in enumerate(split["test"]):
        if test_i["x"].shape[0] < 10:
            continue
        di = client_slice(split["train"], i)
        fit_i, cal_i = _holdout(di, seed=100 + i)
        # holdout-calibrated local router (fit on 80%, calibrate on 20%)
        p_fit, _ = routers.fit_local(routers.make("mlp", C.RCFG), fit_i,
                                     fcfg, key=jax.random.PRNGKey(200 + i),
                                     steps=300)
        ada_fn, _ = P.make_personalized(fed_mlp.predict, p_fit.predict,
                                        cal_i, C.N_MODELS)
        # paper-faithful variant: calibrate on the very training points
        ada_p_fn, _ = P.make_personalized(fed_mlp.predict,
                                          locals_mlp[i].predict, di,
                                          C.N_MODELS)
        rows["fed"].append(C.auc_of(fed_mlp, test_i))
        rows["loc"].append(C.auc_of(locals_mlp[i], test_i))
        rows["ada"].append(C.auc_of(ada_fn, test_i))
        rows["ada_paper"].append(C.auc_of(ada_p_fn, test_i))

        km_loc = C.train_local_kmeans(di, seed=60 + i, fcfg=fcfg)
        km_fit = C.train_local_kmeans(fit_i, seed=60 + i, fcfg=fcfg)
        kada_fn, _ = P.make_personalized(km_fed.predict, km_fit.predict,
                                         cal_i, C.N_MODELS)
        rows["kfed"].append(C.auc_of(km_fed, test_i))
        rows["kloc"].append(C.auc_of(km_loc, test_i))
        rows["kada"].append(C.auc_of(kada_fn, test_i))

    us = t.us()
    for k, v in rows.items():
        C.emit(f"fig5_{k}_mean_local_auc", us, f"{np.mean(v):.4f}")
    # adaptive must track (or beat) the better of fed/local per client
    best = np.maximum(rows["fed"], rows["loc"])
    C.emit("fig5_ada_vs_best_gap", us,
           f"{np.mean(np.asarray(rows['ada']) - best):+.4f}")
    n_fed_losses = sum(f < l - 0.01 for f, l in zip(rows["fed"], rows["loc"]))
    C.emit("fig5_clients_where_fed_underperforms", us,
           f"{n_fed_losses}/{len(rows['fed'])}")
    return rows


if __name__ == "__main__":
    run()
