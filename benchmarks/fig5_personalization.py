"""Fig. 5 / §6.4: adaptive personalization under extreme heterogeneity
(Dirichlet α = 0.03). Per-client local-test AUC for federated, client-local,
and the adaptive federated/local mixture.

Deviation from the paper, documented in EXPERIMENTS.md: the paper calibrates
on the SAME training points used to fit the local router; with our tiny
extreme-α clients the local MLP memorizes its binary accuracy labels
(train-MAE → 0), which collapses the mixture weight onto the overfit local
router. We therefore hold out 20% of each client's training rows for
calibration (still the client's own offline data — no extra model calls),
which restores the paper's qualitative result. Both variants are emitted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import kmeans_router as KR
from repro.core import personalization as P
from repro.data.partition import client_slice


def _holdout(di, frac=0.2, seed=0):
    """Split one client's rows into fit/calibration via the w mask."""
    rng = np.random.default_rng(seed)
    w = np.asarray(di["w"])
    idx = np.where(w > 0)[0]
    rng.shuffle(idx)
    n_cal = max(1, int(len(idx) * frac))
    cal_idx = idx[:n_cal]
    w_fit, w_cal = w.copy(), np.zeros_like(w)
    w_fit[cal_idx] = 0.0
    w_cal[cal_idx] = 1.0
    fit = dict(di); cal = dict(di)
    fit["w"] = jnp.asarray(w_fit)
    cal["w"] = jnp.asarray(w_cal)
    return fit, cal


def run():
    _, split, fcfg = C.corpus_and_split(alpha=0.03, seed=7)
    t = C.Timer()
    fed_mlp, _ = C.train_fed_mlp(split, fcfg)
    locals_mlp = C.train_local_mlps(split, fcfg)
    km_fed = KR.fed_kmeans_router(jax.random.PRNGKey(3), split["train"],
                                  C.RCFG)

    rows = {"fed": [], "loc": [], "ada": [], "ada_paper": [],
            "kfed": [], "kloc": [], "kada": []}
    for i, test_i in enumerate(split["test"]):
        if test_i["x"].shape[0] < 10:
            continue
        di = client_slice(split["train"], i)
        fit_i, cal_i = _holdout(di, seed=100 + i)
        fed_fn = C.mlp_pred(fed_mlp)
        loc_fn = C.mlp_pred(locals_mlp[i])
        # holdout-calibrated local router (fit on 80%, calibrate on 20%)
        from repro.core import federated as F
        p_fit, _ = F.sgd_train(jax.random.PRNGKey(200 + i), fit_i, C.RCFG,
                               fcfg, steps=300)
        loc_fit_fn = C.mlp_pred(p_fit)
        ada_fn, _ = P.make_personalized(fed_fn, loc_fit_fn, cal_i,
                                        C.N_MODELS)
        # paper-faithful variant: calibrate on the very training points
        ada_p_fn, _ = P.make_personalized(fed_fn, loc_fn, di, C.N_MODELS)
        rows["fed"].append(C.auc_of(fed_fn, test_i))
        rows["loc"].append(C.auc_of(loc_fn, test_i))
        rows["ada"].append(C.auc_of(ada_fn, test_i))
        rows["ada_paper"].append(C.auc_of(ada_p_fn, test_i))

        km_loc = KR.local_kmeans_router(jax.random.PRNGKey(60 + i), di,
                                        C.RCFG)
        km_fit = KR.local_kmeans_router(jax.random.PRNGKey(60 + i), fit_i,
                                        C.RCFG)
        kfed_fn = C.kmeans_pred(km_fed)
        kloc_fn = C.kmeans_pred(km_loc)
        kada_fn, _ = P.make_personalized(kfed_fn, C.kmeans_pred(km_fit),
                                         cal_i, C.N_MODELS)
        rows["kfed"].append(C.auc_of(kfed_fn, test_i))
        rows["kloc"].append(C.auc_of(kloc_fn, test_i))
        rows["kada"].append(C.auc_of(kada_fn, test_i))

    us = t.us()
    for k, v in rows.items():
        C.emit(f"fig5_{k}_mean_local_auc", us, f"{np.mean(v):.4f}")
    # adaptive must track (or beat) the better of fed/local per client
    best = np.maximum(rows["fed"], rows["loc"])
    C.emit("fig5_ada_vs_best_gap", us,
           f"{np.mean(np.asarray(rows['ada']) - best):+.4f}")
    n_fed_losses = sum(f < l - 0.01 for f, l in zip(rows["fed"], rows["loc"]))
    C.emit("fig5_clients_where_fed_underperforms", us,
           f"{n_fed_losses}/{len(rows['fed'])}")
    return rows


if __name__ == "__main__":
    run()
