"""Fig. 9 / App. D.1: federated training ≈ centralized training."""
from __future__ import annotations

import jax

from benchmarks import common as C
from repro.core import kmeans_router as KR
from repro.core.kmeans import kmeans
from repro.core.kmeans_router import _cluster_stats, _finalize
from repro.data.partition import flatten_clients


def run():
    _, split, fcfg = C.corpus_and_split()
    tg = split["test_global"]
    t = C.Timer()

    fed_mlp, _ = C.train_fed_mlp(split, fcfg)
    cen_mlp = C.train_centralized(split, fcfg)
    auc_fed = C.auc_of(C.mlp_pred(fed_mlp), tg)
    auc_cen = C.auc_of(C.mlp_pred(cen_mlp), tg)

    # centralized K-means baseline: pooled K-means + pooled stats
    pooled = flatten_clients(split["train"])
    cents, _ = kmeans(jax.random.PRNGKey(5), pooled["x"], C.RCFG.k_global,
                      iters=C.RCFG.kmeans_iters, n_init=C.RCFG.n_init,
                      mask=pooled["w"] > 0)
    a, c, n = _cluster_stats(cents, pooled, C.RCFG.k_global, C.N_MODELS)
    A, Cc = _finalize(a, c, n, C.RCFG.c_max)
    cen_km = {"centroids": cents, "A": A, "C": Cc, "n": n}
    fed_km = KR.fed_kmeans_router(jax.random.PRNGKey(3), split["train"],
                                  C.RCFG)
    auc_fed_km = C.auc_of(C.kmeans_pred(fed_km), tg)
    auc_cen_km = C.auc_of(C.kmeans_pred(cen_km), tg)

    us = t.us()
    C.emit("fig9_mlp_fed_auc", us, f"{auc_fed:.4f}")
    C.emit("fig9_mlp_centralized_auc", us, f"{auc_cen:.4f}")
    C.emit("fig9_mlp_gap", us, f"{auc_fed - auc_cen:+.4f}")
    C.emit("fig9_kmeans_fed_auc", us, f"{auc_fed_km:.4f}")
    C.emit("fig9_kmeans_centralized_auc", us, f"{auc_cen_km:.4f}")
    C.emit("fig9_kmeans_gap", us, f"{auc_fed_km - auc_cen_km:+.4f}")
    return {"mlp": (auc_fed, auc_cen), "kmeans": (auc_fed_km, auc_cen_km)}


if __name__ == "__main__":
    run()
