"""Fig. 9 / App. D.1: federated training ≈ centralized training."""
from __future__ import annotations

from benchmarks import common as C
from repro.data.partition import flatten_clients


def run():
    _, split, fcfg = C.corpus_and_split()
    tg = split["test_global"]
    t = C.Timer()

    fed_mlp, _ = C.train_fed_mlp(split, fcfg)
    cen_mlp = C.train_centralized(split, fcfg)
    auc_fed = C.auc_of(fed_mlp, tg)
    auc_cen = C.auc_of(cen_mlp, tg)

    # centralized K-means baseline: pooled K-means (K = k_global) + pooled
    # stats — exactly fit_local on the flattened client data
    pooled = flatten_clients(split["train"])
    cen_km = C.train_local_kmeans(pooled, seed=5, fcfg=fcfg,
                                  k=C.RCFG.k_global)
    fed_km = C.train_fed_kmeans(split, fcfg)
    auc_fed_km = C.auc_of(fed_km, tg)
    auc_cen_km = C.auc_of(cen_km, tg)

    us = t.us()
    C.emit("fig9_mlp_fed_auc", us, f"{auc_fed:.4f}")
    C.emit("fig9_mlp_centralized_auc", us, f"{auc_cen:.4f}")
    C.emit("fig9_mlp_gap", us, f"{auc_fed - auc_cen:+.4f}")
    C.emit("fig9_kmeans_fed_auc", us, f"{auc_fed_km:.4f}")
    C.emit("fig9_kmeans_centralized_auc", us, f"{auc_cen_km:.4f}")
    C.emit("fig9_kmeans_gap", us, f"{auc_fed_km - auc_cen_km:+.4f}")
    return {"mlp": (auc_fed, auc_cen), "kmeans": (auc_fed_km, auc_cen_km)}


if __name__ == "__main__":
    run()
