"""Fig. 12 / App. D.3: onboarding new clients. Train with 7 clients, then 3
new clients join; MLP continues training on the new clients only with a
distillation regularizer; K-means does a weighted stat update. Global-test
AUC before/after, plus a forgetting check on the original clients."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks import common as C
from repro import routers


def _subset(train, idx):
    return jax.tree.map(lambda a: a[np.asarray(idx)], train)


def run():
    _, split, fcfg = C.corpus_and_split()
    tg = split["test_global"]
    old_idx, new_idx = list(range(7)), [7, 8, 9]
    t = C.Timer()

    old_train = _subset(split["train"], old_idx)
    new_train = _subset(split["train"], new_idx)

    fed7, _ = routers.fit_federated(routers.make("mlp", C.RCFG), old_train,
                                    fcfg, key=jax.random.PRNGKey(2),
                                    rounds=25)
    auc_before = C.auc_of(fed7, tg)
    # gentler adaptation: lower lr + distillation anchor (App. D.3)
    fcfg_adapt = dataclasses.replace(fcfg, lr=3e-4)
    fed10 = fed7.onboard_clients(new_train, key=jax.random.PRNGKey(3),
                                 fcfg=fcfg_adapt, rounds=10, beta=2.0)
    auc_after = C.auc_of(fed10, tg)

    # forgetting check on original clients' local tests
    old_tests = [split["test"][i] for i in old_idx
                 if split["test"][i]["x"].shape[0] >= 10]
    f_before = np.mean([C.auc_of(fed7, te) for te in old_tests])
    f_after = np.mean([C.auc_of(fed10, te) for te in old_tests])

    km7, _ = routers.fit_federated(routers.make("kmeans", C.RCFG), old_train,
                                   fcfg, key=jax.random.PRNGKey(4))
    km10 = km7.onboard_clients(new_train)
    auc_km_before = C.auc_of(km7, tg)
    auc_km_after = C.auc_of(km10, tg)

    us = t.us()
    C.emit("fig12_mlp_auc_before_join", us, f"{auc_before:.4f}")
    C.emit("fig12_mlp_auc_after_join", us, f"{auc_after:.4f}")
    C.emit("fig12_mlp_old_clients_auc_before", us, f"{f_before:.4f}")
    C.emit("fig12_mlp_old_clients_auc_after", us, f"{f_after:.4f}")
    C.emit("fig12_kmeans_auc_before_join", us, f"{auc_km_before:.4f}")
    C.emit("fig12_kmeans_auc_after_join", us, f"{auc_km_after:.4f}")
    return {"mlp": (auc_before, auc_after),
            "kmeans": (auc_km_before, auc_km_after)}


if __name__ == "__main__":
    run()
