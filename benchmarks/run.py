# One module per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import traceback

MODULES = [
    "benchmarks.fig2_global_frontier",   # Fig. 2  fed vs local (global test)
    "benchmarks.fig3_local_tests",       # Fig. 3/10/11  local tests
    "benchmarks.fig9_centralized",       # Fig. 9  fed vs centralized
    "benchmarks.fig4_new_models",        # Fig. 4  model onboarding
    "benchmarks.fig12_new_clients",      # Fig. 12 client onboarding
    "benchmarks.fig5_personalization",   # Fig. 5  adaptive personalization
    "benchmarks.tab1_encoders",          # Tab. 1  encoder ablation
    "benchmarks.appF_proxrouter",        # App. F  second benchmark
    "benchmarks.thm51_convergence",      # Thm 5.1 convergence trend
    "benchmarks.thm53_suboptimality",    # Thm 5.3 Õ(1/√D) subopt trend
    "benchmarks.kernels_bench",          # kernel hot-path timings
    "benchmarks.roofline",               # §Roofline table from the dry-run
]


def main() -> None:
    from benchmarks import common as C

    print("name,us_per_call,derived")
    failures = 0
    only = sys.argv[1:] if len(sys.argv) > 1 else None
    for mod_name in MODULES:
        if only and not any(o in mod_name for o in only):
            continue
        n_before = len(C._RECORDS)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:
            failures += 1
            # drop this module's partial records — an aborted figure must
            # not serialize half its measurements as if they completed
            del C._RECORDS[n_before:]
            print(f"{mod_name},0.0,EXCEPTION")
            traceback.print_exc()
    if only is None:  # a filtered/debug run must not clobber the full set
        path = C.write_bench("BENCH_figures.json",
                             meta={"failures": failures})
        print(f"wrote {path}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
