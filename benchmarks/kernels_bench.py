"""Micro-benchmarks of the routing hot-path ops (jitted jnp oracles on CPU —
the Pallas kernels target TPU and are validated in interpret mode by tests).
us_per_call is a real wall-clock measurement here."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.kernels import ref


def run():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8192, 768))
    cents = jax.random.normal(key, (20, 768))
    f1 = jax.jit(ref.kmeans_assign_ref)
    C.emit("kernel_kmeans_assign_8192x768x20", C.timeit(f1, x, cents),
           "routing-assignment oracle")

    w = jnp.ones((8192,))
    f1r = jax.jit(ref.kmeans_assign_reduce_ref)
    C.emit("kernel_kmeans_assign_reduce_8192x768x20",
           C.timeit(f1r, x, cents, w), "fused Lloyd's-step oracle")

    h = jax.random.normal(key, (4096, 512))
    aw = jax.random.normal(key, (512, 11)) * 0.05
    cw = jax.random.normal(key, (512, 11)) * 0.05
    b = jnp.zeros(11)
    f2 = jax.jit(lambda h: ref.router_utility_ref(h, aw, b, cw, b, 0.5))
    C.emit("kernel_router_utility_4096x512x11", C.timeit(f2, h),
           "fused routing decision oracle")

    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.bfloat16)
    f3 = jax.jit(lambda q: ref.flash_attention_ref(q, q, q, causal=True))
    C.emit("kernel_flash_attention_1x1024x8x64", C.timeit(f3, q, iters=5),
           "attention oracle")
    return None


if __name__ == "__main__":
    run()
