"""Micro-benchmarks of the routing hot-path ops (jitted jnp oracles on CPU —
the Pallas kernels target TPU and are validated in interpret mode by tests).
us_per_call is a real wall-clock measurement here."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.kernels import ref


def _time(f, *args, iters=20):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) * 1e6 / iters


def run():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8192, 768))
    cents = jax.random.normal(key, (20, 768))
    f1 = jax.jit(ref.kmeans_assign_ref)
    C.emit("kernel_kmeans_assign_8192x768x20", _time(f1, x, cents),
           "routing-assignment oracle")

    h = jax.random.normal(key, (4096, 512))
    aw = jax.random.normal(key, (512, 11)) * 0.05
    cw = jax.random.normal(key, (512, 11)) * 0.05
    b = jnp.zeros(11)
    f2 = jax.jit(lambda h: ref.router_utility_ref(h, aw, b, cw, b, 0.5))
    C.emit("kernel_router_utility_4096x512x11", _time(f2, h),
           "fused routing decision oracle")

    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.bfloat16)
    f3 = jax.jit(lambda q: ref.flash_attention_ref(q, q, q, causal=True))
    C.emit("kernel_flash_attention_1x1024x8x64", _time(f3, q, iters=5),
           "attention oracle")
    return None


if __name__ == "__main__":
    run()
