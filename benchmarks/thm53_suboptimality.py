"""Theorem 5.3 empirical analogue: routing suboptimality vs dataset size.

The bound predicts Subopt(π̂_D) = Õ(1/√D) — the oracle-vs-router AUC gap
should shrink as the (pooled) training set grows. We train the centralized
MLP-Router at D ∈ {250, 1000, 4000} samples and report the gap."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks import common as C
from repro import routers
from repro.data.partition import federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus


def run():
    t = C.Timer()
    corpus = make_eval_corpus(jax.random.PRNGKey(9), n_queries=8000,
                              n_tasks=C.N_TASKS, n_models=C.N_MODELS,
                              d_emb=C.D_EMB)
    fcfg = dataclasses.replace(C.FCFG, seed=9, dirichlet_alpha=100.0)
    split = federated_split(jax.random.PRNGKey(9), corpus, fcfg)
    tg = split["test_global"]
    auc_oracle = C.auc_of(lambda x: (tg["acc_table"], tg["cost_table"]), tg)

    pooled = flatten_clients(split["train"])
    order = np.where(np.asarray(pooled["w"]) > 0)[0]
    gaps = {}
    for D in (250, 1000, 4000):
        sub = jax.tree.map(lambda a: a[order[:D]], pooled)
        p, _ = routers.fit_local(routers.make("mlp", C.RCFG), sub, fcfg,
                                 key=jax.random.PRNGKey(10), steps=400)
        auc = C.auc_of(p, tg)
        gaps[D] = auc_oracle - auc
        C.emit(f"thm53_D{D}_subopt_gap", t.us(), f"{gaps[D]:.4f}")
    C.emit("thm53_gap_shrinks_with_D", t.us(),
           str(bool(gaps[4000] <= gaps[250] + 1e-3)))
    return gaps


if __name__ == "__main__":
    run()
