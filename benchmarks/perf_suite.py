"""Hot-path perf suite → BENCH_{train,route,serve,engine}.json.

Measures the wall-clock consumers this repo optimizes — federated
training rounds, the K-means routing math, the serving gateway, and the
continuous-batching engine under Poisson traffic — each against its
pre-fusion baseline, with warmup-then-measure methodology and
``block_until_ready``-correct timers (see benchmarks/common.timeit).

  PYTHONPATH=src python -m benchmarks.perf_suite            # full run
  PYTHONPATH=src python -m benchmarks.perf_suite --smoke    # CI: tiny +
                                                            # JSON validity

``--smoke`` shrinks every workload so the suite finishes in minutes. CI
asserts the JSON files are produced and well-formed; absolute CPU CI
timing is too noisy for thresholds, so the one *relative* floor enforced
is that the engine's traffic throughput never drops below the
per-request gateway path on the same trace (BENCH_engine.smoke.json
speedup >= 1).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.core import federated as F
from repro.data.partition import federated_split
from repro.data.synthetic import make_eval_corpus
from repro.kernels import ops as kops


def _bench_file(section: str, smoke: bool) -> str:
    """Smoke runs write *.smoke.json so they can never clobber the
    git-tracked full-run trajectory files."""
    return f"BENCH_{section}{'.smoke' if smoke else ''}.json"


# ---------------------------------------------------------------------------
# train: scan-fused FedAvg vs the per-round loop
# ---------------------------------------------------------------------------


def bench_train(smoke: bool) -> None:
    import functools

    from repro.core import mlp_router as R

    rounds = 5 if smoke else 30
    rcfg = RouterConfig(d_emb=16, num_models=8, hidden=(32, 32))
    fcfg = FedConfig(num_clients=8, batch_size=128, rounds=rounds)
    corpus = make_eval_corpus(jax.random.PRNGKey(0),
                              n_queries=200 if smoke else 400,
                              n_tasks=4, n_models=8, d_emb=16)
    data = federated_split(jax.random.PRNGKey(1), corpus, fcfg)["train"]
    key = jax.random.PRNGKey(2)
    max_steps = max(1, int(np.ceil(data["x"].shape[1] / fcfg.batch_size))) \
        * fcfg.local_epochs

    def prepr_fit():
        """The pre-scan driver verbatim: a FRESH jit per fit (recompiles
        every call) + one host sync per round."""
        opt = F._make_opt(fcfg, "adamw")
        k, k_init = jax.random.split(key)
        params = R.init_mlp_router(key=k_init, cfg=rcfg)
        round_fn = jax.jit(functools.partial(
            F.fedavg_round, rcfg=rcfg, fcfg=fcfg, opt=opt,
            max_steps=max_steps))
        for _ in range(rounds):
            k, k_r = jax.random.split(k)
            params, loss = round_fn(params, data, k_r)
            float(loss)
        return params

    def loop_fit():  # cached per-round jit, still one dispatch+sync/round
        return F.fedavg(key, data, rcfg, fcfg, eval_fn=lambda p: None)[0]

    def scan_fit():  # the fused path: one dispatch, one sync per fit
        return F.fedavg(key, data, rcfg, fcfg)[0]

    repeats = 2 if smoke else 5
    prepr = C.timeit(prepr_fit, warmup=1, iters=1, repeats=repeats)
    loop = C.timeit(loop_fit, warmup=1, iters=1, repeats=repeats)
    fused = C.timeit(scan_fit, warmup=1, iters=1, repeats=repeats)
    C.emit(f"fedavg_prepr_{rounds}r", prepr,
           "pre-PR driver: jit per fit + sync per round")
    C.emit(f"fedavg_loop_{rounds}r", loop,
           "cached per-round jit + sync per round",
           speedup_vs_baseline=prepr / loop)
    C.emit(f"fedavg_scan_{rounds}r", fused, "lax.scan-fused rounds",
           speedup_vs_baseline=prepr / fused)
    C.emit(f"fedavg_scan_vs_loop_{rounds}r", fused,
           "scan fusion alone (vs cached loop)",
           speedup_vs_baseline=loop / fused)
    C.write_bench(_bench_file("train", smoke), meta={"rounds": rounds,
                                                     "smoke": smoke})


# ---------------------------------------------------------------------------
# route: fused assign-reduce + incremental k-means++ vs their baselines
# ---------------------------------------------------------------------------


def bench_route(smoke: bool) -> None:
    n, d, K = (512, 32, 8) if smoke else (8192, 64, 32)
    kx, kc = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (n, d))
    cents = jax.random.normal(kc, (K, d))
    w = jnp.ones((n,))

    # Lloyd's step, pre-fusion: assign kernel + host-visible one-hot scatter
    @jax.jit
    def lloyd_step_onehot(x, cents, w):
        assign = kops.kmeans_assign(x, cents)
        onehot = jax.nn.one_hot(assign, K, dtype=x.dtype)
        wv = onehot * w[:, None]
        return wv.T @ x, jnp.sum(wv, axis=0)

    @jax.jit
    def lloyd_step_fused(x, cents, w):
        _, sums, cnts = kops.kmeans_assign_reduce(x, cents, w)
        return sums, cnts

    base = C.timeit(lloyd_step_onehot, x, cents, w, repeats=5)
    fused = C.timeit(lloyd_step_fused, x, cents, w, repeats=5)
    C.emit(f"lloyd_step_onehot_{n}x{d}x{K}", base, "assign + one-hot scatter")
    C.emit(f"lloyd_step_fused_{n}x{d}x{K}", fused,
           "fused assign-reduce (on CPU both run the jnp oracle — expect "
           "~1x; the fusion win is the Pallas TPU kernel)",
           speedup_vs_baseline=base / fused)

    # k-means++ seeding: O(n·K·d) broadcast (pre-change) vs incremental
    from repro.core.kmeans import _plusplus_init

    def plusplus_broadcast(key, X, w):  # the replaced implementation
        n = X.shape[0]
        k0, key = jax.random.split(key)
        first = jax.random.choice(k0, n, p=w / jnp.sum(w))
        cents0 = jnp.zeros((K, X.shape[1]), X.dtype).at[0].set(X[first])

        def body(i, carry):
            cents, key = carry
            d2 = jnp.min(
                jnp.sum((X[:, None, :] - cents[None, :, :]) ** 2, -1)
                + jnp.where(jnp.arange(K)[None, :] < i, 0.0, jnp.inf),
                axis=1)
            p = d2 * w
            p = jnp.where(jnp.isfinite(p), p, 0.0)
            p = p / jnp.maximum(jnp.sum(p), 1e-12)
            key, sub = jax.random.split(key)
            nxt = jax.random.choice(sub, n, p=p)
            return cents.at[i].set(X[nxt]), key

        cents, _ = jax.lax.fori_loop(1, K, body, (cents0, key))
        return cents

    k = jax.random.PRNGKey(3)
    base_pp = C.timeit(jax.jit(plusplus_broadcast), k, x, w)
    fast_pp = C.timeit(jax.jit(lambda k, X, w: _plusplus_init(k, X, w, K)),
                       k, x, w)
    C.emit(f"plusplus_broadcast_{n}x{d}x{K}", base_pp, "O(n*K*d) per step")
    C.emit(f"plusplus_incremental_{n}x{d}x{K}", fast_pp,
           "O(n*d) per step", speedup_vs_baseline=base_pp / fast_pp)
    C.write_bench(_bench_file("route", smoke), meta={"n": n, "d": d,
                                                     "K": K, "smoke": smoke})


# ---------------------------------------------------------------------------
# serve: scan-fused decode + cached jit vs the per-token loop
# ---------------------------------------------------------------------------


def bench_serve(smoke: bool) -> None:
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.gateway import PoolModel, RoutedServer

    cfg = get_config("qwen2-1.5b").reduced()
    pool = [PoolModel("qwen2-1.5b", cfg,
                      init_params(jax.random.PRNGKey(0), cfg), 0.1)]
    router = routers.make(
        "kmeans", RouterConfig(d_emb=64, num_models=1),
        state={"centroids": jnp.zeros((1, 64)),
               "A": jnp.array([[0.9]]), "C": jnp.array([[0.1]]),
               "n": jnp.ones((1, 1))})
    srv = RoutedServer(pool, router)
    prompts = ["write a poem about the sea", "solve this integral now",
               "summarize the meeting notes", "prove the theorem carefully"]
    max_new = 4 if smoke else 32
    iters = 1 if smoke else 5

    base = C.timeit(lambda: srv.generate(prompts, lam=0.5,
                                         max_new_tokens=max_new,
                                         engine=False, scan_decode=False),
                    warmup=1, iters=iters)
    fused = C.timeit(lambda: srv.generate(prompts, lam=0.5,
                                          max_new_tokens=max_new,
                                          engine=False),
                     warmup=1, iters=iters)
    C.emit(f"generate_token_loop_b4_t{max_new}", base,
           "per-token dispatch + host sync")
    C.emit(f"generate_scan_decode_b4_t{max_new}", fused,
           "scan decode, one transfer", speedup_vs_baseline=base / fused)

    route_us = C.timeit(lambda: srv.route(prompts, 0.5), warmup=2,
                        iters=max(iters, 3))
    C.emit("route_batch4", route_us, "encode + cached-jit route")
    C.write_bench(_bench_file("serve", smoke),
                  meta={"model": cfg.name, "max_new": max_new,
                        "smoke": smoke})


# ---------------------------------------------------------------------------
# engine: continuous batching under Poisson traffic vs per-request serving
# ---------------------------------------------------------------------------


_WORDS = ("write solve prove summarize explain draft the a of this that "
          "integral poem theorem meeting notes carefully quickly now "
          "report plan code review data model chart essay story").split()


def _make_traffic(seed: int, n_req: int, rate_per_s: float,
                  longtail: bool = False):
    """Poisson arrivals (Exp inter-arrival at ``rate_per_s``) with a
    per-request routing λ. Default prompt mix is 2–12 words; ``longtail``
    draws the production-shaped mix instead — mostly short prompts with a
    heavy tail of long ones (~15% at 24–56 words), the regime where
    uniform max_seq slot reservation wastes most of the KV pool."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_per_s, n_req))
    reqs = []
    for i in range(n_req):
        if longtail and rng.random() < 0.15:
            n_words = int(rng.integers(24, 57))
        else:
            n_words = int(rng.integers(2, 13))
        prompt = " ".join(rng.choice(_WORDS, n_words))
        lam = float(rng.choice([0.2, 0.5, 2.0]))
        reqs.append({"prompt": prompt, "lam": lam,
                     "arrival": float(arrivals[i])})
    return reqs


def _run_engine_traffic(srv, reqs, max_new):
    """Replay the trace against the engine: submit each request when its
    arrival time passes, step the in-flight batch between admissions.
    Returns (tokens/sec over the busy window, per-request latencies)."""
    import time
    pending = sorted(reqs, key=lambda r: r["arrival"])
    arrival_of, completion = {}, {}
    t0 = time.perf_counter()
    i = 0
    while i < len(pending) or srv.engine.busy:
        now = time.perf_counter() - t0
        while i < len(pending) and pending[i]["arrival"] <= now:
            rid = srv.submit(pending[i]["prompt"], lam=pending[i]["lam"],
                             max_new_tokens=max_new)
            arrival_of[rid] = pending[i]["arrival"]
            i += 1
        if srv.engine.busy:
            for rid, _ in srv.step():
                completion[rid] = time.perf_counter() - t0
        elif i < len(pending):
            time.sleep(min(pending[i]["arrival"] - now, 1e-3))
    makespan = max(completion.values())
    srv.drain()              # clear the engine's buffered results
    lat = np.array([completion[r] - arrival_of[r] for r in completion])
    return len(reqs) * max_new / makespan, lat


def _run_per_request_traffic(srv, reqs, max_new):
    """The same trace served one request at a time on the legacy scan path
    (requests queue behind each other — the pre-engine deployment)."""
    import time
    lat = []
    t0 = time.perf_counter()
    for r in sorted(reqs, key=lambda q: q["arrival"]):
        now = time.perf_counter() - t0
        if r["arrival"] > now:
            time.sleep(r["arrival"] - now)
        srv.generate([r["prompt"]], lam=r["lam"], max_new_tokens=max_new,
                     engine=False)
        lat.append(time.perf_counter() - t0 - r["arrival"])
    makespan = time.perf_counter() - t0
    return len(reqs) * max_new / makespan, np.array(lat)


def bench_engine(smoke: bool) -> None:
    """Traffic simulation: Poisson arrivals into the continuous-batching
    engine vs the same trace served per-request. Reports decode tokens/sec
    and p50/p99 request latency for both; the acceptance bar is ≥2×
    tokens/sec at concurrency ≥ 8 (slots)."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.engine import EngineConfig
    from repro.serve.gateway import PoolModel, RoutedServer

    cfg = get_config("qwen2-1.5b").reduced()
    pool = [PoolModel("qwen2-1.5b", cfg,
                      init_params(jax.random.PRNGKey(0), cfg), 0.1)]
    router = routers.make(
        "kmeans", RouterConfig(d_emb=64, num_models=1),
        state={"centroids": jnp.zeros((1, 64)),
               "A": jnp.array([[0.9]]), "C": jnp.array([[0.1]]),
               "n": jnp.ones((1, 1))})
    n_req, max_new, chunk = (10, 8, 4) if smoke else (24, 32, 8)
    ecfg = EngineConfig(slots=8, max_seq=64, chunk=chunk)
    srv = RoutedServer(pool, router, engine_cfg=ecfg)

    # arrival rate: an (over)saturating Poisson stream so the offered
    # concurrency exceeds the 8 slots and admissions happen mid-flight
    reqs = _make_traffic(0, n_req, rate_per_s=200.0 if smoke else 50.0)

    # warm every (config, bucket) program on both paths, off the clock
    warm = {r["prompt"]: None for r in reqs}
    for p in warm:
        srv.submit(p, lam=0.5, max_new_tokens=max_new)
    srv.drain()
    for p in warm:
        srv.generate([p], lam=0.5, max_new_tokens=max_new, engine=False)

    # best-of-repeats per path: a traffic replay can't run under timeit,
    # so repeat the whole scenario (scheduler-noise resistance, same
    # statistic as benchmarks.common.timeit)
    repeats = 2
    eng_tps, eng_lat = max((_run_engine_traffic(srv, reqs, max_new)
                            for _ in range(repeats)), key=lambda r: r[0])
    base_tps, base_lat = max((_run_per_request_traffic(srv, reqs, max_new)
                              for _ in range(repeats)), key=lambda r: r[0])

    C.emit(f"engine_traffic_{n_req}req_t{max_new}", 1e6 / eng_tps,
           f"continuous batching, {ecfg.slots} slots: us per decoded token "
           f"(= {eng_tps:.0f} tok/s); p50/p99 latency "
           f"{np.percentile(eng_lat, 50) * 1e3:.0f}/"
           f"{np.percentile(eng_lat, 99) * 1e3:.0f} ms",
           speedup_vs_baseline=eng_tps / base_tps)
    C.emit(f"per_request_traffic_{n_req}req_t{max_new}", 1e6 / base_tps,
           f"per-request gateway path (= {base_tps:.0f} tok/s); p50/p99 "
           f"latency {np.percentile(base_lat, 50) * 1e3:.0f}/"
           f"{np.percentile(base_lat, 99) * 1e3:.0f} ms")
    C.write_bench(_bench_file("engine", smoke), meta={
        "model": cfg.name, "n_req": n_req, "max_new": max_new,
        "slots": ecfg.slots, "chunk": chunk, "smoke": smoke,
        "engine_tokens_per_s": round(eng_tps, 1),
        "per_request_tokens_per_s": round(base_tps, 1),
        "speedup": round(eng_tps / base_tps, 3),
        "engine_latency_ms": {
            "p50": round(float(np.percentile(eng_lat, 50)) * 1e3, 1),
            "p99": round(float(np.percentile(eng_lat, 99)) * 1e3, 1)},
        "per_request_latency_ms": {
            "p50": round(float(np.percentile(base_lat, 50)) * 1e3, 1),
            "p99": round(float(np.percentile(base_lat, 99)) * 1e3, 1)},
    })


# ---------------------------------------------------------------------------
# paged: paged pool + coalesced prefill vs the uniform-slot engine at
# (near-)equal KV bytes under long-tail Poisson traffic
# ---------------------------------------------------------------------------


def _run_traffic_instrumented(srv, reqs, max_new):
    """Replay the trace against an engine and also record what the paged
    comparison needs: peak in-flight concurrency (sampled every step) and
    per-request admission latency (engine.admission_lat deltas).
    Returns (tokens/sec, completion latencies, max in-flight, admission
    latencies)."""
    import time
    pending = sorted(reqs, key=lambda r: r["arrival"])
    arrival_of, completion = {}, {}
    adm0 = len(srv.engine.admission_lat)
    srv.engine.peak_active = 0
    t0 = time.perf_counter()
    i = 0
    while i < len(pending) or srv.engine.busy:
        now = time.perf_counter() - t0
        while i < len(pending) and pending[i]["arrival"] <= now:
            rid = srv.submit(pending[i]["prompt"], lam=pending[i]["lam"],
                             max_new_tokens=max_new)
            arrival_of[rid] = pending[i]["arrival"]
            i += 1
        if srv.engine.busy:
            for rid, _ in srv.step():
                completion[rid] = time.perf_counter() - t0
        elif i < len(pending):
            time.sleep(min(pending[i]["arrival"] - now, 1e-3))
    makespan = max(completion.values())
    srv.drain()
    lat = np.array([completion[r] - arrival_of[r] for r in completion])
    adm = np.array(list(srv.engine.admission_lat)[adm0:])
    return (len(reqs) * max_new / makespan, lat, srv.engine.peak_active,
            adm)


def bench_paged(smoke: bool) -> None:
    """Long-tail traffic sim: the paged engine (page-granular reservation,
    coalesced prefill) vs the PR 3 uniform-slot engine holding the SAME KV
    pool bytes — uniform must spend them on worst-case max_seq regions, so
    at equal memory it fields half the decode slots. Acceptance: strictly
    more peak in-flight requests per byte of KV pool, and lower p99
    admission latency under Poisson bursts (the queue drains through twice
    the admission capacity). Every request's tokens stay bit-identical to
    solo serving (property-tested in tests/, not re-asserted here)."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.engine import EngineConfig
    from repro.serve.gateway import PoolModel, RoutedServer

    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)

    def mk(ecfg):
        pool = [PoolModel("qwen2-1.5b", cfg, params, 0.1)]
        router = routers.make(
            "kmeans", RouterConfig(d_emb=64, num_models=1),
            state={"centroids": jnp.zeros((1, 64)),
                   "A": jnp.array([[0.9]]), "C": jnp.array([[0.1]]),
                   "n": jnp.ones((1, 1))})
        return RoutedServer(pool, router, engine_cfg=ecfg)

    if smoke:
        n_req, max_new, chunk, max_seq, ps = 12, 4, 4, 64, 16
        paged_cfg = EngineConfig(slots=8, max_seq=max_seq, chunk=chunk,
                                 page_size=ps, pages=16)   # 272 positions
        uni_cfg = EngineConfig(slots=4, max_seq=max_seq, chunk=chunk,
                               page_size=None)             # 256 positions
        # effectively a t=0 burst: every request is queued before the
        # first chunk, so BOTH engines deterministically saturate their
        # admission capacity (peak in-flight = slots) no matter how fast
        # the CI runner decodes — the in-flight-per-byte floor ci.yml
        # enforces is then capacity accounting, not a wall-clock race
        rate = 1e5
    else:
        n_req, max_new, chunk, max_seq, ps = 32, 16, 8, 128, 16
        paged_cfg = EngineConfig(slots=16, max_seq=max_seq, chunk=chunk,
                                 page_size=ps, pages=64)   # 1040 positions
        uni_cfg = EngineConfig(slots=8, max_seq=max_seq, chunk=chunk,
                               page_size=None)             # 1024 positions
        rate = 100.0

    reqs = _make_traffic(0, n_req, rate_per_s=rate, longtail=True)
    srv_p, srv_u = mk(paged_cfg), mk(uni_cfg)

    # warm every (config, bucket) program on both engines, off the clock
    for srv in (srv_p, srv_u):
        for p in {r["prompt"] for r in reqs}:
            srv.submit(p, lam=0.5, max_new_tokens=max_new)
        srv.drain()
    # the paged engine coalesces admissions, so its prefill/write trace
    # set is (B_b, S_b) PAIRS — which grouping the replay produces depends
    # on wall-clock arrival vs chunk boundaries. Warm every reachable pair
    # directly through the cached jit stages (writes target the trash
    # page), so no compile ever lands inside the timed replay.
    from repro.serve import engine as E
    lane = srv_p.engine._lanes[0]
    s_buckets = sorted({E.next_pow2(len(r["prompt"].split()))
                        for r in reqs})
    pf, wf = E._prefill_fn(cfg), E._write_pages_fn(cfg)
    B = 1
    while B <= paged_cfg.slots:
        for S_b in s_buckets:
            n_pp = -(-S_b // ps)
            _, kv = pf(params, jnp.zeros((B, S_b), jnp.int32),
                       jnp.zeros((B,), jnp.int32))
            lane.pool = wf(lane.pool, kv, jnp.zeros((B, n_pp), jnp.int32))
        B *= 2

    repeats = 2
    p_tps, p_lat, p_inf, p_adm = max(
        (_run_traffic_instrumented(srv_p, reqs, max_new)
         for _ in range(repeats)), key=lambda r: r[0])
    u_tps, u_lat, u_inf, u_adm = max(
        (_run_traffic_instrumented(srv_u, reqs, max_new)
         for _ in range(repeats)), key=lambda r: r[0])

    p_bytes, u_bytes = srv_p.engine.kv_pool_bytes(), \
        srv_u.engine.kv_pool_bytes()
    p_per_mb = p_inf / (p_bytes / 2 ** 20)
    u_per_mb = u_inf / (u_bytes / 2 ** 20)

    def _pcts(arr):
        """The JSON latency schema, defined once: {p50, p99} in ms."""
        return {"p50": round(float(np.percentile(arr, 50)) * 1e3, 1),
                "p99": round(float(np.percentile(arr, 99)) * 1e3, 1)}

    C.emit(f"paged_traffic_{n_req}req_t{max_new}", 1e6 / p_tps,
           f"paged pool ({paged_cfg.slots} slots, {paged_cfg.resolved_pages}"
           f" pages of {ps}) + coalesced prefill: us/decoded token "
           f"(= {p_tps:.0f} tok/s); peak in-flight {p_inf} on "
           f"{p_bytes / 2 ** 20:.1f} MB; admission p50/p99 "
           f"{np.percentile(p_adm, 50) * 1e3:.0f}/"
           f"{np.percentile(p_adm, 99) * 1e3:.0f} ms",
           speedup_vs_baseline=p_tps / u_tps)
    C.emit(f"uniform_traffic_{n_req}req_t{max_new}", 1e6 / u_tps,
           f"uniform slots ({uni_cfg.slots} x max_seq={max_seq}) at equal "
           f"KV bytes: us/decoded token (= {u_tps:.0f} tok/s); peak "
           f"in-flight {u_inf} on {u_bytes / 2 ** 20:.1f} MB; admission "
           f"p50/p99 {np.percentile(u_adm, 50) * 1e3:.0f}/"
           f"{np.percentile(u_adm, 99) * 1e3:.0f} ms")
    C.write_bench(_bench_file("paged", smoke), meta={
        "model": cfg.name, "n_req": n_req, "max_new": max_new,
        "smoke": smoke, "page_size": ps,
        "paged": {"slots": paged_cfg.slots,
                  "pages": paged_cfg.resolved_pages,
                  "kv_pool_bytes": int(p_bytes),
                  "tokens_per_s": round(p_tps, 1),
                  "max_inflight": int(p_inf),
                  "inflight_per_mb": round(p_per_mb, 3),
                  "admission_ms": _pcts(p_adm),
                  "latency_ms": _pcts(p_lat)},
        "uniform": {"slots": uni_cfg.slots,
                    "kv_pool_bytes": int(u_bytes),
                    "tokens_per_s": round(u_tps, 1),
                    "max_inflight": int(u_inf),
                    "inflight_per_mb": round(u_per_mb, 3),
                    "admission_ms": _pcts(u_adm),
                    "latency_ms": _pcts(u_lat)},
        "inflight_per_byte_ratio": round(p_per_mb / u_per_mb, 3),
        "admission_p99_ratio": round(
            float(np.percentile(p_adm, 99) / np.percentile(u_adm, 99)), 3),
    })


# ---------------------------------------------------------------------------
# preempt: deadline goodput under pool oversubscription — preemption with
# recompute-on-resume vs admission stalling vs load shedding
# ---------------------------------------------------------------------------


def _deadline_traffic(seed: int, n_req: int, max_new: int, chunk: int,
                      slack: int, scale: float = 1.0, tail: float = 0.3,
                      long_words: tuple = (24, 57)):
    """Long-tail Poisson arrivals on the ENGINE-STEP clock: exponential
    inter-arrival gaps (mean ``scale`` steps) and per-request deadlines of
    slack..2·slack service times. Step-clock arrivals make every run of
    the schedule deterministic — goodput differences between admission
    policies are scheduling accounting, not a wall-clock race CI could
    lose."""
    rng = np.random.default_rng(seed)
    steps = np.floor(np.cumsum(rng.exponential(scale, n_req))).astype(int)
    svc = -(-max_new // chunk)               # solo decode steps
    evs = []
    for i in range(n_req):
        long = rng.random() < tail           # the long tail
        n_words = int(rng.integers(*long_words) if long
                      else rng.integers(2, 13))
        # batch-style long jobs run with loose deadlines; interactive
        # shorts are tight — the regime where latest-deadline-first
        # eviction pays (shorts preempt longs, longs still finish)
        loose = 4 if long else 1
        evs.append({"prompt": " ".join(rng.choice(_WORDS, n_words)),
                    "step": int(steps[i]),
                    "deadline": int(svc * slack * loose
                                    + rng.integers(0, svc * slack))})
    return evs


def _run_deadline_traffic(srv, events, max_new):
    """Replay a step-clock deadline trace: submit each arrival at its
    step, advance one chunk per step, drain, and fold the engine's typed
    terminals into goodput accounting. Deadline-met tokens (requests that
    COMPLETED — the engine kills deadline-missers, so completion implies
    the deadline was met) are deterministic; wall time is informational."""
    import time
    from repro.serve.engine import DONE, PREEMPTED_RESUMED
    ev = sorted(events, key=lambda e: e["step"])
    adm0 = len(srv.engine.admission_lat)
    meta, i, step = {}, 0, 0
    t0 = time.perf_counter()
    while i < len(ev) or srv.engine.busy:
        while i < len(ev) and ev[i]["step"] <= step:
            rid = srv.submit(ev[i]["prompt"], lam=0.5,
                             max_new_tokens=max_new,
                             deadline=ev[i]["deadline"])
            meta[rid] = ev[i]
            i += 1
        srv.step()
        step += 1
    wall = time.perf_counter() - t0
    res = srv.drain()                        # whole done buffer — keep
    eng = srv.engine                         # only THIS run's rids
    completed = {r: res[r] for r in meta if r in res
                 and eng.status(r) in (DONE, PREEMPTED_RESUMED)}
    adm = np.array(list(eng.admission_lat)[adm0:] or [0.0])
    return {"meta": meta, "completed": completed,
            "met_tokens": int(sum(len(v) for v in completed.values())),
            "wall_s": wall,
            "admission_p99_ms": round(
                float(np.percentile(adm, 99)) * 1e3, 2)}


def bench_preempt(smoke: bool) -> None:
    """Overload policy comparison at 2× and 4× page-pool oversubscription
    (pool = what full concurrency needs, divided by the factor) under
    long-tail Poisson deadline traffic: ``stall`` (lifetime reservation —
    admission waits for worst-case pages), ``preempt`` (initial
    reservation + on-demand growth + latest-deadline-first eviction with
    recompute-on-resume), ``shed`` (lifetime + bounded queue,
    reject-latest-deadline). Reports deadline-met tokens (deterministic),
    wall goodput, p99 admission latency, and the resilience counters.
    Acceptance (ci.yml enforces on the smoke JSON): preempt's met tokens
    at 2× beat stall's, every completed request in preempt mode is
    bit-identical to solo serving (resume parity), and the measured
    replay of every (factor, policy) cell adds ZERO decode retraces."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import gateway as G
    from repro.serve.engine import EngineConfig, PREEMPTED_RESUMED
    from repro.serve.gateway import PoolModel, RoutedServer

    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)

    def mk(ecfg):
        pool = [PoolModel("qwen2-1.5b", cfg, params, 0.1)]
        router = routers.make(
            "kmeans", RouterConfig(d_emb=64, num_models=1),
            state={"centroids": jnp.zeros((1, 64)),
                   "A": jnp.array([[0.9]]), "C": jnp.array([[0.1]]),
                   "n": jnp.ones((1, 1))})
        return RoutedServer(pool, router, engine_cfg=ecfg)

    if smoke:
        n_req, max_new, chunk, max_seq, ps, slots = 16, 16, 4, 64, 8, 4
        slack, scale, long_words = 2, 0.25, (24, 41)   # region ≤ max_seq
    else:
        n_req, max_new, chunk, max_seq, ps, slots = 48, 32, 8, 128, 16, 8
        slack, scale, long_words = 2, 0.25, (24, 57)
    base_pages = slots * (max_seq // ps)     # full-concurrency worst case
    events = _deadline_traffic(0, n_req, max_new, chunk, slack=slack,
                               scale=scale, long_words=long_words)

    def cfg_for(mode, pages):
        kw = dict(slots=slots, max_seq=max_seq, chunk=chunk, page_size=ps,
                  pages=pages)
        if mode == "preempt":
            kw["reserve"] = "initial"
        elif mode == "shed":
            kw.update(queue_cap=slots,
                      shed_policy="reject-latest-deadline")
        return EngineConfig(**kw)

    factors, policies = (2, 4), ("stall", "preempt", "shed")
    # solo references (resume-parity oracle) — also warms the per-request
    # scan path BEFORE the trace-log snapshot below
    solo_srv, solo = mk(cfg_for("stall", base_pages)), {}
    for e in events:
        if e["prompt"] not in solo:
            solo[e["prompt"]] = np.asarray(solo_srv.generate(
                [e["prompt"]], lam=0.5, max_new_tokens=max_new,
                engine=False)["results"][0]["tokens"])
    # warm pass: every (factor, policy) cell once, off the books. The
    # measured replay reuses the SAME servers (route/prefill/decode jits
    # are warm per router instance), so any trace-log growth below is a
    # genuine decode retrace on the resilience path.
    servers = {(f, mode): mk(cfg_for(mode, base_pages // f))
               for f in factors for mode in policies}
    for srv in servers.values():
        _run_deadline_traffic(srv, events, max_new)
    trace0 = len(G.TRACE_LOG)

    oversub, parity = {}, True
    for f in factors:
        cell = {"pages": base_pages // f}
        for mode in policies:
            srv = servers[(f, mode)]
            c0 = srv.engine.counters()       # warm-pass totals to subtract
            r = _run_deadline_traffic(srv, events, max_new)
            c = {k: v - c0[k] for k, v in srv.engine.counters().items()}
            if mode == "preempt":
                for rid, toks in r["completed"].items():
                    parity &= bool(np.array_equal(
                        toks, solo[r["meta"][rid]["prompt"]]))
                    if srv.engine.status(rid) == PREEMPTED_RESUMED:
                        assert c["preemptions"] > 0
            goodput = r["met_tokens"] / max(r["wall_s"], 1e-9)
            cell[mode] = {
                "met_tokens": r["met_tokens"],
                "goodput_tok_s": round(goodput, 1),
                "admission_p99_ms": r["admission_p99_ms"],
                "completed": len(r["completed"]),
                "expiries": c["expiries"], "sheds": c["sheds"],
                "preemptions": c["preemptions"],
                "resume_recompute_toks": c["resume_recompute_toks"],
            }
            C.emit(
                f"preempt_{mode}_{f}x_{n_req}req",
                1e6 / max(goodput, 1e-9),
                f"{mode} policy at {f}x oversubscription "
                f"({base_pages // f} pages): {r['met_tokens']} deadline-met "
                f"tokens ({len(r['completed'])}/{n_req} requests), "
                f"admission p99 {r['admission_p99_ms']} ms, "
                f"{c['expiries']} expiries / {c['sheds']} sheds / "
                f"{c['preemptions']} preemptions")
        oversub[f"{f}x"] = cell
    decode_retraces = len(G.TRACE_LOG) - trace0

    C.write_bench(_bench_file("preempt", smoke), meta={
        "model": cfg.name, "n_req": n_req, "max_new": max_new,
        "chunk": chunk, "max_seq": max_seq, "page_size": ps,
        "slots": slots, "base_pages": base_pages, "smoke": smoke,
        "oversub": oversub,
        "resume_parity": bool(parity),
        "decode_retraces": int(decode_retraces),
    })


# ---------------------------------------------------------------------------
# spec: speculative multi-token decode (router-paired drafting) vs plain
# chunked decode on the same traffic
# ---------------------------------------------------------------------------


def _layer_skip_pair(key, cfg, skip_to):
    """A (target params, draft cfg, draft params) triple where the draft
    is the target's own first ``skip_to`` layers (shared embedding,
    unembedding and final norm — a LayerSkip-style self-drafter). The
    target's upper layers have their residual write-backs (attention
    ``wo``, SwiGLU ``wd``) zeroed, so its hidden state after N layers is
    bit-identical to the draft's after ``skip_to`` — greedy argmax agrees
    exactly and the drafter's acceptance rate is 1.0 by construction.
    This isolates the speculative pipeline's speedup at a *known*
    acceptance instead of entangling it with model quality."""
    import dataclasses
    from repro.models import init_params

    params = init_params(key, cfg)
    blocks = params["blocks"]
    u = skip_to
    blocks = dict(blocks)
    for lname in blocks:
        lp = dict(blocks[lname])
        mixer = dict(lp["mixer"])
        mixer["wo"] = mixer["wo"].at[u:].set(0.0)
        lp["mixer"] = mixer
        ffn = dict(lp["ffn"])
        ffn["wd"] = ffn["wd"].at[u:].set(0.0)
        lp["ffn"] = ffn
        blocks[lname] = lp
    params["blocks"] = blocks
    dcfg = dataclasses.replace(cfg, name=f"{cfg.name}-skip{u}", n_layers=u)
    dparams = {"embed": params["embed"], "final_norm": params["final_norm"],
               "blocks": jax.tree.map(lambda a: a[:u], params["blocks"])}
    return params, dcfg, dparams


def _run_spec_traffic(srv, reqs, max_new, draft_model=None):
    """`_run_engine_traffic` plus result capture: returns
    (tokens/sec, {prompt: np tokens}) so spec cells can be checked
    bit-identical against the non-speculative baseline."""
    import time
    pending = sorted(reqs, key=lambda r: r["arrival"])
    kw = {} if draft_model is None else {"draft_model": draft_model}
    prompt_of, completion = {}, {}
    t0 = time.perf_counter()
    i = 0
    while i < len(pending) or srv.engine.busy:
        now = time.perf_counter() - t0
        while i < len(pending) and pending[i]["arrival"] <= now:
            rid = srv.submit(pending[i]["prompt"], lam=pending[i]["lam"],
                             max_new_tokens=max_new, **kw)
            prompt_of[rid] = pending[i]["prompt"]
            i += 1
        if srv.engine.busy:
            for rid, _ in srv.step():
                completion[rid] = time.perf_counter() - t0
        elif i < len(pending):
            time.sleep(min(pending[i]["arrival"] - now, 1e-3))
    makespan = max(completion.values())
    out = srv.drain()
    toks = {prompt_of[r]: np.asarray(v) for r, v in out.items()}
    return len(reqs) * max_new / makespan, toks


def bench_spec(smoke: bool) -> None:
    """Speculative multi-token decode vs the plain chunked engine on the
    same Poisson trace. The pool holds the target, a LayerSkip-style
    self-drafter (first layer of the target — acceptance 1.0 by
    construction, see `_layer_skip_pair`), and a cheaper-but-useless tiny
    drafter (independent weights — acceptance ~1/vocab). The ``router``
    cells let the gateway pick the drafter by router utility A − λC,
    which ranks the layer-skip drafter above the tiny one despite its
    higher cost; the ``tiny`` cell forces the bad drafter via
    ``draft_model=`` to show the acceptance-rate dependence. Acceptance
    (ci.yml enforces on the smoke JSON): best spec cell's tokens/sec
    >= the non-spec baseline, every cell's tokens bit-identical to the
    baseline's, and the measured replays add ZERO decode retraces."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import gateway as G
    from repro.serve.engine import EngineConfig
    from repro.serve.gateway import PoolModel, RoutedServer

    # Deeper/wider than the other benches: the speculative win comes from
    # verify batching T positions through weight-traversal-bound matmuls,
    # so compute must dominate per-dispatch overhead.
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=2, d_ff=1024)
    key = jax.random.PRNGKey(0)
    params, dcfg, dparams = _layer_skip_pair(key, cfg, skip_to=1)
    tiny_cfg = dataclasses.replace(cfg, name=f"{cfg.name}-tiny", n_layers=1)
    tiny_params = init_params(jax.random.PRNGKey(99), tiny_cfg)

    pool = [PoolModel(cfg.name, cfg, params, 1.0),
            PoolModel(dcfg.name, dcfg, dparams, 0.25),
            PoolModel(tiny_cfg.name, tiny_cfg, tiny_params, 0.05)]
    # One cluster; A ranks target >> layer-skip >> tiny, so requests
    # route to the target at every λ in the trace while `_pick_draft`
    # (utility over the strictly-cheaper candidates) pairs it with the
    # layer-skip drafter, not the cheapest one.
    router = routers.make(
        "kmeans", RouterConfig(d_emb=64, num_models=3),
        state={"centroids": jnp.zeros((1, 64)),
               "A": jnp.array([[0.9, 0.6, 0.05]]),
               "C": jnp.array([[0.10, 0.025, 0.005]]),
               "n": jnp.ones((1, 3))})

    if smoke:
        n_req, max_new, max_seq, rate, longtail = 8, 12, 64, 200.0, False
        cells = [("router", 4)]
    else:
        n_req, max_new, max_seq, rate, longtail = 24, 32, 128, 50.0, True
        cells = [("router", 2), ("router", 4), ("router", 6), ("tiny", 4)]
    reqs = _make_traffic(0, n_req, rate_per_s=rate, longtail=longtail)

    def mk(spec_k):
        return RoutedServer(pool, router, engine_cfg=EngineConfig(
            slots=4, max_seq=max_seq, chunk=4, spec_k=spec_k))

    servers = {"base": mk(0)}
    for drafter, k in cells:
        servers[(drafter, k)] = mk(k)
    # warm pass on the SAME servers: every (cfg, bucket) prefill, draft
    # and verify program compiles off the books, so trace-log growth in
    # the measured replays below is a genuine speculative-path retrace
    for name, srv in servers.items():
        dm = 2 if name != "base" and name[0] == "tiny" else None
        _run_spec_traffic(srv, reqs, max_new, draft_model=dm)
    trace0 = len(G.TRACE_LOG)

    repeats = 2
    base_tps, base_toks = max(
        (_run_spec_traffic(servers["base"], reqs, max_new)
         for _ in range(repeats)), key=lambda r: r[0])
    parity, results = True, {}
    for drafter, k in cells:
        srv = servers[(drafter, k)]
        dm = 2 if drafter == "tiny" else None
        c0 = srv.engine.counters()
        tps, toks = max(
            (_run_spec_traffic(srv, reqs, max_new, draft_model=dm)
             for _ in range(repeats)), key=lambda r: r[0])
        c = {n: v - c0[n] for n, v in srv.engine.counters().items()}
        cell_parity = all(np.array_equal(toks[p], base_toks[p])
                          for p in base_toks)
        parity &= cell_parity
        acc = c["spec_accepted"] / max(c["spec_drafted"], 1)
        results[f"{drafter}_k{k}"] = {
            "tokens_per_s": round(tps, 1),
            "speedup": round(tps / base_tps, 3),
            "acceptance": round(acc, 3),
            "spec_rounds": c["spec_rounds"],
            "token_parity": bool(cell_parity),
        }
        C.emit(f"spec_{drafter}_k{k}_{n_req}req", 1e6 / tps,
               f"spec_k={k}, drafter={drafter}: {tps:.0f} tok/s "
               f"({tps / base_tps:.2f}x vs non-spec), acceptance "
               f"{acc:.2f} over {c['spec_rounds']} rounds",
               speedup_vs_baseline=tps / base_tps)
    C.emit(f"spec_baseline_{n_req}req", 1e6 / base_tps,
           f"non-speculative chunked engine: {base_tps:.0f} tok/s")
    decode_retraces = len(G.TRACE_LOG) - trace0

    best_name = max(results, key=lambda n: results[n]["speedup"])
    drafter, k = best_name.rsplit("_k", 1)
    C.write_bench(_bench_file("spec", smoke), meta={
        "model": cfg.name, "draft": dcfg.name, "n_req": n_req,
        "max_new": max_new, "slots": 4, "smoke": smoke,
        "baseline_tokens_per_s": round(base_tps, 1),
        "cells": results,
        "best": {"spec_k": int(k), "drafter": drafter,
                 "speedup": results[best_name]["speedup"],
                 "acceptance": results[best_name]["acceptance"]},
        "token_parity": bool(parity),
        "decode_retraces": int(decode_retraces),
    })


# ---------------------------------------------------------------------------
# fedloop: online federation (serve → harvest → federate → hot-swap) vs a
# frozen client-local router under distribution drift
# ---------------------------------------------------------------------------


def bench_fedloop(smoke: bool) -> None:
    """Drive live traffic through the engine while the FedLoop harvests
    per-client evaluations, runs federated syncs over the harvested
    buffers, and hot-swaps router state under the traffic. Scores the
    online-federated router against per-client routers frozen after
    phase 0 (the no-federation deployment) as mean frontier AUC over the
    clients' drifted query mixtures. Deterministic in its seeds, so the CI
    floor (online >= frozen-local under drift) is exact accounting, not a
    wall-clock race."""
    import time

    from repro.fed.scenarios import ScenarioConfig, run_online_vs_frozen
    from repro.serve.engine import TRACE_LOG

    if smoke:
        cfg = ScenarioConfig(queries_per_phase=64, phases=2, n_queries=800,
                             test_queries=48)
    else:
        cfg = ScenarioConfig(n_clients=8, queries_per_phase=256, phases=3,
                             n_queries=2000, test_queries=96)

    n_trace0 = len(TRACE_LOG)
    t0 = time.perf_counter()
    m = run_online_vs_frozen(cfg)
    wall = time.perf_counter() - t0
    # every sync after warmup swaps under the cached route jit — the trace
    # log only grows while programs warm, never per swap (tests pin the
    # zero-retrace guarantee; here we record the count for the trajectory)
    traces = len(TRACE_LOG) - n_trace0

    C.emit(f"fedloop_scenario_{cfg.phases}ph_{cfg.queries_per_phase}q",
           wall * 1e6 / max(m["requests_served"], 1),
           f"us per served request incl. {m['syncs']} federated syncs + "
           f"hot-swaps; final-phase frontier AUC online "
           f"{m['auc_online_final']:.3f} vs frozen client-local "
           f"{m['auc_frozen_local_final']:.3f} under drift",
           speedup_vs_baseline=(m["auc_online_final"]
                                / max(m["auc_frozen_local_final"], 1e-9)))
    C.write_bench(_bench_file("fedloop", smoke), meta={
        "smoke": smoke, "phases": cfg.phases,
        "queries_per_phase": cfg.queries_per_phase,
        "n_clients": cfg.n_clients,
        "auc_online": m["auc_online"],
        "auc_frozen_local": m["auc_frozen_local"],
        "auc_online_final": round(m["auc_online_final"], 4),
        "auc_frozen_local_final": round(m["auc_frozen_local_final"], 4),
        "auc_gap_final": round(m["auc_gap_final"], 4),
        "syncs": m["syncs"],
        "router_version": m["router_version"],
        "requests_served": m["requests_served"],
        "harvested_samples": m["harvested_samples"],
        "harvest_bytes": m["harvest_bytes"],
        "jit_traces_during_run": traces,
        "wall_seconds": round(wall, 2),
    })


# ---------------------------------------------------------------------------
# routerbench: the router zoo under the RouterBench-style harness —
# federated vs client-local AIQ per family, clean and perturbed, offline
# and live through the FedLoop
# ---------------------------------------------------------------------------


def bench_routerbench(smoke: bool) -> None:
    """Every registered router family fit federated vs per-client-local on
    one many-model pool, scored as frontier AIQ (normalized frontier AUC)
    under the clean, paraphrase-drift and adversarial routing-flip
    scenarios — plus the same comparison live (a FedLoop-maintained router
    vs frozen client-local fits under embedding drift). Deterministic in
    its seeds, so the CI floor — federated AIQ ≥ client-local AIQ for the
    mf family on EVERY scenario of the smoke run — is exact accounting,
    not a wall-clock race (see ci.yml)."""
    import time

    from repro.evalbench.harness import (offline_routerbench,
                                         online_routerbench)
    from repro.evalbench.pools import make_pool_corpus
    from repro.fed.scenarios import ScenarioConfig

    if smoke:
        rcfg = RouterConfig(d_emb=16, num_models=6, hidden=(48, 48),
                            dropout=0.0, k_local=5, k_global=8, mf_rank=12)
        fcfg = FedConfig(num_clients=4, rounds=30, batch_size=32, lr=3e-3,
                         seed=0)
        corpus = make_pool_corpus(jax.random.PRNGKey(1), n_models=6,
                                  n_queries=800, d_emb=16, n_tasks=5)
        local_steps, online_families = 200, ("mf",)
    else:
        rcfg = RouterConfig(d_emb=24, num_models=8, hidden=(48, 48),
                            dropout=0.0, k_local=6, k_global=10, mf_rank=16)
        fcfg = FedConfig(num_clients=6, rounds=40, batch_size=32, lr=3e-3,
                         seed=0)
        corpus = make_pool_corpus(jax.random.PRNGKey(1), n_models=8,
                                  n_queries=1200, d_emb=24, n_tasks=6)
        local_steps, online_families = 300, ("mf", "elo")

    t0 = time.perf_counter()
    off = offline_routerbench(jax.random.PRNGKey(0), rcfg=rcfg, fcfg=fcfg,
                              corpus=corpus, local_steps=local_steps)
    off_wall = time.perf_counter() - t0
    per_family_us = off_wall * 1e6 / max(len(off["families"]), 1)
    for name in sorted(off["families"]):
        fam = off["families"][name]
        fed, loc = fam["federated"], fam["client_local"]
        C.emit(f"routerbench_offline_{name}", per_family_us,
               "AIQ fed/local — " + "; ".join(
                   f"{sc} {fed[sc]['aiq']:.3f}/{loc[sc]['aiq']:.3f}"
                   for sc in ("clean", "paraphrase", "adversarial")),
               speedup_vs_baseline=(fed["clean"]["aiq"]
                                    / max(loc["clean"]["aiq"], 1e-9)))

    scen = ScenarioConfig(n_clients=4, n_models=3, d_emb=24, n_queries=800,
                          queries_per_phase=96, phases=2, embed_sigma=0.9,
                          test_queries=48, seed=0)
    online = {}
    for fam in online_families:
        t1 = time.perf_counter()
        res = online_routerbench(family=fam, cfg=scen, local_steps=150,
                                 capacity=256)
        wall = time.perf_counter() - t1
        C.emit(f"routerbench_online_{fam}",
               wall * 1e6 / max(res["requests_served"], 1),
               f"us per served request; final-phase AIQ online "
               f"{res['auc_online_final']:.3f} vs frozen client-local "
               f"{res['auc_frozen_local_final']:.3f} under embedding drift",
               speedup_vs_baseline=(res["auc_online_final"]
                                    / max(res["auc_frozen_local_final"],
                                          1e-9)))
        online[fam] = {
            "embed_sigma": res["embed_sigma"],
            "auc_online_final": round(res["auc_online_final"], 4),
            "auc_frozen_local_final": round(res["auc_frozen_local_final"],
                                            4),
            "auc_gap_final": round(res["auc_gap_final"], 4),
            "syncs": res["syncs"],
            "requests_served": res["requests_served"],
        }

    C.write_bench(_bench_file("routerbench", smoke), meta={
        "smoke": smoke,
        "n_models": off["n_models"],
        "n_clients": off["n_clients"],
        "rounds": fcfg.rounds,
        "local_steps": local_steps,
        "pool": off["pool"],
        "reference": {k: round(v, 4) for k, v in off["reference"].items()
                      if k != "models"},
        "families": off["families"],
        "online": online,
        "offline_wall_seconds": round(off_wall, 2),
    })


# ---------------------------------------------------------------------------
# resilience: Byzantine-robust aggregation under corrupted clients, sync
# latency vs cohort size, and FedLoop checkpoint/resume recovery
# ---------------------------------------------------------------------------


def _flip_labels(train, mask) -> dict:
    """Label-flip fault at the DATA layer: the masked clients report
    inverted accuracies (acc -> 1 - acc on their real rows) — the
    harvest-poisoning counterpart of the update-space corruptions."""
    acc = np.asarray(train["acc"]).copy()
    w = np.asarray(train["w"])
    for i, bad in enumerate(mask):
        if bad:
            acc[i] = np.where(w[i] > 0, 1.0 - acc[i], acc[i])
    out = dict(train)
    out["acc"] = jnp.asarray(acc)
    return out


def bench_resilience(smoke: bool) -> None:
    """Three fault-tolerance measurements, all exact accounting (seeded
    faults, deterministic fits) so ci.yml can enforce floors without a
    statistical fudge factor:

      * **corruption table** — frontier AUC of {fedavg, trimmed_mean,
        median, norm_clip} under 25% Byzantine clients for each fault
        class (sign-flip / scaled-noise update corruption via
        ``CorruptUpdates``, label-flip data poisoning) vs the clean fit.
        The CI floor: trimmed-mean under sign-flip stays within
        ``RESILIENCE_AUC_FLOOR`` of its clean AUC while plain FedAvg
        measurably degrades.
      * **sync latency vs cohort** — wall-clock of the scan-fused
        federated fit at full participation vs sampled cohorts (the
        static-slab gather keeps every cohort size on one compile).
      * **recovery** — a live FedLoop is killed after phase 0 (save),
        restored into a fresh process-alike (restore), and run to the end:
        reports save/restore wall time and whether the resumed router is
        bit-identical to the uninterrupted twin's.
    """
    import time

    from repro.core import policy
    from repro.fed.aggregators import (FedAvgAggregator, MedianAggregator,
                                       NormClipAggregator,
                                       TrimmedMeanAggregator)
    from repro.fed.faults import FaultPlan

    n_clients = 8
    rounds = 20 if smoke else 40
    rcfg = RouterConfig(d_emb=16, num_models=6, hidden=(32, 32), dropout=0.0)
    # full participation: every corrupted client is in every round, so the
    # 25%-Byzantine claim (and the trim capacity matched to it) is exact
    fcfg = FedConfig(num_clients=n_clients, participation=1.0, rounds=rounds,
                     batch_size=32, lr=3e-3)
    corpus = make_eval_corpus(jax.random.PRNGKey(0),
                              n_queries=600 if smoke else 1500,
                              n_tasks=5, n_models=6, d_emb=16)
    split = federated_split(jax.random.PRNGKey(1), corpus, fcfg)
    train, test = split["train"], split["test_global"]
    plan = FaultPlan(seed=3, corrupt_frac=0.25)
    mask = plan.corrupted_clients(n_clients)  # (n_clients,) bool

    aggs = {"fedavg": FedAvgAggregator(),
            "trimmed_mean": TrimmedMeanAggregator(trim_frac=0.25),
            "median": MedianAggregator(),
            "norm_clip": NormClipAggregator(clip=0.5)}

    def fit_auc(data, aggregator) -> float:
        router, _ = routers.fit_federated(
            routers.make("mlp", rcfg), data, fcfg,
            key=jax.random.PRNGKey(5), rounds=rounds, aggregator=aggregator)
        *_, auc = policy.eval_router(router.predict, test["x"],
                                     test["acc_table"], test["cost_table"])
        return float(auc)

    table: dict = {}
    t0 = time.perf_counter()
    for name, agg in aggs.items():
        row = {"clean": round(fit_auc(train, agg), 4)}
        for mode in ("sign_flip", "scaled_noise"):
            wrapped = plan.corrupt_updates(n_clients, inner=agg, mode=mode)
            row[mode] = round(fit_auc(train, wrapped), 4)
        row["label_flip"] = round(fit_auc(_flip_labels(train, mask), agg), 4)
        table[name] = row
        C.emit(f"resilience_{name}",
               (time.perf_counter() - t0) * 1e6 / (4 * rounds),
               f"us per round (4 fault classes x {rounds}r); AUC clean "
               f"{row['clean']:.3f} sign_flip {row['sign_flip']:.3f} "
               f"scaled_noise {row['scaled_noise']:.3f} label_flip "
               f"{row['label_flip']:.3f} at 25% corrupted",
               speedup_vs_baseline=row["sign_flip"]
               / max(row["clean"], 1e-9))
        t0 = time.perf_counter()

    # --- sync latency vs cohort size (scan-fused fit, static cohort slab)
    cohort_us = {}
    for cohort in (None, n_clients // 2, n_clients // 4):
        us = C.timeit(
            lambda c=cohort: routers.fit_federated(
                routers.make("mlp", rcfg), train, fcfg,
                key=jax.random.PRNGKey(5), rounds=rounds, cohort=c),
            warmup=1, iters=1, repeats=2 if smoke else 3)
        label = "full" if cohort is None else str(cohort)
        cohort_us[label] = round(us, 1)
        C.emit(f"resilience_sync_cohort_{label}", us,
               f"{rounds}-round scan-fused fit, cohort="
               f"{label}/{n_clients} clients")

    # --- checkpoint/resume recovery: killed-and-restored vs uninterrupted
    from repro.fed.harvest import HarvestStore
    from repro.fed.loop import FedLoop, FedLoopConfig
    from repro.fed.scenarios import ScenarioConfig, TrafficScenario
    from repro.serve.engine import EngineConfig
    from repro.serve.gateway import RoutedServer

    scfg = ScenarioConfig(n_clients=4, n_models=2, d_emb=16,
                          n_queries=400, queries_per_phase=48, phases=2,
                          straggler_frac=0.0, test_queries=32, seed=0)
    loop_rcfg = RouterConfig(d_emb=scfg.d_emb, num_models=scfg.n_models,
                             hidden=(16, 16), dropout=0.0)
    loop_fcfg = FedConfig(num_clients=scfg.n_clients, participation=1.0,
                          batch_size=32, lr=3e-3)
    lcfg = FedLoopConfig(sync_every=10 ** 9, rounds_per_sync=3,
                         min_samples=1)

    def fresh_loop(scenario):
        pool = scenario.make_pool()
        router = routers.make("mlp", loop_rcfg).init(jax.random.PRNGKey(21))
        harvest = HarvestStore(scfg.d_emb, capacity=64,
                               clients=range(scfg.n_clients))
        srv = RoutedServer(pool, router, harvest=harvest,
                           engine_cfg=EngineConfig(slots=4, max_seq=32,
                                                   chunk=4, page_size=8))
        return srv, FedLoop(srv, loop_fcfg, key=jax.random.PRNGKey(23),
                            cfg=lcfg)

    def drive(scenario, srv, loop, phase):
        # outcomes keyed statelessly on (query, model) so an interrupted
        # run replays the exact same observations after restore
        for (c, q, lam) in scenario.events(phase):
            rid = srv.submit(scenario.prompt(q), lam=lam,
                             max_new_tokens=scfg.max_new, client_id=c,
                             x=scenario.x(q, phase))
            m = srv.routed_model(rid)
            p = float(scenario.corpus["acc_table"][q, m])
            u = np.random.default_rng(q * 1_000_003 + m).random()
            srv.report_outcome(rid, float(u < p),
                               float(scenario.corpus["cost_table"][q, m]))
            loop.step()
        loop.drain()
        loop.sync()

    srv_a, loop_a = fresh_loop(TrafficScenario(scfg))   # uninterrupted twin
    for phase in range(scfg.phases):
        drive(TrafficScenario(scfg), srv_a, loop_a, phase)

    srv_b, loop_b = fresh_loop(TrafficScenario(scfg))   # killed after phase 0
    drive(TrafficScenario(scfg), srv_b, loop_b, 0)
    ckpt_path = C.REPO_ROOT / ("BENCH_resilience.ckpt.tmp")
    t0 = time.perf_counter()
    loop_b.save(ckpt_path)
    save_s = time.perf_counter() - t0
    del srv_b, loop_b

    t0 = time.perf_counter()
    srv_c, loop_c = fresh_loop(TrafficScenario(scfg))
    loop_c.restore(ckpt_path)
    restore_s = time.perf_counter() - t0
    ckpt_bytes = ckpt_path.stat().st_size
    ckpt_path.unlink()
    for phase in range(1, scfg.phases):
        drive(TrafficScenario(scfg), srv_c, loop_c, phase)

    la, lc = jax.tree.leaves(srv_a.router.state), \
        jax.tree.leaves(srv_c.router.state)
    parity = (len(la) == len(lc)
              and all(np.array_equal(np.asarray(x), np.asarray(y))
                      for x, y in zip(la, lc))
              and srv_a.router_version == srv_c.router_version)
    C.emit("resilience_recovery", restore_s * 1e6,
           f"restore a killed FedLoop ({ckpt_bytes} bytes) and resume; "
           f"save {save_s * 1e3:.1f} ms; resumed router bit-identical to "
           f"uninterrupted twin: {parity}",
           speedup_vs_baseline=1.0 if parity else 0.0)

    C.write_bench(_bench_file("resilience", smoke), meta={
        "smoke": smoke, "rounds": rounds, "n_clients": n_clients,
        "corrupt_frac": 0.25,
        "corrupted_clients": [int(i) for i in np.flatnonzero(mask)],
        "corruption_auc": table,
        "sync_us_by_cohort": cohort_us,
        "checkpoint": {"save_ms": round(save_s * 1e3, 2),
                       "restore_ms": round(restore_s * 1e3, 2),
                       "bytes": int(ckpt_bytes),
                       "resume_bit_identical": bool(parity)},
    })


# ---------------------------------------------------------------------------
# mesh: cross-silo sharded fit + engine vs single device (subprocess
# workers — XLA_FLAGS must force the device count before jax initializes)
# ---------------------------------------------------------------------------


def _mesh_env(devices: int) -> dict:
    """Worker environment: strip any inherited device-count forcing, then
    force ``devices`` host CPU devices (1 = a plain single-device run)."""
    import os
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "xla_force_host_platform_device_count" not in f]
    if devices > 1:
        flags.append(f"--xla_force_host_platform_device_count={devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = str(C.REPO_ROOT / "src")
    return env


def _run_mesh_worker(task: dict) -> dict:
    """Run one measurement in a fresh interpreter (its own device count)
    and parse the MESHRESULT line it prints."""
    import subprocess
    import sys
    cmd = [sys.executable, "-m", "benchmarks.perf_suite",
           "--mesh-worker", json.dumps(task)]
    proc = subprocess.run(cmd, env=_mesh_env(task["devices"]),
                          capture_output=True, text=True, timeout=3000,
                          cwd=C.REPO_ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh worker {task} failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith("MESHRESULT "):
            return json.loads(line[len("MESHRESULT "):])
    raise RuntimeError(f"mesh worker {task} printed no MESHRESULT:\n"
                       f"{proc.stdout}\n{proc.stderr}")


def _mesh_fit_slab(n_clients: int, queries: int, d_emb: int,
                   n_models: int) -> dict:
    """Stacked federated slab with PowerLaw-skewed per-client sample masks
    (the population regime the sharded fit targets: a Zipf head carries
    the data, the long tail is mostly padding)."""
    from repro.fed.scenarios import PowerLawScenario
    rng = np.random.default_rng(7)
    scen = PowerLawScenario(n_clients=n_clients, zipf_a=1.1, churn=0.15)
    counts = np.minimum(
        queries,
        np.ceil(scen.popularity(0) * n_clients * queries * 0.5)
    ).astype(np.int32)
    return {
        "x": rng.normal(size=(n_clients, queries, d_emb)).astype(np.float32),
        "m": rng.integers(0, n_models,
                          size=(n_clients, queries)).astype(np.int32),
        "acc": (rng.random((n_clients, queries)) < 0.5).astype(np.float32),
        "cost": rng.random((n_clients, queries)).astype(np.float32),
        "w": (np.arange(queries)[None] < counts[:, None]).astype(np.float32),
    }


def _mesh_worker_fit(task: dict) -> dict:
    """Measure one federated fit configuration in-process (the parent
    forced our device count via XLA_FLAGS). Reports clients/s plus the
    FIT_TRACE_LOG growth across the timed repeats (zero-retrace pin)."""
    import time

    import repro.sharding as shd
    from repro.core import federated as F

    N, D = int(task["n_clients"]), int(task["queries"])
    rounds, cohort = int(task["rounds"]), task.get("cohort")
    d_emb, n_models = 16, 8
    data = _mesh_fit_slab(N, D, d_emb, n_models)
    rcfg = RouterConfig(d_emb=d_emb, num_models=n_models, hidden=(32, 32))
    fcfg = FedConfig(num_clients=N, batch_size=16, lr=1e-2)
    mesh = shd.client_mesh(task["devices"]) if task["devices"] > 1 else None
    if mesh is not None:
        data = shd.shard_clients(data, mesh)
    key = jax.random.PRNGKey(0)

    def fit():
        params, _ = F.fedavg(key, data, rcfg, fcfg, rounds=rounds,
                             cohort=cohort, mesh=mesh)
        jax.block_until_ready(params)

    t0 = time.perf_counter()
    fit()                                   # compile + warm caches
    compile_s = time.perf_counter() - t0
    n_trace = len(F.FIT_TRACE_LOG)
    times = []
    for i in range(int(task.get("repeats", 3))):
        if task.get("profile") and i == 0:
            with jax.profiler.trace(task["profile"]):
                t0 = time.perf_counter()
                fit()
                times.append(time.perf_counter() - t0)
        else:
            t0 = time.perf_counter()
            fit()
            times.append(time.perf_counter() - t0)
    per_round_clients = cohort if cohort else N
    return {"fit_s": min(times), "compile_s": compile_s,
            "clients_per_s": per_round_clients * rounds / min(times),
            "retraces": len(F.FIT_TRACE_LOG) - n_trace}


def _mesh_worker_engine(task: dict) -> dict:
    """Measure engine decode tokens/s (the parent forced our device
    count): a slot-saturating batch on a uniform pool, solo or with the
    KV pool sharded slot-parallel over a "data" mesh. Token parity versus
    the solo engine is pinned in tests/test_mesh.py; here we time."""
    import time

    import repro.sharding as shd
    from repro.models import init_params
    from repro.serve.engine import (TRACE_LOG, EngineConfig, ModelConfig,
                                    ServeEngine)

    slots, max_new = int(task["slots"]), int(task["max_new"])
    cfg = ModelConfig(name="mesh-bench-dense", arch_type="dense",
                      n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=257, head_dim=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    pm = type("PM", (), {"cfg": cfg, "params": params})()
    mesh = shd.data_mesh(task["devices"]) if task["devices"] > 1 else None
    ecfg = EngineConfig(slots=slots, max_seq=128, chunk=8, page_size=None)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab, size=rng.integers(4, 12))
               for _ in range(2 * slots)]

    def run(engine):
        rids = [engine.submit(0, p, max_new) for p in prompts]
        engine.drain(rids)

    engine = ServeEngine([pm], ecfg, mesh=mesh)
    run(engine)                              # compile + warm caches
    n_trace = len(TRACE_LOG)
    times = []
    for _ in range(int(task.get("repeats", 3))):
        t0 = time.perf_counter()
        run(engine)
        times.append(time.perf_counter() - t0)
    toks = len(prompts) * max_new
    return {"engine_s": min(times), "tokens_per_s": toks / min(times),
            "retraces": len(TRACE_LOG) - n_trace}


def _mesh_worker_main(payload: str) -> None:
    task = json.loads(payload)
    out = (_mesh_worker_fit(task) if task["kind"] == "fit"
           else _mesh_worker_engine(task))
    print("MESHRESULT " + json.dumps(out))


def bench_mesh(smoke: bool, profile: str | None = None) -> None:
    """Cross-silo mesh execution vs single device, each measurement in its
    own interpreter (forced host device count): the sharded federated fit
    (PowerLaw client population, full and cohort-sampled rounds) and the
    slot-parallel engine decode. On hosts with fewer cores than devices
    the mesh path pays pure dispatch + collective overhead with no
    parallel hardware to win it back — ``meta.host_cpus`` records that so
    CI gates the throughput floor on real parallelism being present."""
    import os

    devices = 8
    if smoke:
        fit_cases = [("fit_256c", dict(kind="fit", n_clients=256,
                                       queries=8, rounds=2, repeats=2))]
    else:
        fit_cases = [
            ("fit_1024c", dict(kind="fit", n_clients=1024, queries=16,
                               rounds=3, repeats=3)),
            ("fit_10240c_cohort512", dict(kind="fit", n_clients=10240,
                                          queries=8, rounds=3, cohort=512,
                                          repeats=3)),
        ]
    eng_case = dict(kind="engine", slots=8, max_new=8 if smoke else 32,
                    repeats=2 if smoke else 3)

    results = {}
    for name, case in fit_cases + [("engine", eng_case)]:
        for dev in (1, devices):
            task = {**case, "devices": dev}
            if profile and dev == devices and case["kind"] == "fit":
                task["profile"] = os.path.join(profile, f"mesh_{name}")
            results[(name, dev)] = _run_mesh_worker(task)

    for name, case in fit_cases:
        solo, mesh = results[(name, 1)], results[(name, devices)]
        assert mesh["retraces"] == 0, f"{name}: mesh fit retraced"
        C.emit(f"mesh_{name}_1dev", solo["fit_s"] * 1e6,
               f"{solo['clients_per_s']:.0f} clients/s, single device")
        C.emit(f"mesh_{name}_{devices}dev", mesh["fit_s"] * 1e6,
               f"{mesh['clients_per_s']:.0f} clients/s, shard_map over "
               f"{devices} forced host devices",
               speedup_vs_baseline=solo["fit_s"] / mesh["fit_s"])
    solo, mesh = results[("engine", 1)], results[("engine", devices)]
    assert mesh["retraces"] == 0, "engine: mesh decode retraced"
    C.emit("mesh_engine_1dev", solo["engine_s"] * 1e6,
           f"{solo['tokens_per_s']:.0f} tokens/s, single device")
    C.emit(f"mesh_engine_{devices}dev", mesh["engine_s"] * 1e6,
           f"{mesh['tokens_per_s']:.0f} tokens/s, KV pool slot-parallel "
           f"over {devices} forced host devices",
           speedup_vs_baseline=solo["engine_s"] / mesh["engine_s"])
    C.write_bench(_bench_file("mesh", smoke), meta={
        "smoke": smoke, "devices": devices,
        "host_cpus": os.cpu_count(),
        "fit_speedup": {n: round(results[(n, 1)]["fit_s"]
                                 / results[(n, devices)]["fit_s"], 3)
                        for n, _ in fit_cases},
        "engine_speedup": round(solo["engine_s"] / mesh["engine_s"], 3),
    })


SECTIONS = ("train", "route", "serve", "engine", "paged", "preempt",
            "spec", "fedloop", "routerbench", "resilience", "mesh")


def main() -> None:
    import contextlib
    import os

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workloads — validate the harness, not perf")
    ap.add_argument("--sections", default=",".join(SECTIONS),
                    help="comma-separated subset of sections to run "
                         f"(default: all of {','.join(SECTIONS)})")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace per section into "
                         "DIR (TensorBoard format); off by default")
    ap.add_argument("--mesh-worker", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.mesh_worker is not None:
        _mesh_worker_main(args.mesh_worker)
        return

    sections = [s.strip() for s in args.sections.split(",") if s.strip()]
    unknown = set(sections) - set(SECTIONS)
    if unknown:
        raise SystemExit(f"unknown sections: {sorted(unknown)} "
                         f"(pick from {','.join(SECTIONS)})")
    if args.profile:
        os.makedirs(args.profile, exist_ok=True)

    for s in sections:
        if s == "mesh":
            # subprocess workers profile themselves (own device counts)
            bench_mesh(args.smoke, profile=args.profile)
            continue
        ctx = (jax.profiler.trace(os.path.join(args.profile, s))
               if args.profile else contextlib.nullcontext())
        with ctx:
            globals()[f"bench_{s}"](args.smoke)

    for f in (_bench_file(s, args.smoke) for s in sections):
        blob = json.loads((C.REPO_ROOT / f).read_text())
        assert blob["records"], f"{f}: no records"
        assert all(np.isfinite(r["us_per_call"]) for r in blob["records"])
        print(f"{f}: {len(blob['records'])} records OK")


if __name__ == "__main__":
    main()
