"""Theorem 5.1 empirical analogue: FedAvg convergence speedup with N.

The bound predicts a 1/√(NτT) rate — more clients (same total data per
client) should reach a given loss in fewer rounds. We train with
N ∈ {2, 10} clients and report loss after a fixed round budget, plus the
τ=1/full-participation exact-equivalence check (also a unit test)."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks import common as C
from repro import routers
from repro.data.partition import federated_split
from repro.data.synthetic import make_eval_corpus


def run():
    t = C.Timer()
    out = {}
    for n_clients in (2, 10):
        # EQUAL per-client data (500 queries each): the Thm 5.1 speedup is
        # in N at fixed per-client τ — more clients aggregate more data
        # per round, so the loss after T rounds should be lower.
        corpus = make_eval_corpus(jax.random.PRNGKey(5),
                                  n_queries=667 * n_clients,
                                  n_tasks=C.N_TASKS, n_models=C.N_MODELS,
                                  d_emb=C.D_EMB)
        fcfg = dataclasses.replace(C.FCFG, num_clients=n_clients,
                                   participation=1.0, seed=6,
                                   dirichlet_alpha=100.0)  # near-iid
        split = federated_split(jax.random.PRNGKey(6), corpus, fcfg)
        _, hist = routers.fit_federated(routers.make("mlp", C.RCFG),
                                        split["train"], fcfg,
                                        key=jax.random.PRNGKey(7),
                                        rounds=10)
        out[n_clients] = hist["loss"]
        C.emit(f"thm51_N{n_clients}_loss_round10", t.us(),
               f"{hist['loss'][-1]:.4f}")
    C.emit("thm51_more_clients_lower_loss", t.us(),
           str(bool(out[10][-1] <= out[2][-1] + 0.02)))
    return out


if __name__ == "__main__":
    run()
