"""Fig. 4 / §6.3: onboarding new models. Three models are withheld during
initial training, then introduced via a 10%-of-prompts calibration subset:
MLP gets fresh heads trained with a frozen trunk; K-means gets new
per-cluster statistics. Frontier AUC before vs after expansion."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro import routers
from repro.core import policy
from repro.data.partition import federated_split
from repro.data.synthetic import observe


def _auc_on(router, tg, models=None):
    acc, cost = tg["acc_table"], tg["cost_table"]
    if models is not None:
        acc, cost = acc[:, models], cost[:, models]
    return policy.eval_router(router.predict, tg["x"], acc, cost)[-1]


def run():
    corpus, _, _ = C.corpus_and_split()
    M = C.N_MODELS
    withheld = [M - 3, M - 2, M - 1]
    base_models = list(range(M - 3))
    fcfg = dataclasses.replace(C.FCFG, seed=11)
    split = federated_split(jax.random.PRNGKey(9), corpus, fcfg,
                            model_subset=base_models)
    tg = split["test_global"]
    rcfg8 = dataclasses.replace(C.RCFG, num_models=M - 3)
    t = C.Timer()

    # ---- initial training on the reduced pool
    fed8, _ = routers.fit_federated(routers.make("mlp", rcfg8),
                                    split["train"], fcfg,
                                    key=jax.random.PRNGKey(2))
    auc_before = _auc_on(fed8, tg, base_models)

    km8, _ = routers.fit_federated(routers.make("kmeans", rcfg8),
                                   split["train"], fcfg,
                                   key=jax.random.PRNGKey(3))

    # ---- calibration set: 10% of each client's prompts × 3 new models
    rng = np.random.default_rng(0)
    calib_q = []
    for tr in split["train_idx"]:
        k = max(1, len(tr) // 10)
        calib_q.extend(rng.choice(tr, size=k, replace=False).tolist())
    calib_q = np.asarray(calib_q)
    xs, ms, accs, costs = [], [], [], []
    for j, m_new in enumerate(withheld):
        a, cst = observe(jax.random.PRNGKey(50 + j), corpus,
                         jnp.asarray(calib_q),
                         jnp.full(len(calib_q), m_new))
        xs.append(np.asarray(corpus["x"])[calib_q])
        ms.append(np.full(len(calib_q), m_new))
        accs.append(np.asarray(a))
        costs.append(np.asarray(cst))
    calib = {"x": jnp.asarray(np.concatenate(xs)),
             "m": jnp.asarray(np.concatenate(ms), jnp.int32),
             "acc": jnp.asarray(np.concatenate(accs)),
             "cost": jnp.asarray(np.concatenate(costs)),
             "w": jnp.ones(3 * len(calib_q))}

    # ---- MLP: append + train only new heads (frozen trunk)
    fed11 = fed8.onboard_model(calib, key=jax.random.PRNGKey(4), fcfg=fcfg,
                               n_new=3, steps=400)
    auc_after = _auc_on(fed11, tg)

    # ---- K-means: training-free stat estimation per new model
    km11 = km8
    for j, m_new in enumerate(withheld):
        sel = slice(j * len(calib_q), (j + 1) * len(calib_q))
        km11 = km11.onboard_model({k: calib[k][sel]
                                   for k in ("x", "acc", "cost", "w")})
    auc_km_before = _auc_on(km8, tg, base_models)
    auc_km_after = _auc_on(km11, tg)

    us = t.us()
    C.emit("fig4_mlp_auc_before_expansion", us, f"{auc_before:.4f}")
    C.emit("fig4_mlp_auc_after_expansion", us, f"{auc_after:.4f}")
    C.emit("fig4_kmeans_auc_before_expansion", us, f"{auc_km_before:.4f}")
    C.emit("fig4_kmeans_auc_after_expansion", us, f"{auc_km_after:.4f}")
    return {"mlp": (auc_before, auc_after),
            "kmeans": (auc_km_before, auc_km_after)}


if __name__ == "__main__":
    run()
