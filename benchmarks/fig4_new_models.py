"""Fig. 4 / §6.3: onboarding new models. Three models are withheld during
initial training, then introduced via a 10%-of-prompts calibration subset:
MLP gets fresh heads trained with a frozen trunk; K-means gets new
per-cluster statistics. Frontier AUC before vs after expansion."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import expansion as E
from repro.core import federated as F
from repro.core import kmeans_router as KR
from repro.core import policy
from repro.data.partition import federated_split
from repro.data.synthetic import observe


def _restricted_pred(pred, keep):
    def f(x):
        A, Cc = pred(x)
        return A[:, keep], Cc[:, keep]
    return f


def run():
    corpus, _, _ = C.corpus_and_split()
    M = C.N_MODELS
    withheld = [M - 3, M - 2, M - 1]
    base_models = list(range(M - 3))
    fcfg = dataclasses.replace(C.FCFG, seed=11)
    split = federated_split(jax.random.PRNGKey(9), corpus, fcfg,
                            model_subset=base_models)
    tg = split["test_global"]
    rcfg8 = dataclasses.replace(C.RCFG, num_models=M - 3)
    t = C.Timer()

    # ---- initial training on the reduced pool
    fed8, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], rcfg8, fcfg)
    auc_before = policy.eval_router(
        lambda x: F.R.apply_mlp_router(fed8, x), tg["x"],
        tg["acc_table"][:, base_models], tg["cost_table"][:, base_models])[-1]

    km8 = KR.fed_kmeans_router(jax.random.PRNGKey(3), split["train"], rcfg8,
                               num_models=M - 3)

    # ---- calibration set: 10% of each client's prompts × 3 new models
    rng = np.random.default_rng(0)
    calib_q = []
    for tr in split["train_idx"]:
        k = max(1, len(tr) // 10)
        calib_q.extend(rng.choice(tr, size=k, replace=False).tolist())
    calib_q = np.asarray(calib_q)
    xs, ms, accs, costs = [], [], [], []
    for j, m_new in enumerate(withheld):
        a, cst = observe(jax.random.PRNGKey(50 + j), corpus,
                         jnp.asarray(calib_q),
                         jnp.full(len(calib_q), m_new))
        xs.append(np.asarray(corpus["x"])[calib_q])
        ms.append(np.full(len(calib_q), m_new))
        accs.append(np.asarray(a))
        costs.append(np.asarray(cst))
    calib = {"x": jnp.asarray(np.concatenate(xs)),
             "m": jnp.asarray(np.concatenate(ms), jnp.int32),
             "acc": jnp.asarray(np.concatenate(accs)),
             "cost": jnp.asarray(np.concatenate(costs)),
             "w": jnp.ones(3 * len(calib_q))}

    # ---- MLP: append + train only new heads (frozen trunk)
    fed11, _ = E.onboard_models_mlp(jax.random.PRNGKey(4), fed8, calib,
                                    rcfg8, fcfg, 3, steps=400)
    auc_after = policy.eval_router(
        lambda x: F.R.apply_mlp_router(fed11, x), tg["x"], tg["acc_table"],
        tg["cost_table"])[-1]

    # ---- K-means: training-free stat estimation per new model
    km11 = km8
    for j, m_new in enumerate(withheld):
        sel = slice(j * len(calib_q), (j + 1) * len(calib_q))
        km11 = KR.add_model_stats(km11, {k: calib[k][sel]
                                         for k in ("x", "acc", "cost", "w")})
    auc_km_before = policy.eval_router(
        lambda x: KR.predict(km8, x), tg["x"],
        tg["acc_table"][:, base_models], tg["cost_table"][:, base_models])[-1]
    auc_km_after = policy.eval_router(
        lambda x: KR.predict(km11, x), tg["x"], tg["acc_table"],
        tg["cost_table"])[-1]

    us = t.us()
    C.emit("fig4_mlp_auc_before_expansion", us, f"{auc_before:.4f}")
    C.emit("fig4_mlp_auc_after_expansion", us, f"{auc_after:.4f}")
    C.emit("fig4_kmeans_auc_before_expansion", us, f"{auc_km_before:.4f}")
    C.emit("fig4_kmeans_auc_after_expansion", us, f"{auc_km_after:.4f}")
    return {"mlp": (auc_before, auc_after),
            "kmeans": (auc_km_before, auc_km_after)}


if __name__ == "__main__":
    run()
