"""Fig. 2: federated vs client-local routers on the global test distribution
(MLP-Router and K-Means-Router accuracy–cost AUC)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.data.partition import client_slice


def run():
    _, split, fcfg = C.corpus_and_split()
    tg = split["test_global"]
    t = C.Timer()

    fed_mlp, _ = C.train_fed_mlp(split, fcfg)
    auc_fed_mlp = C.auc_of(fed_mlp, tg)
    locals_mlp = C.train_local_mlps(split, fcfg)
    auc_loc_mlp = float(np.mean([C.auc_of(r, tg) for r in locals_mlp]))

    r_fed = C.train_fed_kmeans(split, fcfg)
    auc_fed_km = C.auc_of(r_fed, tg)
    auc_loc_km = float(np.mean([
        C.auc_of(C.train_local_kmeans(client_slice(split["train"], i),
                                      seed=30 + i, fcfg=fcfg), tg)
        for i in range(fcfg.num_clients)]))

    us = t.us()
    C.emit("fig2_mlp_fed_auc", us, f"{auc_fed_mlp:.4f}")
    C.emit("fig2_mlp_local_mean_auc", us, f"{auc_loc_mlp:.4f}")
    C.emit("fig2_kmeans_fed_auc", us, f"{auc_fed_km:.4f}")
    C.emit("fig2_kmeans_local_mean_auc", us, f"{auc_loc_km:.4f}")
    C.emit("fig2_mlp_gain", us, f"{auc_fed_mlp - auc_loc_mlp:+.4f}")
    C.emit("fig2_kmeans_gain", us, f"{auc_fed_km - auc_loc_km:+.4f}")
    assert auc_fed_mlp > auc_loc_mlp and auc_fed_km > auc_loc_km
    # paper: gains larger for K-Means-Router
    return {"mlp": (auc_fed_mlp, auc_loc_mlp),
            "kmeans": (auc_fed_km, auc_loc_km)}


if __name__ == "__main__":
    run()
