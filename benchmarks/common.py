"""Shared setup for the paper-figure benchmarks.

All router construction/fitting goes through the unified ``repro.routers``
API — benchmarks never touch the family-specific modules directly.
"""
from __future__ import annotations

import functools
import json
import pathlib
import time

import jax
import numpy as np

from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.core import policy
from repro.data.partition import client_slice, federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus
from repro.routers import Router

D_EMB = 48
N_MODELS = 11
N_TASKS = 8
N_QUERIES = 6000

RCFG = RouterConfig(d_emb=D_EMB, num_models=N_MODELS)
FCFG = FedConfig()


@functools.lru_cache(maxsize=4)
def corpus_and_split(alpha: float = 0.6, seed: int = 0,
                     n_queries: int = N_QUERIES):
    corpus = make_eval_corpus(jax.random.PRNGKey(seed), n_queries=n_queries,
                              n_tasks=N_TASKS, n_models=N_MODELS,
                              d_emb=D_EMB)
    fcfg = FedConfig(dirichlet_alpha=alpha, seed=seed)
    split = federated_split(jax.random.PRNGKey(seed + 1), corpus, fcfg)
    return corpus, split, fcfg


def auc_of(router, test) -> float:
    """Frontier AUC of a fitted Router (or a raw predict_fn, e.g. the
    oracle's true tables) on one test split."""
    pred = router.predict if isinstance(router, Router) else router
    *_, auc = policy.eval_router(pred, test["x"], test["acc_table"],
                                 test["cost_table"])
    return auc


def train_fed_mlp(split, fcfg, rounds=30, seed=2, rcfg=RCFG):
    return routers.fit_federated(routers.make("mlp", rcfg), split["train"],
                                 fcfg, key=jax.random.PRNGKey(seed),
                                 rounds=rounds)


def train_fed_kmeans(split, fcfg, seed=3, rcfg=RCFG, num_models=None):
    router, _ = routers.fit_federated(
        routers.make("kmeans", rcfg, num_models=num_models), split["train"],
        fcfg, key=jax.random.PRNGKey(seed))
    return router


def train_local_mlps(split, fcfg, steps=400, seed=100, rcfg=RCFG):
    out = []
    for i in range(split["train"]["x"].shape[0]):
        r, _ = routers.fit_local(routers.make("mlp", rcfg),
                                 client_slice(split["train"], i), fcfg,
                                 key=jax.random.PRNGKey(seed + i),
                                 steps=steps)
        out.append(r)
    return out


def train_local_kmeans(data_i, seed, fcfg=FCFG, rcfg=RCFG, num_models=None,
                       k=None):
    router, _ = routers.fit_local(
        routers.make("kmeans", rcfg, num_models=num_models), data_i, fcfg,
        key=jax.random.PRNGKey(seed), k=k)
    return router


def train_centralized(split, fcfg, steps=None, seed=4, rcfg=RCFG):
    pooled = flatten_clients(split["train"])
    steps = steps or fcfg.rounds * int(np.ceil(
        split["train"]["x"].shape[1] / fcfg.batch_size))
    r, _ = routers.fit_local(routers.make("mlp", rcfg), pooled, fcfg,
                             key=jax.random.PRNGKey(seed), steps=steps)
    return r


REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

#: records collected by ``emit`` since the last ``write_bench`` — one dict
#: per measurement, serialized as the BENCH_*.json trajectory files.
_RECORDS: list[dict] = []


class Timer:
    """Wall-clock region timer (``perf_counter``-based, monotonic)."""

    def __init__(self):
        self.t0 = time.perf_counter()

    def us(self, calls: int = 1) -> float:
        return (time.perf_counter() - self.t0) * 1e6 / max(calls, 1)


def timeit(fn, *args, warmup: int = 2, iters: int = 20, repeats: int = 3,
           **kw) -> float:
    """µs per call of ``fn(*args)``: ``warmup`` untimed calls (compile +
    cache fill), then ``repeats`` timed loops of ``iters`` calls under
    ``block_until_ready`` (async dispatch can't fake a result). Reports
    the best repeat — the scheduler-noise-resistant statistic."""
    iters = max(iters, 1)
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e6


def emit(name: str, us_per_call: float, derived: str = "", *,
         speedup_vs_baseline: float | None = None):
    """Record one measurement (JSON trajectory record) and echo it."""
    rec = {"op": name, "us_per_call": round(us_per_call, 2)}
    if derived:
        rec["derived"] = derived
    if speedup_vs_baseline is not None:
        rec["speedup_vs_baseline"] = round(speedup_vs_baseline, 3)
    _RECORDS.append(rec)
    extra = (f",speedup={speedup_vs_baseline:.2f}x"
             if speedup_vs_baseline is not None else "")
    print(f"{name},{us_per_call:.1f},{derived}{extra}")


def write_bench(filename: str, *, meta: dict | None = None) -> pathlib.Path:
    """Flush the records emitted so far to ``REPO_ROOT/filename`` (JSON)
    and reset the collector. Returns the written path."""
    path = REPO_ROOT / filename
    payload = {"meta": {"backend": jax.default_backend(),
                        **(meta or {})},
               "records": _RECORDS[:]}
    path.write_text(json.dumps(payload, indent=2) + "\n")
    _RECORDS.clear()
    return path
