"""Shared setup for the paper-figure benchmarks.

All router construction/fitting goes through the unified ``repro.routers``
API — benchmarks never touch the family-specific modules directly.
"""
from __future__ import annotations

import functools
import time

import jax
import numpy as np

from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.core import policy
from repro.data.partition import client_slice, federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus
from repro.routers import Router

D_EMB = 48
N_MODELS = 11
N_TASKS = 8
N_QUERIES = 6000

RCFG = RouterConfig(d_emb=D_EMB, num_models=N_MODELS)
FCFG = FedConfig()


@functools.lru_cache(maxsize=4)
def corpus_and_split(alpha: float = 0.6, seed: int = 0,
                     n_queries: int = N_QUERIES):
    corpus = make_eval_corpus(jax.random.PRNGKey(seed), n_queries=n_queries,
                              n_tasks=N_TASKS, n_models=N_MODELS,
                              d_emb=D_EMB)
    fcfg = FedConfig(dirichlet_alpha=alpha, seed=seed)
    split = federated_split(jax.random.PRNGKey(seed + 1), corpus, fcfg)
    return corpus, split, fcfg


def auc_of(router, test) -> float:
    """Frontier AUC of a fitted Router (or a raw predict_fn, e.g. the
    oracle's true tables) on one test split."""
    pred = router.predict if isinstance(router, Router) else router
    *_, auc = policy.eval_router(pred, test["x"], test["acc_table"],
                                 test["cost_table"])
    return auc


def train_fed_mlp(split, fcfg, rounds=30, seed=2, rcfg=RCFG):
    return routers.fit_federated(routers.make("mlp", rcfg), split["train"],
                                 fcfg, key=jax.random.PRNGKey(seed),
                                 rounds=rounds)


def train_fed_kmeans(split, fcfg, seed=3, rcfg=RCFG, num_models=None):
    router, _ = routers.fit_federated(
        routers.make("kmeans", rcfg, num_models=num_models), split["train"],
        fcfg, key=jax.random.PRNGKey(seed))
    return router


def train_local_mlps(split, fcfg, steps=400, seed=100, rcfg=RCFG):
    out = []
    for i in range(split["train"]["x"].shape[0]):
        r, _ = routers.fit_local(routers.make("mlp", rcfg),
                                 client_slice(split["train"], i), fcfg,
                                 key=jax.random.PRNGKey(seed + i),
                                 steps=steps)
        out.append(r)
    return out


def train_local_kmeans(data_i, seed, fcfg=FCFG, rcfg=RCFG, num_models=None,
                       k=None):
    router, _ = routers.fit_local(
        routers.make("kmeans", rcfg, num_models=num_models), data_i, fcfg,
        key=jax.random.PRNGKey(seed), k=k)
    return router


def train_centralized(split, fcfg, steps=None, seed=4, rcfg=RCFG):
    pooled = flatten_clients(split["train"])
    steps = steps or fcfg.rounds * int(np.ceil(
        split["train"]["x"].shape[1] / fcfg.batch_size))
    r, _ = routers.fit_local(routers.make("mlp", rcfg), pooled, fcfg,
                             key=jax.random.PRNGKey(seed), steps=steps)
    return r


class Timer:
    def __init__(self):
        self.t0 = time.time()

    def us(self, calls: int = 1) -> float:
        return (time.time() - self.t0) * 1e6 / max(calls, 1)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
