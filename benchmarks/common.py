"""Shared setup for the paper-figure benchmarks."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig, RouterConfig
from repro.core import federated as F
from repro.core import kmeans_router as KR
from repro.core import mlp_router as R
from repro.core import policy
from repro.data.partition import client_slice, federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus

D_EMB = 48
N_MODELS = 11
N_TASKS = 8
N_QUERIES = 6000

RCFG = RouterConfig(d_emb=D_EMB, num_models=N_MODELS)
FCFG = FedConfig()


@functools.lru_cache(maxsize=4)
def corpus_and_split(alpha: float = 0.6, seed: int = 0,
                     n_queries: int = N_QUERIES):
    corpus = make_eval_corpus(jax.random.PRNGKey(seed), n_queries=n_queries,
                              n_tasks=N_TASKS, n_models=N_MODELS,
                              d_emb=D_EMB)
    fcfg = FedConfig(dirichlet_alpha=alpha, seed=seed)
    split = federated_split(jax.random.PRNGKey(seed + 1), corpus, fcfg)
    return corpus, split, fcfg


def auc_of(pred_fn, test) -> float:
    *_, auc = policy.eval_router(pred_fn, test["x"], test["acc_table"],
                                 test["cost_table"])
    return auc


def mlp_pred(params):
    return lambda x: R.apply_mlp_router(params, x)


def kmeans_pred(router):
    return lambda x: KR.predict(router, x)


def train_fed_mlp(split, fcfg, rounds=30, seed=2):
    params, hist = F.fedavg(jax.random.PRNGKey(seed), split["train"], RCFG,
                            fcfg, rounds=rounds)
    return params, hist


def train_local_mlps(split, fcfg, steps=400, seed=100):
    out = []
    for i in range(split["train"]["x"].shape[0]):
        p, _ = F.sgd_train(jax.random.PRNGKey(seed + i),
                           client_slice(split["train"], i), RCFG, fcfg,
                           steps=steps)
        out.append(p)
    return out


def train_centralized(split, fcfg, steps=None, seed=4):
    pooled = flatten_clients(split["train"])
    steps = steps or fcfg.rounds * int(np.ceil(
        split["train"]["x"].shape[1] / fcfg.batch_size))
    p, _ = F.sgd_train(jax.random.PRNGKey(seed), pooled, RCFG, fcfg,
                       steps=steps)
    return p


class Timer:
    def __init__(self):
        self.t0 = time.time()

    def us(self, calls: int = 1) -> float:
        return (time.time() - self.t0) * 1e6 / max(calls, 1)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
