"""Table 1: sentence-encoder ablation analogue. The paper varies the frozen
encoder (768-d mpnet, 384-d MiniLM, 768-d ALBERT) and finds routing quality
roughly constant. Offline we vary the featurizer dimensionality of the
synthetic corpus (queries re-embedded at d ∈ {24, 48, 96}) and report
centralized AUC for both router families."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks import common as C
from repro import routers
from repro.data.partition import federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus


def run():
    t = C.Timer()
    out = {}
    for d_emb in (24, 48, 96):
        corpus = make_eval_corpus(jax.random.PRNGKey(1), n_queries=4000,
                                  n_tasks=C.N_TASKS, n_models=C.N_MODELS,
                                  d_emb=d_emb)
        fcfg = dataclasses.replace(C.FCFG, seed=2)
        split = federated_split(jax.random.PRNGKey(2), corpus, fcfg)
        rcfg = dataclasses.replace(C.RCFG, d_emb=d_emb)
        tg = split["test_global"]
        pooled = flatten_clients(split["train"])

        p_cen, _ = routers.fit_local(routers.make("mlp", rcfg), pooled,
                                     fcfg, key=jax.random.PRNGKey(3),
                                     steps=300)
        auc_mlp = C.auc_of(p_cen, tg)

        km_cen, _ = routers.fit_local(routers.make("kmeans", rcfg), pooled,
                                      fcfg, key=jax.random.PRNGKey(4),
                                      k=rcfg.k_global)
        auc_km = C.auc_of(km_cen, tg)

        us = t.us()
        C.emit(f"tab1_d{d_emb}_mlp_auc", us, f"{auc_mlp:.4f}")
        C.emit(f"tab1_d{d_emb}_kmeans_auc", us, f"{auc_km:.4f}")
        out[d_emb] = (auc_mlp, auc_km)
    return out


if __name__ == "__main__":
    run()
