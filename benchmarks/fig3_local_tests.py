"""Fig. 3/10/11: federated vs client-local routers on each client's LOCAL
test set — the in-distribution model-coverage effect."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as C
from repro.core import kmeans_router as KR


def run():
    _, split, fcfg = C.corpus_and_split()
    t = C.Timer()
    fed_mlp, _ = C.train_fed_mlp(split, fcfg)
    locals_mlp = C.train_local_mlps(split, fcfg)
    r_fed = KR.fed_kmeans_router(jax.random.PRNGKey(3), split["train"],
                                 C.RCFG)

    fed_m, loc_m, fed_k, loc_k = [], [], [], []
    for i, test_i in enumerate(split["test"]):
        if test_i["x"].shape[0] < 10:
            continue
        fed_m.append(C.auc_of(C.mlp_pred(fed_mlp), test_i))
        loc_m.append(C.auc_of(C.mlp_pred(locals_mlp[i]), test_i))
        fed_k.append(C.auc_of(C.kmeans_pred(r_fed), test_i))
        r_i = KR.local_kmeans_router(
            jax.random.PRNGKey(40 + i),
            jax.tree.map(lambda a: a[i], split["train"]), C.RCFG)
        loc_k.append(C.auc_of(C.kmeans_pred(r_i), test_i))

    us = t.us()
    C.emit("fig3_mlp_fed_mean_local_auc", us, f"{np.mean(fed_m):.4f}")
    C.emit("fig3_mlp_local_mean_local_auc", us, f"{np.mean(loc_m):.4f}")
    C.emit("fig3_kmeans_fed_mean_local_auc", us, f"{np.mean(fed_k):.4f}")
    C.emit("fig3_kmeans_local_mean_local_auc", us, f"{np.mean(loc_k):.4f}")
    wins = sum(f >= l for f, l in zip(fed_m, loc_m))
    C.emit("fig3_mlp_fed_wins_clients", us, f"{wins}/{len(fed_m)}")
    return {"mlp": (np.mean(fed_m), np.mean(loc_m)),
            "kmeans": (np.mean(fed_k), np.mean(loc_k))}


if __name__ == "__main__":
    run()
