"""Fig. 3/10/11: federated vs client-local routers on each client's LOCAL
test set — the in-distribution model-coverage effect."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.data.partition import client_slice


def run():
    _, split, fcfg = C.corpus_and_split()
    t = C.Timer()
    fed_mlp, _ = C.train_fed_mlp(split, fcfg)
    locals_mlp = C.train_local_mlps(split, fcfg)
    r_fed = C.train_fed_kmeans(split, fcfg)

    fed_m, loc_m, fed_k, loc_k = [], [], [], []
    for i, test_i in enumerate(split["test"]):
        if test_i["x"].shape[0] < 10:
            continue
        fed_m.append(C.auc_of(fed_mlp, test_i))
        loc_m.append(C.auc_of(locals_mlp[i], test_i))
        fed_k.append(C.auc_of(r_fed, test_i))
        r_i = C.train_local_kmeans(client_slice(split["train"], i),
                                   seed=40 + i, fcfg=fcfg)
        loc_k.append(C.auc_of(r_i, test_i))

    us = t.us()
    C.emit("fig3_mlp_fed_mean_local_auc", us, f"{np.mean(fed_m):.4f}")
    C.emit("fig3_mlp_local_mean_local_auc", us, f"{np.mean(loc_m):.4f}")
    C.emit("fig3_kmeans_fed_mean_local_auc", us, f"{np.mean(fed_k):.4f}")
    C.emit("fig3_kmeans_local_mean_local_auc", us, f"{np.mean(loc_k):.4f}")
    wins = sum(f >= l for f, l in zip(fed_m, loc_m))
    C.emit("fig3_mlp_fed_wins_clients", us, f"{wins}/{len(fed_m)}")
    return {"mlp": (np.mean(fed_m), np.mean(loc_m)),
            "kmeans": (np.mean(fed_k), np.mean(loc_k))}


if __name__ == "__main__":
    run()
