"""Roofline table (deliverable g): reads the dry-run JSONL cache and prints
per-(arch × shape × mesh) compute/memory/collective terms, the dominant
bottleneck, and MODEL_FLOPS/HLO_FLOPs. Does NOT lower anything itself —
run launch/dryrun.py first (it needs the 512-device process)."""
from __future__ import annotations

import json
import pathlib

from benchmarks import common as C

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun.jsonl"


def load(tag=None):
    recs = {}
    if not RESULTS.exists():
        return recs
    for line in RESULTS.read_text().splitlines():
        if not line.strip():
            continue
        r = json.loads(line)
        if tag and r.get("tag") != tag:
            continue
        recs[(r["arch"], r["shape"], r["mesh"], r.get("tag", "baseline"))] = r
    return recs


def run():
    recs = load()
    n_ok = n_skip = n_err = 0
    for key, r in sorted(recs.items()):
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}_{r.get('tag')}"
        if r["status"] == "skipped":
            n_skip += 1
            C.emit(name, 0.0, f"skipped:{r['reason']}")
            continue
        if r["status"] != "ok":
            n_err += 1
            C.emit(name, 0.0, "ERROR")
            continue
        n_ok += 1
        dom_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        C.emit(name, dom_us,
               f"dom={r['dominant']};compute_ms={r['compute_s']*1e3:.2f};"
               f"memory_ms={r['memory_s']*1e3:.2f};"
               f"collective_ms={r['collective_s']*1e3:.2f};"
               f"useful={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)}")
    C.emit("roofline_summary", 0.0, f"ok={n_ok};skipped={n_skip};err={n_err}")
    return recs


if __name__ == "__main__":
    run()
