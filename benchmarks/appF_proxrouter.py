"""Appendix F: the paper's second benchmark (ProxRouter-Data analogue).

14 models × 10 task clusters, Dirichlet α = 0.4 query heterogeneity,
UNIFORM model logging (App. B.2: "For ProxRouter-Data, we use uniform model
logging for variety"). Repeats the Fig. 2 (fed vs local, global test) and
Fig. 9 (fed vs centralized) comparisons — App. F reports the same
conclusions hold."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks import common as C
from repro.core import federated as F
from repro.core import kmeans_router as KR
from repro.data.partition import client_slice, federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus

N_MODELS_PROX = 14


def run():
    t = C.Timer()
    corpus = make_eval_corpus(jax.random.PRNGKey(21), n_queries=6000,
                              n_tasks=10, n_models=N_MODELS_PROX,
                              d_emb=C.D_EMB)
    rcfg = dataclasses.replace(C.RCFG, num_models=N_MODELS_PROX)
    fcfg = dataclasses.replace(C.FCFG, dirichlet_alpha=0.4,
                               model_alpha=float("inf"), seed=21)
    split = federated_split(jax.random.PRNGKey(22), corpus, fcfg)
    tg = split["test_global"]

    fed_mlp, _ = F.fedavg(jax.random.PRNGKey(23), split["train"], rcfg,
                          fcfg, rounds=30)
    auc_fed = C.auc_of(lambda x: F.R.apply_mlp_router(fed_mlp, x), tg)
    aucs_loc = []
    for i in range(fcfg.num_clients):
        p_i, _ = F.sgd_train(jax.random.PRNGKey(40 + i),
                             client_slice(split["train"], i), rcfg, fcfg,
                             steps=400)
        aucs_loc.append(C.auc_of(
            lambda x, p=p_i: F.R.apply_mlp_router(p, x), tg))
    cen, _ = F.sgd_train(jax.random.PRNGKey(24),
                         flatten_clients(split["train"]), rcfg, fcfg,
                         steps=360)
    auc_cen = C.auc_of(lambda x: F.R.apply_mlp_router(cen, x), tg)

    km_fed = KR.fed_kmeans_router(jax.random.PRNGKey(25), split["train"],
                                  rcfg, num_models=N_MODELS_PROX)
    auc_kfed = C.auc_of(C.kmeans_pred(km_fed), tg)
    aucs_kloc = [
        C.auc_of(C.kmeans_pred(KR.local_kmeans_router(
            jax.random.PRNGKey(50 + i), client_slice(split["train"], i),
            rcfg, num_models=N_MODELS_PROX)), tg)
        for i in range(fcfg.num_clients)]

    us = t.us()
    C.emit("appF_mlp_fed_auc", us, f"{auc_fed:.4f}")
    C.emit("appF_mlp_local_mean_auc", us, f"{np.mean(aucs_loc):.4f}")
    C.emit("appF_mlp_centralized_auc", us, f"{auc_cen:.4f}")
    C.emit("appF_kmeans_fed_auc", us, f"{auc_kfed:.4f}")
    C.emit("appF_kmeans_local_mean_auc", us, f"{np.mean(aucs_kloc):.4f}")
    return {"mlp": (auc_fed, np.mean(aucs_loc), auc_cen),
            "kmeans": (auc_kfed, np.mean(aucs_kloc))}


if __name__ == "__main__":
    run()
