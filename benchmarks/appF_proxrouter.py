"""Appendix F: the paper's second benchmark (ProxRouter-Data analogue).

14 models × 10 task clusters, Dirichlet α = 0.4 query heterogeneity,
UNIFORM model logging (App. B.2: "For ProxRouter-Data, we use uniform model
logging for variety"). Repeats the Fig. 2 (fed vs local, global test) and
Fig. 9 (fed vs centralized) comparisons — App. F reports the same
conclusions hold."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks import common as C
from repro import routers
from repro.data.partition import client_slice, federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus

N_MODELS_PROX = 14


def run():
    t = C.Timer()
    corpus = make_eval_corpus(jax.random.PRNGKey(21), n_queries=6000,
                              n_tasks=10, n_models=N_MODELS_PROX,
                              d_emb=C.D_EMB)
    rcfg = dataclasses.replace(C.RCFG, num_models=N_MODELS_PROX)
    fcfg = dataclasses.replace(C.FCFG, dirichlet_alpha=0.4,
                               model_alpha=float("inf"), seed=21)
    split = federated_split(jax.random.PRNGKey(22), corpus, fcfg)
    tg = split["test_global"]

    fed_mlp, _ = routers.fit_federated(routers.make("mlp", rcfg),
                                       split["train"], fcfg,
                                       key=jax.random.PRNGKey(23),
                                       rounds=30)
    auc_fed = C.auc_of(fed_mlp, tg)
    aucs_loc = []
    for i in range(fcfg.num_clients):
        p_i, _ = routers.fit_local(routers.make("mlp", rcfg),
                                   client_slice(split["train"], i), fcfg,
                                   key=jax.random.PRNGKey(40 + i),
                                   steps=400)
        aucs_loc.append(C.auc_of(p_i, tg))
    cen, _ = routers.fit_local(routers.make("mlp", rcfg),
                               flatten_clients(split["train"]), fcfg,
                               key=jax.random.PRNGKey(24), steps=360)
    auc_cen = C.auc_of(cen, tg)

    km_fed = C.train_fed_kmeans(split, fcfg, seed=25, rcfg=rcfg,
                                num_models=N_MODELS_PROX)
    auc_kfed = C.auc_of(km_fed, tg)
    aucs_kloc = [
        C.auc_of(C.train_local_kmeans(client_slice(split["train"], i),
                                      seed=50 + i, fcfg=fcfg, rcfg=rcfg,
                                      num_models=N_MODELS_PROX), tg)
        for i in range(fcfg.num_clients)]

    us = t.us()
    C.emit("appF_mlp_fed_auc", us, f"{auc_fed:.4f}")
    C.emit("appF_mlp_local_mean_auc", us, f"{np.mean(aucs_loc):.4f}")
    C.emit("appF_mlp_centralized_auc", us, f"{auc_cen:.4f}")
    C.emit("appF_kmeans_fed_auc", us, f"{auc_kfed:.4f}")
    C.emit("appF_kmeans_local_mean_auc", us, f"{np.mean(aucs_kloc):.4f}")
    return {"mlp": (auc_fed, np.mean(aucs_loc), auc_cen),
            "kmeans": (auc_kfed, np.mean(aucs_kloc))}


if __name__ == "__main__":
    run()
