"""Paper-core correctness: policy, K-means router, FedAvg, personalization,
onboarding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig, RouterConfig
from repro.core import expansion as E
from repro.core import federated as F
from repro.core import kmeans_router as KR
from repro.core import mlp_router as R
from repro.core import personalization as P
from repro.core import policy
from repro.core.kmeans import kmeans
from repro.data.partition import client_slice, federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus

RCFG = RouterConfig(d_emb=16, num_models=5, hidden=(32, 32), k_local=4,
                    k_global=6)
FCFG = FedConfig(num_clients=4, rounds=3, batch_size=32, seed=1)


@pytest.fixture(scope="module")
def split():
    corpus = make_eval_corpus(jax.random.PRNGKey(0), n_queries=1200,
                              n_tasks=4, n_models=5, d_emb=16)
    return federated_split(jax.random.PRNGKey(1), corpus, FCFG)


# ---------------------------------------------------------------------- policy

def test_route_argmax_matches_manual():
    A = jnp.array([[0.9, 0.5], [0.2, 0.8]])
    C = jnp.array([[1.0, 0.1], [0.5, 0.9]])
    assert policy.route(A, C, 0.0).tolist() == [0, 1]
    assert policy.route(A, C, 10.0).tolist() == [1, 0]


def test_frontier_auc_bounds_and_oracle_best(split):
    tg = split["test_global"]
    # oracle router (true tables) must beat a random-estimate router
    *_, auc_oracle = policy.eval_router(
        lambda x: (tg["acc_table"], tg["cost_table"]), tg["x"],
        tg["acc_table"], tg["cost_table"])
    key = jax.random.PRNGKey(3)
    rand_A = jax.random.uniform(key, tg["acc_table"].shape)
    *_, auc_rand = policy.eval_router(
        lambda x: (rand_A, tg["cost_table"]), tg["x"], tg["acc_table"],
        tg["cost_table"])
    assert 0.0 <= auc_rand <= 1.0 and 0.0 <= auc_oracle <= 1.0
    assert auc_oracle >= auc_rand


# --------------------------------------------------------------------- kmeans

def test_kmeans_assign_is_nearest():
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (200, 8))
    cents, _ = kmeans(key, X, 5, iters=10, n_init=2)
    from repro.kernels.ops import kmeans_assign
    a = kmeans_assign(X, cents)
    d2 = jnp.sum((X[:, None] - cents[None]) ** 2, -1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(jnp.argmin(d2, 1)))


def test_kmeans_mask_excludes_padding():
    key = jax.random.PRNGKey(0)
    X = jnp.concatenate([jax.random.normal(key, (50, 4)),
                         1e6 * jnp.ones((10, 4))])
    mask = jnp.concatenate([jnp.ones(50), jnp.zeros(10)])
    cents, _ = kmeans(key, X, 3, iters=10, n_init=1, mask=mask > 0)
    assert float(jnp.max(jnp.abs(cents))) < 1e3  # padding never absorbed


def test_fed_kmeans_router_shapes(split):
    r = KR.fed_kmeans_router(jax.random.PRNGKey(0), split["train"], RCFG,
                             num_models=5)
    K = RCFG.k_global
    assert r["centroids"].shape == (K, 16)
    assert r["A"].shape == (K, 5) and r["C"].shape == (K, 5)
    assert bool(jnp.all((r["A"] >= 0) & (r["A"] <= 1)))
    A, C = KR.predict(r, split["test_global"]["x"][:7])
    assert A.shape == (7, 5)


def test_kmeans_stats_match_manual_average(split):
    """Server aggregation (Alg. 2 line 14) = count-weighted global mean."""
    r = KR.fed_kmeans_router(jax.random.PRNGKey(0), split["train"], RCFG,
                             num_models=5)
    from repro.kernels.ops import kmeans_assign
    tr = split["train"]
    N, D = tr["m"].shape
    flat = jax.tree.map(lambda a: a.reshape((N * D,) + a.shape[2:]), tr)
    assign = kmeans_assign(flat["x"], r["centroids"])
    for k in range(RCFG.k_global):
        for m in range(5):
            sel = (np.asarray(assign) == k) & (np.asarray(flat["m"]) == m) \
                & (np.asarray(flat["w"]) > 0)
            if sel.sum() == 0:
                continue
            np.testing.assert_allclose(float(r["A"][k, m]),
                                       np.asarray(flat["acc"])[sel].mean(),
                                       rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------- fedavg

def test_fedavg_tau1_fullbatch_equals_centralized_gd(split):
    """Alg. 1 with τ=1 full-batch SGD and full participation must equal
    centralized full-batch gradient descent on the pooled loss."""
    fcfg = FedConfig(num_clients=4, participation=1.0, lr=0.05, seed=0)
    init = R.init_mlp_router(jax.random.PRNGKey(7), RCFG)
    fed_params, _ = F.fedavg(jax.random.PRNGKey(0), split["train"], RCFG,
                             fcfg, rounds=3, optimizer="sgd",
                             full_batch=True, init=init)

    # manual centralized GD (pooled, sample-weighted = D_i-weighted)
    pooled = flatten_clients(split["train"])
    params = init
    for _ in range(3):
        g = jax.grad(lambda p: R.router_loss(p, pooled, RCFG))(params)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    for a, b in zip(jax.tree.leaves(fed_params), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


def test_fedavg_reduces_loss(split):
    _, hist = F.fedavg(jax.random.PRNGKey(0), split["train"], RCFG, FCFG,
                       rounds=6)
    assert hist["loss"][-1] < hist["loss"][0]


def test_fedavg_aggregation_convex(split):
    """The server aggregation (Alg. 1 line 11) is a weighted mean: it must
    lie in the convex hull of the client params and match the manual
    tensordot for the same client updates."""
    opt = F._make_opt(FCFG, "adamw")
    params = R.init_mlp_router(jax.random.PRNGKey(0), RCFG)
    cp, _ = jax.vmap(lambda d, k: F.client_update(params, d, k, RCFG, FCFG,
                                                  opt, 2),
                     in_axes=(0, 0))(split["train"],
                                     jax.random.split(jax.random.PRNGKey(1),
                                                      4))
    wts = F.dataset_sizes(split["train"])
    wts = wts / jnp.sum(wts)
    agg = jax.tree.map(
        lambda s_: jnp.tensordot(wts, s_.astype(jnp.float32), axes=1), cp)
    for leaf, stack in zip(jax.tree.leaves(agg), jax.tree.leaves(cp)):
        lo = np.asarray(stack).min(0) - 1e-5
        hi = np.asarray(stack).max(0) + 1e-5
        a = np.asarray(leaf)
        assert ((a >= lo) & (a <= hi)).all()


# -------------------------------------------------------------- personalization

def test_mixture_weights_bounds_and_edges():
    e_f = jnp.array([0.1, 0.5, jnp.inf, jnp.inf])
    e_l = jnp.array([0.1, jnp.inf, 0.2, jnp.inf])
    w = P.mixture_weights(e_f, e_l)
    assert bool(jnp.all((w >= 0) & (w <= 1)))
    assert w[1] == 0.0   # local never logged m → use fed
    assert w[2] == 1.0   # fed never saw m → use local
    assert w[3] == 0.0


def test_personalized_interpolates(split):
    di = client_slice(split["train"], 0)
    fed = lambda x: (jnp.full((x.shape[0], 5), 0.8),
                     jnp.full((x.shape[0], 5), 0.5))
    loc = lambda x: (jnp.full((x.shape[0], 5), 0.2),
                     jnp.full((x.shape[0], 5), 0.1))
    mixed, (wa, wc) = P.make_personalized(fed, loc, di, 5)
    A, C = mixed(di["x"][:3])
    assert bool(jnp.all((A >= 0.2 - 1e-6) & (A <= 0.8 + 1e-6)))
    assert bool(jnp.all((wa >= 0) & (wa <= 1)))


# ------------------------------------------------------------------ expansion

def test_mlp_model_onboarding_trains_only_new_head(split):
    key = jax.random.PRNGKey(0)
    base, _ = F.fedavg(key, split["train"], RCFG, FCFG, rounds=2)
    calib = flatten_clients(split["train"])
    # pretend model 5 is new: relabel some samples
    calib = dict(calib)
    calib["m"] = jnp.where(calib["m"] == 0, 5, calib["m"])
    new_params, _ = E.onboard_models_mlp(key, base, calib, RCFG, FCFG, 1,
                                         steps=30)
    assert new_params["heads"]["acc_w"].shape[1] == 6
    # frozen trunk + old heads unchanged
    for a, b in zip(jax.tree.leaves(base["trunk"]),
                    jax.tree.leaves(new_params["trunk"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(base["heads"]["acc_w"]),
        np.asarray(new_params["heads"]["acc_w"][:, :5]))


def test_kmeans_model_onboarding(split):
    r = KR.fed_kmeans_router(jax.random.PRNGKey(0), split["train"], RCFG,
                             num_models=5)
    calib = {"x": split["test_global"]["x"][:100],
             "acc": jnp.ones(100) * 0.7, "cost": jnp.ones(100) * 0.3,
             "w": jnp.ones(100)}
    r2 = KR.add_model_stats(r, calib)
    assert r2["A"].shape == (RCFG.k_global, 6)
    np.testing.assert_array_equal(np.asarray(r["A"]),
                                  np.asarray(r2["A"][:, :5]))


def test_kmeans_client_onboarding_counts_add(split):
    r = KR.fed_kmeans_router(jax.random.PRNGKey(0), split["train"], RCFG,
                             num_models=5)
    r2 = KR.merge_client_stats(r, split["train"], RCFG, num_models=5)
    assert float(jnp.sum(r2["n"])) == pytest.approx(
        2 * float(jnp.sum(r["n"])), rel=1e-6)


# ------------------------------------------------------------------ extras

def test_fedavg_dp_noise_option(split):
    """dp_sigma=0 is exact; dp_sigma>0 perturbs but still trains."""
    p0, h0 = F.fedavg(jax.random.PRNGKey(5), split["train"], RCFG, FCFG,
                      rounds=6, dp_sigma=0.0)
    p1, h1 = F.fedavg(jax.random.PRNGKey(5), split["train"], RCFG, FCFG,
                      rounds=6, dp_sigma=1e-3)
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1))]
    assert max(diffs) > 1e-5           # noise did something
    assert h1["loss"][-1] < h1["loss"][0]   # and training still converges


def test_secure_aggregation_masks_cancel(split):
    """Masked aggregate ≡ plain weighted mean; individual contributions are
    hidden (far from the raw updates)."""
    from repro.core import secure_agg as SA
    key = jax.random.PRNGKey(0)
    N = 4
    updates = [R.init_mlp_router(jax.random.PRNGKey(10 + i), RCFG)
               for i in range(N)]
    wts = [1.0, 2.0, 3.0, 4.0]
    round_key = jax.random.PRNGKey(99)
    masked = [SA.mask_update(round_key, i, N, updates[i], wts[i])
              for i in range(N)]
    agg = SA.secure_aggregate(masked, sum(wts))
    # plain weighted mean
    want = jax.tree.map(
        lambda *ls: sum(w * l for w, l in zip(wts, ls)) / sum(wts), *updates)
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)
    # privacy: a masked contribution is nowhere near the raw update
    raw0 = jax.tree.leaves(updates[0])[0]
    msk0 = jax.tree.leaves(masked[0])[0]
    assert float(jnp.mean(jnp.abs(msk0 - raw0))) > 1.0
