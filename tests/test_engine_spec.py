"""Speculative multi-token decode (ISSUE 9 acceptance suite).

The contract: a speculative engine (``EngineConfig.spec_k > 0``) emits
tokens BIT-IDENTICAL to the non-speculative engine — and therefore to the
single-request solo scan path — for every drafter pairing, while running
zero decode retraces (acceptance variation is data, never shape). Edge
cases pinned here: all-k-rejected rounds (degenerate to one plain step),
verify windows straddling page boundaries without leaking pages, a
drafter equal to the target (full acceptance — the self-speculation
sanity bound), preemption/cancel/expiry with unverified drafts in flight
(partials stay exact solo prefixes: uncommitted drafts never surface),
and the gateway's ``drain(rids=)`` passthrough regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import routers
from repro.config import ModelConfig, RouterConfig
from repro.serve import gateway
from repro.serve.engine import (CANCELLED, DONE, EXPIRED, PREEMPTED_RESUMED,
                                EngineConfig, Outcome, ServeEngine)
from repro.serve.gateway import PoolModel, RoutedServer

TGT = ModelConfig(name="spec-tgt", arch_type="dense", n_layers=2,
                  d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                  head_dim=16)
#: independent tiny drafter: different seed AND depth — near-zero
#: agreement with the target, so it exercises the rejection path hard
DRF = ModelConfig(name="spec-drf", arch_type="dense", n_layers=1,
                  d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                  head_dim=16)
SSM = ModelConfig(name="spec-ssm", arch_type="ssm", n_layers=1,
                  d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                  head_dim=16)


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    # This module compiles dozens of engine programs (draft/verify/admit
    # × uniform/paged × spec_k values × two drafters, plus the non-spec
    # references). Drop them when the module finishes so the full-suite
    # process doesn't accumulate every executable to the end of the run.
    yield
    jax.clear_caches()


@pytest.fixture(scope="module")
def pool():
    from repro.models import init_params
    return [PoolModel("spec-tgt", TGT,
                      init_params(jax.random.PRNGKey(0), TGT), 1.0),
            PoolModel("spec-drf", DRF,
                      init_params(jax.random.PRNGKey(7), DRF), 0.2)]


def _toks(seed, n):
    return np.random.default_rng(seed).integers(
        1, TGT.vocab, size=n).astype(np.int32)


REQS = [(_toks(10 + i, 3 + 2 * i), 6 + 3 * i) for i in range(4)]


def _run(pool, ecfg, reqs=REQS, draft=None):
    eng = ServeEngine(pool, ecfg)
    rids = [eng.submit(0, t, m, draft=draft) for t, m in reqs]
    out = eng.drain()
    return {r: np.asarray(out[r]) for r in rids}, eng


def _ecfg(paged, **kw):
    base = dict(slots=4, max_seq=64, chunk=4)
    if paged:
        base.update(page_size=4, pages=80)
    base.update(kw)
    return EngineConfig(**base)


def _assert_pool_recovered(eng):
    for lane in eng._lanes.values():
        assert sorted(lane.free) == list(range(eng.ecfg.slots))
        assert not lane.active and not lane.queue
        assert (lane.tok == 0).all() and (lane.pos == 0).all()
        if lane.paged:
            assert sorted(lane.pt.free) == \
                list(range(1, eng.ecfg.resolved_pages + 1))
            assert not lane.pt._held and (lane.pt.table == 0).all()


# --------------------------------------------------------------- parity
@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("spec_k", [1, 3, 5])
def test_spec_tokens_bit_identical_to_nonspec(pool, paged, spec_k):
    """THE tentpole property: every request's tokens from the speculative
    engine equal the non-speculative engine's bit-for-bit, in both pool
    regimes, for self-drafting (full acceptance) AND an independent
    drafter (heavy rejection)."""
    ref, _ = _run(pool, _ecfg(paged))
    for draft in (0, 1):
        out, eng = _run(pool, _ecfg(paged, spec_k=spec_k), draft=draft)
        for r in ref:
            np.testing.assert_array_equal(ref[r], out[r])
        c = eng.counters()
        assert c["spec_rounds"] > 0
        assert c["spec_drafted"] == c["spec_accepted"] + c["spec_rejected"]
        _assert_pool_recovered(eng)


@pytest.mark.parametrize("paged", [False, True])
def test_self_draft_full_acceptance(pool, paged):
    """draft == target is the acceptance upper bound: the drafter's
    logits are the target's, so every drafted token must be accepted.
    This also pins draft-cache consistency across rounds — a single
    position the drafter failed to ingest (e.g. taking the verify's bonus
    token past the drafted window) would break equality from round two
    on, not just lower the rate."""
    out, eng = _run(pool, _ecfg(paged, spec_k=3), draft=0)
    c = eng.counters()
    assert c["spec_drafted"] > 0
    assert c["spec_accepted"] == c["spec_drafted"]
    assert c["spec_rejected"] == 0


@pytest.mark.parametrize("paged", [False, True])
def test_all_k_rejected_degenerates_to_plain_step(pool, paged):
    """An independent random-init drafter agrees with the target
    essentially never: rounds with zero accepted drafts must still
    commit exactly one correct token each (the verify's own argmax), so
    progress — and parity, checked above — never stalls."""
    out, eng = _run(pool, _ecfg(paged, spec_k=3), draft=1)
    c = eng.counters()
    assert c["spec_rejected"] > 0
    total = sum(m for _, m in REQS)
    assert sum(len(v) for v in out.values()) == total


def test_page_boundary_straddle_no_page_leaks(pool):
    """spec_k not dividing page_size: verify write-ahead windows straddle
    page boundaries every round, and near the region end they poke past
    the last claimed page (trash-redirected, never claimed). After drain
    the page pool must be exactly whole."""
    ecfg = EngineConfig(slots=3, max_seq=64, chunk=4, page_size=4,
                        pages=60, spec_k=3)
    ref, _ = _run(pool, _ecfg(True))
    out, eng = _run(pool, ecfg, draft=0)
    for r in ref:
        np.testing.assert_array_equal(ref[r], out[r])
    _assert_pool_recovered(eng)


def test_spec_zero_decode_retraces(pool):
    """Once warm, spec rounds compile nothing: draft/verify jits are
    cached per (config, spec_k) and acceptance variation is pure data.
    Runs both drafters so rejection-heavy and acceptance-heavy rounds
    share the same programs."""
    for draft in (0, 1):
        _run(pool, _ecfg(True, spec_k=3), draft=draft)    # warm
    gateway.reset_trace_log()
    n0 = len(gateway.TRACE_LOG)
    for draft in (0, 1):
        out, _ = _run(pool, _ecfg(True, spec_k=3), draft=draft)
    assert len(gateway.TRACE_LOG) == n0, \
        f"spec retrace: {list(gateway.TRACE_LOG)[n0:]}"


# ------------------------------------------------- lifecycle edge cases
def test_preemption_with_unverified_drafts_resumes_bit_identical(pool):
    """Preemption between spec rounds throws away the uncommitted drafted
    suffix by construction (only verified prefixes enter st.chunks); the
    resumed request re-prefills prompt + committed tokens and must finish
    bit-identical to its never-preempted twin."""
    ecfg = EngineConfig(slots=3, max_seq=32, chunk=4, page_size=4,
                        pages=8, reserve="initial", spec_k=3)
    ref_ecfg = EngineConfig(slots=3, max_seq=32, chunk=4, page_size=4,
                            pages=80)
    reqs = [(_toks(50 + i, 5 + i), 12) for i in range(3)]
    ref, _ = _run(pool, ref_ecfg, reqs=reqs)
    eng = ServeEngine(pool, ecfg)
    rids = [eng.submit(0, t, m, draft=0) for t, m in reqs]
    out = eng.drain()
    assert eng.preemptions > 0, "schedule failed to force a preemption"
    resumed = 0
    for rid, ref_rid in zip(rids, ref):
        np.testing.assert_array_equal(np.asarray(out[rid]), ref[ref_rid])
        resumed += eng.status(rid) == PREEMPTED_RESUMED
    assert resumed > 0
    _assert_pool_recovered(eng)


@pytest.mark.parametrize("terminal", ["cancel", "expire"])
def test_cancel_expire_mid_draft_discards_uncommitted(pool, terminal):
    """A request cancelled/expired between spec rounds surfaces ONLY
    committed tokens — an exact prefix of its solo reference. Uncommitted
    drafts (already physically written into both KV pools) must never
    leak into the partial."""
    solo, _ = _run(pool, _ecfg(True), reqs=[(REQS[0][0], 12)])
    solo_tokens = next(iter(solo.values()))
    eng = ServeEngine(pool, _ecfg(True, spec_k=3))
    if terminal == "cancel":
        rid = eng.submit(0, REQS[0][0], 12, draft=0)
        eng.step(); eng.step()
        assert eng.cancel(rid) == CANCELLED
        want = CANCELLED
    else:
        rid = eng.submit(0, REQS[0][0], 12, deadline=2, draft=0)
        eng.step(); eng.step(); eng.step()
        want = EXPIRED
    out = eng.drain()
    payload = out[rid]
    assert isinstance(payload, Outcome) and payload.status == want
    if payload.tokens is not None:
        n = len(payload.tokens)
        assert 0 < n < 12
        np.testing.assert_array_equal(payload.tokens, solo_tokens[:n])
    _assert_pool_recovered(eng)


# ------------------------------------------------------ API validation
def test_draft_requires_spec_mode(pool):
    eng = ServeEngine(pool, _ecfg(False))
    with pytest.raises(ValueError, match="spec_k"):
        eng.submit(0, _toks(1, 4), 4, draft=1)
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(pool, _ecfg(False, draft=1))


def test_bad_drafters_rejected(pool):
    from repro.models import init_params
    eng = ServeEngine(pool, _ecfg(False, spec_k=2))
    with pytest.raises(ValueError, match="pool index"):
        eng.submit(0, _toks(1, 4), 4, draft=9)
    big_vocab = ModelConfig(name="spec-vmismatch", arch_type="dense",
                            n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
                            d_ff=64, vocab=31, head_dim=16)
    pool3 = pool + [PoolModel("vm", big_vocab,
                              init_params(jax.random.PRNGKey(3), big_vocab),
                              0.1),
                    PoolModel("ssm", SSM, {}, 0.1)]
    eng3 = ServeEngine(pool3, _ecfg(False, spec_k=2))
    with pytest.raises(ValueError, match="token space"):
        eng3.submit(0, _toks(1, 4), 4, draft=2)
    with pytest.raises(TypeError, match="drafter"):
        eng3.submit(0, _toks(1, 4), 4, draft=3)


# ------------------------------------------- gateway: routing + drain()
def _make_server(pool, ecfg):
    router = routers.make(
        "kmeans", RouterConfig(d_emb=16, num_models=2),
        state={"centroids": jnp.zeros((1, 16)),
               "A": jnp.array([[0.9, 0.5]]), "C": jnp.array([[1.0, 0.2]]),
               "n": jnp.ones((1, 2))})
    return RoutedServer(pool, router, engine_cfg=ecfg)


def test_gateway_routes_cheaper_drafter(pool):
    """The gateway pairs a speculative request with the router's best
    strictly-cheaper model; the expensive target drafts with the cheap
    one, the cheap target self-drafts (nothing cheaper exists)."""
    srv = _make_server(pool, _ecfg(True, spec_k=3))
    x = np.zeros(16, np.float32)
    assert srv._pick_draft(0, x, 0.5) == 1
    assert srv._pick_draft(1, x, 0.5) == 1
    with pytest.raises(ValueError, match="spec"):
        _make_server(pool, _ecfg(True)).submit("a b", draft_model=1)


def test_gateway_drain_rids_passthrough(pool):
    """Regression (ISSUE 9 satellite): RoutedServer.drain dropped the
    engine's ``rids`` parameter — a selective drain through the gateway
    silently drained (and CLEARED) every interleaved stream's results.
    Now it passes through: draining one stream leaves the other's results
    on the engine."""
    srv = _make_server(pool, _ecfg(True))
    ra = srv.submit("stream one alpha", max_new_tokens=6)
    rb = srv.submit("stream two beta gamma", max_new_tokens=7)
    out_a = srv.drain(rids=[ra])
    assert ra in out_a and rb not in out_a
    out_b = srv.drain([rb])
    assert rb in out_b and out_b[rb].shape == (7,)
    assert srv.drain() == {}


def test_spec_counters_flow_through_gateway(pool):
    """ServeEngine.counters() carries the spec accounting, so the FedLoop
    sync-history snapshot (which stores counters() verbatim) picks it up
    with no further plumbing."""
    srv = _make_server(pool, _ecfg(True, spec_k=3))
    srv.submit("gamma delta epsilon", max_new_tokens=8)
    srv.drain()
    c = srv.engine.counters()
    for key in ("spec_rounds", "spec_drafted", "spec_accepted",
                "spec_rejected"):
        assert key in c
    assert c["spec_drafted"] == c["spec_accepted"] + c["spec_rejected"] > 0
