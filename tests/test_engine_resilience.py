"""Serve-engine resilience (ISSUE 8 acceptance suite): deadlines,
cancellation, paged-pool preemption with bit-identical recompute-on-resume,
load shedding, typed terminal statuses, the PageTable release/grow guards,
the gateway's expiry-as-backend-failure accounting, and the FedLoop
checkpoint guard with preempted requests in flight — all with ZERO decode
retraces (TRACE_LOG-pinned)."""
import jax
import numpy as np
import pytest

from repro import routers
from repro.config import FedConfig, ModelConfig, RouterConfig
from repro.fed.faults import FaultPlan
from repro.fed.harvest import HarvestStore
from repro.fed.loop import FedLoop, FedLoopConfig
from repro.fed.scenarios import engine_chaos_schedule
from repro.serve import gateway
from repro.serve.engine import (CANCELLED, DONE, EXPIRED, PREEMPTED_RESUMED,
                                SHED, TERMINAL_STATUSES, EngineConfig,
                                Outcome, ServeEngine)
from repro.serve.gateway import PoolModel, RoutedServer
from repro.serve.kv_cache import PageTable

TINY = ModelConfig(name="tiny-dense-resil", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                   head_dim=16)
#: oversubscribed initial-reservation shape used across the preemption
#: tests: 3 slots but only 8 pages of 4 — two long requests already
#: exceed the pool mid-decode, so growth must preempt.
PREEMPT_ECFG = EngineConfig(slots=3, max_seq=32, chunk=4, page_size=4,
                            pages=8, reserve="initial")


@pytest.fixture(scope="module")
def pm():
    from repro.models import init_params
    return PoolModel("tiny", TINY, init_params(jax.random.PRNGKey(0), TINY),
                     0.1)


_solo_cache = {}


def _solo(pm, toks, max_new):
    key = (np.asarray(toks).tobytes(), max_new)
    if key not in _solo_cache:
        _solo_cache[key] = RoutedServer._serve_batch(
            pm, np.asarray(toks)[None], max_new)[0]
    return _solo_cache[key]


def _toks(seed, n):
    return np.random.default_rng(seed).integers(
        1, TINY.vocab, size=n).astype(np.int32)


def _assert_pool_recovered(eng):
    """Slots, pages, queue, and carry all back to the initial state."""
    for lane in eng._lanes.values():
        assert sorted(lane.free) == list(range(eng.ecfg.slots))
        assert not lane.active and not lane.queue
        assert (lane.tok == 0).all() and (lane.pos == 0).all()
        if lane.paged:
            assert sorted(lane.pt.free) == \
                list(range(1, eng.ecfg.resolved_pages + 1))
            assert not lane.pt._held and (lane.pt.table == 0).all()
    assert not eng.busy and not eng._events


# ----------------------------------------- satellite: PageTable guards


def test_pagetable_release_double_release_is_deterministic_noop():
    pt = PageTable(slots=2, pages=4, page_size=4, max_seq=32)
    pt.alloc(0, 3)
    assert pt.available == 1
    assert pt.release(0) is True
    assert pt.available == 4
    # double release: deterministic no-op, the free list is NOT corrupted
    assert pt.release(0) is False
    assert pt.release(0) is False
    assert sorted(pt.free) == [1, 2, 3, 4]
    # a slot that never held pages is the same no-op...
    assert pt.release(1) is False
    # ...but an out-of-table slot index is a caller bug and raises
    with pytest.raises(IndexError, match="outside the page table"):
        pt.release(7)


def test_pagetable_grow_guards():
    pt = PageTable(slots=2, pages=4, page_size=4, max_seq=16)  # width 4
    with pytest.raises(RuntimeError, match="holds no pages"):
        pt.grow(0, 1)
    pages = list(pt.alloc(0, 2))
    pages += list(pt.grow(0, 2))
    assert len(set(pages)) == 4 and pt.available == 0
    assert (pt.table[0] == pages).all()
    with pytest.raises(RuntimeError, match="wide"):
        pt.grow(0, 1)                       # past the static table width
    pt2 = PageTable(slots=2, pages=2, page_size=4, max_seq=32)
    pt2.alloc(0, 2)
    pt2._held[1] = []                       # simulate an admitted-empty row
    with pytest.raises(RuntimeError, match="exhausted"):
        pt2.grow(0, 1)


# --------------------------------------------- cancellation & deadlines


def test_cancel_queued_and_active(pm):
    eng = ServeEngine([pm], EngineConfig(slots=1, max_seq=32, chunk=4,
                                         page_size=8))
    t_a, t_b = _toks(0, 5), _toks(1, 4)
    ra = eng.submit(0, t_a, 12)
    rb = eng.submit(0, t_b, 4)              # waits: one slot
    eng.step()
    assert eng.status(ra) == "ACTIVE" and eng.status(rb) == "QUEUED"
    # cancel the queued request: nothing was generated
    assert eng.cancel(rb) == CANCELLED
    # cancel the active one mid-flight: partial tokens are a solo prefix
    assert eng.cancel(ra) == CANCELLED
    out = eng.drain()
    assert isinstance(out[ra], Outcome) and out[ra].status == CANCELLED
    assert out[rb].tokens is None
    np.testing.assert_array_equal(out[ra].tokens,
                                  _solo(pm, t_a, 12)[:len(out[ra].tokens)])
    assert eng.cancels == 2
    # cancelling a terminal rid is a no-op returning its status
    assert eng.cancel(ra) == CANCELLED
    with pytest.raises(KeyError, match="unknown request id"):
        eng.cancel(10 ** 9)
    _assert_pool_recovered(eng)


def test_deadline_expiry_releases_and_surfaces_partial_tokens(pm):
    eng = ServeEngine([pm], EngineConfig(slots=2, max_seq=32, chunk=4,
                                         page_size=8))
    t = _toks(2, 5)
    r_exp = eng.submit(0, t, 16, deadline=2)
    r_ok = eng.submit(0, _toks(3, 4), 16)
    eng.step()
    eng.step()
    finished = dict(eng.step())             # the expiry surfaces here
    assert isinstance(finished[r_exp], Outcome)
    assert finished[r_exp].status == EXPIRED
    # deadline=2 ⇒ two steps of progress ⇒ 2 chunks of partial tokens,
    # still a bit-exact solo prefix
    np.testing.assert_array_equal(finished[r_exp].tokens,
                                  _solo(pm, t, 16)[:8])
    assert eng.expiries == 1
    assert eng.status(r_exp) == EXPIRED
    out = eng.drain()
    assert out[r_ok].shape == (16,)         # the undeadlined one completes
    _assert_pool_recovered(eng)
    with pytest.raises(ValueError, match="deadline"):
        eng.submit(0, t, 4, deadline=0)


def test_queued_request_expires_without_ever_admitting(pm):
    eng = ServeEngine([pm], EngineConfig(slots=1, max_seq=32, chunk=4,
                                         page_size=8))
    ra = eng.submit(0, _toks(4, 4), 12)
    rb = eng.submit(0, _toks(5, 4), 4, deadline=1)   # starves in queue
    out = eng.drain()
    assert out[rb].status == EXPIRED and out[rb].tokens is None
    assert out[ra].shape == (12,)
    _assert_pool_recovered(eng)


def test_drain_rids_returns_typed_terminal_instead_of_raising(pm):
    """Satellite: drain(rids=...) on a cancelled/expired/shed rid returns
    its typed record — no hang, no KeyError; only never-seen rids raise."""
    eng = ServeEngine([pm], EngineConfig(slots=1, max_seq=32, chunk=4,
                                         page_size=8, queue_cap=1))
    ra = eng.submit(0, _toks(6, 4), 12)
    eng.step()                              # ra takes the slot
    rb = eng.submit(0, _toks(7, 4), 12, deadline=1)
    rc = eng.submit(0, _toks(8, 4), 4)      # queue full (cap 1) → shed
    assert eng.status(rc) == SHED
    eng.cancel(ra)
    got = eng.drain([ra, rb, rc])
    assert got[ra].status == CANCELLED
    assert got[rb].status == EXPIRED
    assert got[rc].status == SHED
    # the engine is idle and the rids are terminal: drain again still
    # resolves them (typed, from the status map) instead of KeyError-ing
    again = eng.drain([rc])
    assert again[rc].status == SHED
    with pytest.raises(KeyError, match="unknown request ids"):
        eng.drain([10 ** 9])
    _assert_pool_recovered(eng)


# ------------------------------------------------------- load shedding


def test_shed_reject_newest(pm):
    eng = ServeEngine([pm], EngineConfig(slots=1, max_seq=32, chunk=4,
                                         page_size=8, queue_cap=2))
    rids = [eng.submit(0, _toks(9 + i, 4), 4) for i in range(4)]
    # slot empty until step: 1st queues... cap 2 → 3rd and 4th shed
    assert eng.status(rids[0]) == "QUEUED"
    assert [eng.status(r) for r in rids[2:]] == [SHED, SHED]
    assert eng.sheds == 2
    out = eng.drain()
    assert out[rids[0]].shape == (4,)
    assert isinstance(out[rids[2]], Outcome)
    _assert_pool_recovered(eng)


def test_shed_reject_latest_deadline_displaces_queued_victim(pm):
    eng = ServeEngine([pm], EngineConfig(slots=1, max_seq=32, chunk=4,
                                         page_size=8, queue_cap=1,
                                         shed_policy="reject-latest-deadline"))
    r_active = eng.submit(0, _toks(20, 4), 12)
    eng.step()                              # r_active takes the slot
    assert eng.status(r_active) == "ACTIVE"
    r_loose = eng.submit(0, _toks(21, 4), 4, deadline=50)
    # queue is full with the loose-deadline request; a tighter-deadline
    # arrival displaces it (the queued one sheds, not the incoming)
    r_tight = eng.submit(0, _toks(22, 4), 4, deadline=30)
    assert eng.status(r_loose) == SHED
    assert eng.status(r_tight) == "QUEUED"
    # an arrival with the LATEST deadline of all sheds itself
    r_latest = eng.submit(0, _toks(23, 4), 4, deadline=99)
    assert eng.status(r_latest) == SHED
    # deadline-less counts as latest of all
    r_none = eng.submit(0, _toks(24, 4), 4)
    assert eng.status(r_none) == SHED
    assert eng.sheds == 3
    out = eng.drain()
    assert out[r_tight].shape == (4,)
    _assert_pool_recovered(eng)


def test_lane_quotas_isolate_models(pm):
    """A per-model quota sheds the hot lane's excess while the other lane
    keeps queueing — one overloaded model cannot starve the rest."""
    eng = ServeEngine([pm, pm], EngineConfig(slots=1, max_seq=32, chunk=4,
                                             page_size=8,
                                             lane_quotas=((0, 1),)))
    r0 = [eng.submit(0, _toks(30 + i, 4), 4) for i in range(3)]
    r1 = [eng.submit(1, _toks(40 + i, 4), 4) for i in range(3)]
    assert [eng.status(r) for r in r0[1:]] == [SHED, SHED]  # lane 0 capped
    assert all(eng.status(r) == "QUEUED" for r in r1)       # lane 1 free
    out = eng.drain()
    assert all(out[r].shape == (4,) for r in r1)
    assert eng.counters()["sheds"] == 2


# ------------------------------------- preemption + recompute-on-resume


def _preempt_schedule(eng):
    """Three page-hungry requests through the oversubscribed initial-
    reservation pool (PREEMPT_ECFG): growth pressure forces preemption."""
    reqs = [(_toks(50 + i, 5 + i), 12) for i in range(3)]
    rids = [eng.submit(0, t, m) for t, m in reqs]
    return reqs, rids, eng.drain()


def test_preempted_request_resumes_bit_identical(pm):
    """THE acceptance property: a preempted-then-resumed request's final
    tokens are exactly its never-preempted solo twin's, and its terminal
    status says it survived preemption."""
    eng = ServeEngine([pm], PREEMPT_ECFG)
    reqs, rids, out = _preempt_schedule(eng)
    assert eng.preemptions > 0, "schedule failed to force a preemption"
    assert eng.resume_recompute_toks > 0
    resumed = 0
    for rid, (t, m) in zip(rids, reqs):
        np.testing.assert_array_equal(out[rid], _solo(pm, t, m))
        if eng.status(rid) == PREEMPTED_RESUMED:
            resumed += 1
        else:
            assert eng.status(rid) == DONE
    assert resumed > 0
    _assert_pool_recovered(eng)


def test_admission_preemption_needs_strictly_later_deadline_victim(pm):
    """Admission-time preemption only displaces a victim whose deadline is
    STRICTLY later than the queue head's — deadline-less traffic keeps the
    seed engine's FIFO wait-for-pages behavior."""
    ecfg = EngineConfig(slots=2, max_seq=32, chunk=4, page_size=4,
                        pages=6, reserve="initial")
    eng = ServeEngine([pm], ecfg)
    t_bg = _toks(60, 12)                    # bucket 16 → 4 initial pages
    r_bg = eng.submit(0, t_bg, 8)           # no deadline → never a victim
    eng.step()                              # of a deadline-less head
    r_head = eng.submit(0, _toks(61, 12), 8)
    eng.step()
    # head can't get 4 pages, and the active request's deadline (None) is
    # not strictly later than the head's (None): nobody preempted
    assert eng.preemptions == 0
    assert eng.status(r_head) == "QUEUED"
    out = eng.drain()
    assert out[r_bg].shape == (8,) and out[r_head].shape == (8,)

    # same shape, but now the background request HAS a late deadline and
    # the head a tight one: admission preempts the victim
    eng2 = ServeEngine([pm], ecfg)
    r_bg2 = eng2.submit(0, t_bg, 8, deadline=200)
    eng2.step()
    r_head2 = eng2.submit(0, _toks(62, 12), 8, deadline=40)
    eng2.step()
    assert eng2.preemptions >= 1
    assert eng2.status(r_bg2) in ("PREEMPTED", "ACTIVE", PREEMPTED_RESUMED)
    out2 = eng2.drain()
    np.testing.assert_array_equal(out2[r_bg2], _solo(pm, t_bg, 8))
    np.testing.assert_array_equal(out2[r_head2],
                                  _solo(pm, _toks(62, 12), 8))
    _assert_pool_recovered(eng2)


def test_zero_decode_retraces_across_cancel_preempt_expiry(pm):
    """Acceptance: cancellation, preemption, and expiry are host-side
    bookkeeping — an identical warm replay of a schedule exercising all
    three adds ZERO TRACE_LOG entries."""
    def schedule():
        eng = ServeEngine([pm], PREEMPT_ECFG)
        reqs = [(_toks(70 + i, 5 + i), 12) for i in range(3)]
        rids = [eng.submit(0, t, m) for t, m in reqs]
        r_dead = eng.submit(0, _toks(75, 4), 16, deadline=3)
        eng.step()
        eng.cancel(rids[1])
        out = eng.drain()
        assert eng.preemptions > 0 and eng.expiries > 0
        return {r: out[r] for r in (rids[0], rids[2])}, out[r_dead].status

    first = schedule()                      # warm every program
    gateway.reset_trace_log()
    n0 = len(gateway.TRACE_LOG)
    second = schedule()                     # identical replay
    assert len(gateway.TRACE_LOG) == n0, \
        f"resilience path retraced: {list(gateway.TRACE_LOG)[n0:]}"
    assert second[1] == EXPIRED
    for (a, b) in zip(first[0].values(), second[0].values()):
        np.testing.assert_array_equal(a, b)


def test_reserve_initial_validation():
    with pytest.raises(ValueError, match="paged-pool feature"):
        ServeEngine([], EngineConfig(page_size=None, reserve="initial"))
    with pytest.raises(ValueError, match="reserve"):
        ServeEngine([], EngineConfig(reserve="eager"))
    with pytest.raises(ValueError, match="shed_policy"):
        ServeEngine([], EngineConfig(shed_policy="drop-all"))


# ------------------------------------------------- gateway integration


D_EMB = 8


def _routed(engine_cfg, clients=1):
    from repro.models import init_params
    params = init_params(jax.random.PRNGKey(0), TINY)
    pool = [PoolModel("m0", TINY, params, 0.1)]
    rcfg = RouterConfig(d_emb=D_EMB, num_models=1, hidden=(16,),
                        dropout=0.0)
    router = routers.make("mlp", rcfg).init(jax.random.PRNGKey(1))
    harvest = HarvestStore(D_EMB, capacity=32, clients=range(clients))
    return RoutedServer(pool, router, harvest=harvest,
                        engine_cfg=engine_cfg)


def test_gateway_expiry_counts_as_backend_failure_for_harvest():
    """Tentpole (a): an EXPIRED request is a backend failure for harvest
    purposes — a zero-score outcome lands against the routed model and the
    failure counters bump, so the FedLoop learns an overloaded backend
    exactly like a crashed one."""
    srv = _routed(EngineConfig(slots=2, max_seq=32, chunk=4, page_size=8))
    x = np.zeros(D_EMB, np.float32)
    rid = srv.submit("three word prompt", max_new_tokens=16, client_id=0,
                     x=x, deadline=1)
    out = srv.drain()
    assert out[rid].status == EXPIRED
    assert srv.expiry_failures == 1 and srv.backend_failures == 1
    data = srv.harvest.buffer(0).as_client_data()
    assert float(data["w"].sum()) == 1
    assert float(data["acc"][0]) == 0.0     # the zero-score outcome
    with pytest.raises(ValueError, match="EXPIRED past its deadline"):
        srv.report_outcome(rid, 1.0)
    # draining again is idempotent — no double-count
    srv.step()
    assert srv.expiry_failures == 1


def test_gateway_cancel_and_shed_drop_pending_evals():
    srv = _routed(EngineConfig(slots=1, max_seq=32, chunk=4, page_size=8,
                               queue_cap=1))
    x = np.zeros(D_EMB, np.float32)
    r0 = srv.submit("aa bb cc", max_new_tokens=8, client_id=0, x=x)
    srv.step()                              # r0 takes the single slot
    r1 = srv.submit("dd ee", max_new_tokens=8, client_id=0, x=x)
    r2 = srv.submit("ff gg hh ii", max_new_tokens=8, client_id=0, x=x)
    assert srv.status(r2) == SHED           # never harvest-registered
    with pytest.raises(ValueError, match="cancelled or shed"):
        srv.routed_model(r2)
    assert srv.cancel(r1) == CANCELLED
    with pytest.raises(ValueError, match="cancelled or shed"):
        srv.report_outcome(r1, 1.0)
    out = srv.drain()
    assert out[r0].shape == (8,)
    srv.report_outcome(r0, 1.0)             # the survivor still reports
    assert len(srv.harvest) == 1
    assert srv.backend_failures == 0        # cancels/sheds aren't failures


# --------------------------------- FedLoop: counters + checkpoint guard


def _loop(engine_cfg):
    srv = _routed(engine_cfg, clients=2)
    fcfg = FedConfig(num_clients=2, participation=1.0, batch_size=8,
                     lr=3e-3)
    cfg = FedLoopConfig(sync_every=10 ** 9, rounds_per_sync=2,
                        min_samples=1)
    return srv, FedLoop(srv, fcfg, key=jax.random.PRNGKey(7), cfg=cfg)


def test_save_with_preempted_or_queued_requests_raises_idle_guard(tmp_path):
    """Satellite: the pinned contract is the idle-engine guard — save()
    with preempted/queued requests in flight raises with a message that
    names them as in-flight (their decode state is recomputable but their
    queue entries are not checkpointed)."""
    srv, loop = _loop(PREEMPT_ECFG)
    x = np.zeros(D_EMB, np.float32)
    for i in range(3):
        srv.submit(f"prompt number {i} padded out", max_new_tokens=12,
                   client_id=i % 2, x=x)
    while srv.engine.preemptions == 0 and srv.engine.busy:
        loop.step()
    assert srv.engine.preemptions > 0       # a resume is pending/queued
    assert srv.engine.busy
    with pytest.raises(ValueError, match="preempted-awaiting-resume"):
        loop.save(tmp_path / "ck.msgpack")
    loop.drain()                            # idle again → save succeeds
    loop.save(tmp_path / "ck.msgpack")


def test_engine_counters_threaded_into_fedloop_history():
    srv, loop = _loop(EngineConfig(slots=2, max_seq=32, chunk=4,
                                   page_size=8, queue_cap=1))
    x = np.zeros(D_EMB, np.float32)
    rids = [srv.submit(f"query {i} words here", max_new_tokens=4,
                       client_id=i % 2, x=x) for i in range(4)]
    sheds = srv.engine.sheds
    assert sheds > 0                        # cap 1 forced shedding
    for r in rids:
        if srv.engine.status(r) != SHED:
            srv.report_outcome(r, 1.0, 0.1)
    loop.drain()
    loop.sync()
    eng_hist = loop.history[-1]["engine"]
    assert eng_hist["sheds"] == sheds
    assert set(eng_hist) >= {"sheds", "preemptions", "expiries", "cancels",
                             "resume_recompute_toks", "queue_depth_hw",
                             "peak_active"}


# ------------------------------------------- seeded chaos determinism


def test_faultplan_engine_draws_are_pure_and_seeded():
    plan = FaultPlan(seed=3, burst_rate=0.3, burst_max=5, storm_rate=0.4,
                     storm_len=4, storm_deadline=6, cancel_rate=0.3,
                     spike_rate=0.2, spike_scale=3)
    a = [(plan.burst_size(t), plan.deadline_storm(t), plan.page_spike(t))
         for t in range(50)]
    b = [(plan.burst_size(t), plan.deadline_storm(t), plan.page_spike(t))
         for t in range(50)]
    assert a == b                           # pure functions of (seed, tags)
    assert any(x[0] == 5 for x in a) and any(x[1] for x in a)
    assert any(x[2] == 3 for x in a)
    other = FaultPlan(seed=4, burst_rate=0.3, burst_max=5, storm_rate=0.4,
                      storm_len=4, storm_deadline=6, cancel_rate=0.3,
                      spike_rate=0.2, spike_scale=3)
    assert [(other.burst_size(t), other.deadline_storm(t))
            for t in range(50)] != [(x[0], x[1]) for x in a]
    # storm windows are contiguous storm_len blocks
    storms = [plan.deadline_storm(t) for t in range(40)]
    for w in range(0, 40, 4):
        assert len(set(storms[w:w + 4])) == 1
    # cancel fates: deterministic per rid, horizon respected
    fated = [r for r in range(64) if plan.cancels_request(r)]
    assert fated and all(1 <= plan.cancel_after(r, 12) <= 12 for r in fated)
    # the zero plan injects nothing
    zero = FaultPlan(seed=3)
    assert all(zero.burst_size(t) == 0 and not zero.deadline_storm(t)
               and zero.page_spike(t) == 1 for t in range(20))
    assert not any(zero.cancels_request(r) for r in range(64))


def test_engine_chaos_schedule_deterministic_and_well_formed():
    plan = FaultPlan(seed=1, burst_rate=0.25, burst_max=3, storm_rate=0.3,
                     storm_len=4, storm_deadline=5, cancel_rate=0.25,
                     spike_rate=0.2, spike_scale=2)
    ev_a = engine_chaos_schedule(plan, ticks=12, max_new=3, vocab=TINY.vocab)
    ev_b = engine_chaos_schedule(plan, ticks=12, max_new=3, vocab=TINY.vocab)
    assert len(ev_a) == len(ev_b) >= 12
    for a, b in zip(ev_a, ev_b):
        assert a["tick"] == b["tick"] and a["max_new"] == b["max_new"]
        assert a["deadline"] == b["deadline"]
        assert a["cancel_after"] == b["cancel_after"]
        np.testing.assert_array_equal(a["toks"], b["toks"])
    assert any(e["deadline"] == 5 for e in ev_a)          # storm arrivals
    assert any(e["cancel_after"] is not None for e in ev_a)
    assert any(e["max_new"] == 6 for e in ev_a)           # spike ticks


def test_terminal_status_vocabulary():
    assert TERMINAL_STATUSES == (DONE, PREEMPTED_RESUMED, EXPIRED,
                                 CANCELLED, SHED)
    assert PREEMPTED_RESUMED == "PREEMPTED-resumed"
