"""Property-based engine invariants: random arrival schedules × prompt
lengths × max_new × page/slot sizes must leave every request bit-identical
to serving it alone, and must return the pool (slots AND pages) to its
initial state after drain() — no leaks, no double-frees, no cross-request
cache contamination.

The schedule checker is plain pytest-parametrized over fixed seeds (always
runs, including in this hypothesis-less container); the hypothesis
section behind the usual ``importorskip`` guard drives the same checker
over drawn schedules (CI runs it with a bounded profile —
``--hypothesis-seed=0`` and small ``max_examples``, see ci.yml).
"""
import jax
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.serve.engine import EngineConfig, ServeEngine, region_len
from repro.serve.gateway import PoolModel, RoutedServer

TINY = ModelConfig(name="tiny-dense-prop", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                   head_dim=16)
MAX_SEQ = 32


@pytest.fixture(scope="module")
def pm():
    from repro.models import init_params
    return PoolModel("tiny", TINY, init_params(jax.random.PRNGKey(0), TINY),
                     0.1)


_solo_cache = {}


def _solo(pm, toks, max_new):
    """Reference: the request served alone on the per-request scan path
    (cached — schedules repeat prompts across examples)."""
    key = (toks.tobytes(), max_new)
    if key not in _solo_cache:
        _solo_cache[key] = RoutedServer._serve_batch(
            pm, np.asarray(toks)[None], max_new)[0]
    return _solo_cache[key]


def _check_schedule(pm, ecfg: EngineConfig, reqs, gaps):
    """Run ``reqs`` = [(toks, max_new)] through a fresh engine, stepping
    ``gaps[i]`` chunks after the i-th submit, then drain and assert the
    two core properties: per-request solo parity and full pool recovery."""
    eng = ServeEngine([pm], ecfg)
    rids = []
    for (toks, max_new), gap in zip(reqs, gaps):
        rids.append(eng.submit(0, toks, max_new))
        for _ in range(gap):
            eng.step()
    out = eng.drain()
    assert sorted(out) == sorted(rids)

    # 1) bit-identical to solo serving, for every request
    for rid, (toks, max_new) in zip(rids, reqs):
        np.testing.assert_array_equal(out[rid], _solo(pm, toks, max_new))

    # 2) the pool returns to its initial state: every slot free, every
    #    page back on the free list exactly once, page table all-trash
    lane = eng._lanes[0]
    assert sorted(lane.free) == list(range(ecfg.slots))
    assert not lane.active and not lane.queue
    if ecfg.page_size:
        assert sorted(lane.pt.free) == \
            list(range(1, ecfg.resolved_pages + 1)), "page leak/double-free"
        assert not lane.pt._held
        assert (lane.pt.table == 0).all()
    assert eng.n_active() == 0 and not eng.busy


def _spec_from_seed(seed: int):
    """One random schedule: engine shape + request mix + interleaving.
    Kept small so the jit trace set stays bounded across examples."""
    rng = np.random.default_rng(seed)
    page_size = int(rng.choice([0, 4, 8, 16]))        # 0 → uniform lane
    slots = int(rng.integers(2, 4))
    chunk = int(rng.choice([2, 4]))
    n_req = int(rng.integers(1, 8))
    reqs, max_need = [], 1
    for _ in range(n_req):
        max_new = int(rng.integers(1, 9))
        steps = -(-max_new // chunk) * chunk
        S = int(rng.integers(1, MAX_SEQ - steps + 1))
        reqs.append((rng.integers(1, TINY.vocab, size=S).astype(np.int32),
                     max_new))
        if page_size:        # the engine's own page accounting, not a copy
            max_need = max(max_need, -(-region_len(S, max_new, chunk)
                                       // page_size))
    # half the paged examples run a TIGHT pool: just enough pages for the
    # hungriest request, so admission stalls on pages (FIFO) mid-schedule
    pages = 0
    if page_size and rng.random() < 0.5:
        pages = int(max_need + rng.integers(0, max_need + 1))
    ecfg = EngineConfig(slots=slots, max_seq=MAX_SEQ, chunk=chunk,
                        page_size=page_size or None, pages=pages)
    gaps = [int(g) for g in rng.integers(0, 3, size=n_req)]
    return ecfg, reqs, gaps


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 5, 8, 13])
def test_random_schedules_solo_parity_and_pool_recovery(pm, seed):
    ecfg, reqs, gaps = _spec_from_seed(seed)
    _check_schedule(pm, ecfg, reqs, gaps)


def test_tight_pool_serialized_long_requests(pm):
    """Pages force near-serial execution of page-hungry requests while
    short ones keep flowing — ordering pressure must not corrupt tokens
    or leak pages."""
    rng = np.random.default_rng(42)
    long_toks = [rng.integers(1, TINY.vocab, size=24).astype(np.int32)
                 for _ in range(3)]
    short_toks = [rng.integers(1, TINY.vocab, size=3).astype(np.int32)
                  for _ in range(3)]
    reqs = [(t, 4) for pair in zip(long_toks, short_toks) for t in pair]
    ecfg = EngineConfig(slots=3, max_seq=MAX_SEQ, chunk=4, page_size=8,
                        pages=5)      # one long (4 pages) + one short (1)
    _check_schedule(pm, ecfg, reqs, gaps=[1, 0, 2, 0, 0, 1])


def test_every_request_alone_equals_itself(pm):
    """Degenerate schedules (single request, every page size) recover the
    pool and match solo — the base case the batched properties build on."""
    toks = np.arange(1, 8, dtype=np.int32)
    for page_size in (None, 4, 16, 32):
        ecfg = EngineConfig(slots=2, max_seq=MAX_SEQ, chunk=4,
                            page_size=page_size)
        _check_schedule(pm, ecfg, [(toks, 5)], gaps=[0])


# ---------------------------------------------------------------------------
# hypothesis-drawn schedules — same importorskip discipline as
# test_properties.py, but scoped to the hypothesis tests only so the
# fixed-seed drivers above still run in hypothesis-less containers
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import HealthCheck, given, settings
except ImportError:                                   # pragma: no cover
    st = None

if st is not None:
    @st.composite
    def schedules(draw):
        page_size = draw(st.sampled_from([0, 4, 8, 16]))
        slots = draw(st.integers(2, 3))
        chunk = draw(st.sampled_from([2, 4]))
        n_req = draw(st.integers(1, 6))
        reqs, max_need = [], 1
        for _ in range(n_req):
            max_new = draw(st.integers(1, 8))
            steps = -(-max_new // chunk) * chunk
            S = draw(st.integers(1, MAX_SEQ - steps))
            toks = np.asarray(draw(st.lists(st.integers(1, TINY.vocab - 1),
                                            min_size=S, max_size=S)),
                              np.int32)
            reqs.append((toks, max_new))
            if page_size:    # the engine's own page accounting, not a copy
                max_need = max(max_need, -(-region_len(S, max_new, chunk)
                                           // page_size))
        pages = 0
        if page_size and draw(st.booleans()):
            pages = max_need + draw(st.integers(0, max_need))
        gaps = [draw(st.integers(0, 2)) for _ in range(n_req)]
        ecfg = EngineConfig(slots=slots, max_seq=MAX_SEQ, chunk=chunk,
                            page_size=page_size or None, pages=pages)
        return ecfg, reqs, gaps

    @given(schedules())
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_schedule_property(pm, spec):
        ecfg, reqs, gaps = spec
        _check_schedule(pm, ecfg, reqs, gaps)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_schedule_property():
        pass
