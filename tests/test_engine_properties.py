"""Property-based engine invariants: random arrival schedules × prompt
lengths × max_new × page/slot sizes must leave every request bit-identical
to serving it alone, and must return the pool (slots AND pages) to its
initial state after drain() — no leaks, no double-frees, no cross-request
cache contamination.

The schedule checker is plain pytest-parametrized over fixed seeds (always
runs, including in this hypothesis-less container); the hypothesis
section behind the usual ``importorskip`` guard drives the same checker
over drawn schedules (CI runs it with a bounded profile —
``--hypothesis-seed=0`` and small ``max_examples``, see ci.yml).
"""
import jax
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.serve.engine import EngineConfig, ServeEngine, region_len
from repro.serve.gateway import PoolModel, RoutedServer

TINY = ModelConfig(name="tiny-dense-prop", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                   head_dim=16)
MAX_SEQ = 32


@pytest.fixture(scope="module")
def pm():
    from repro.models import init_params
    return PoolModel("tiny", TINY, init_params(jax.random.PRNGKey(0), TINY),
                     0.1)


_solo_cache = {}


def _solo(pm, toks, max_new):
    """Reference: the request served alone on the per-request scan path
    (cached — schedules repeat prompts across examples)."""
    key = (toks.tobytes(), max_new)
    if key not in _solo_cache:
        _solo_cache[key] = RoutedServer._serve_batch(
            pm, np.asarray(toks)[None], max_new)[0]
    return _solo_cache[key]


def _check_schedule(pm, ecfg: EngineConfig, reqs, gaps):
    """Run ``reqs`` = [(toks, max_new)] through a fresh engine, stepping
    ``gaps[i]`` chunks after the i-th submit, then drain and assert the
    two core properties: per-request solo parity and full pool recovery."""
    eng = ServeEngine([pm], ecfg)
    rids = []
    for (toks, max_new), gap in zip(reqs, gaps):
        rids.append(eng.submit(0, toks, max_new))
        for _ in range(gap):
            eng.step()
    out = eng.drain()
    assert sorted(out) == sorted(rids)

    # 1) bit-identical to solo serving, for every request
    for rid, (toks, max_new) in zip(rids, reqs):
        np.testing.assert_array_equal(out[rid], _solo(pm, toks, max_new))

    # 2) the pool returns to its initial state: every slot free, every
    #    page back on the free list exactly once, page table all-trash
    lane = eng._lanes[0]
    assert sorted(lane.free) == list(range(ecfg.slots))
    assert not lane.active and not lane.queue
    if ecfg.page_size:
        assert sorted(lane.pt.free) == \
            list(range(1, ecfg.resolved_pages + 1)), "page leak/double-free"
        assert not lane.pt._held
        assert (lane.pt.table == 0).all()
    assert eng.n_active() == 0 and not eng.busy


def _spec_from_seed(seed: int):
    """One random schedule: engine shape + request mix + interleaving.
    Kept small so the jit trace set stays bounded across examples."""
    rng = np.random.default_rng(seed)
    page_size = int(rng.choice([0, 4, 8, 16]))        # 0 → uniform lane
    slots = int(rng.integers(2, 4))
    chunk = int(rng.choice([2, 4]))
    n_req = int(rng.integers(1, 8))
    reqs, max_need = [], 1
    for _ in range(n_req):
        max_new = int(rng.integers(1, 9))
        steps = -(-max_new // chunk) * chunk
        S = int(rng.integers(1, MAX_SEQ - steps + 1))
        reqs.append((rng.integers(1, TINY.vocab, size=S).astype(np.int32),
                     max_new))
        if page_size:        # the engine's own page accounting, not a copy
            max_need = max(max_need, -(-region_len(S, max_new, chunk)
                                       // page_size))
    # half the paged examples run a TIGHT pool: just enough pages for the
    # hungriest request, so admission stalls on pages (FIFO) mid-schedule
    pages = 0
    if page_size and rng.random() < 0.5:
        pages = int(max_need + rng.integers(0, max_need + 1))
    ecfg = EngineConfig(slots=slots, max_seq=MAX_SEQ, chunk=chunk,
                        page_size=page_size or None, pages=pages)
    gaps = [int(g) for g in rng.integers(0, 3, size=n_req)]
    return ecfg, reqs, gaps


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 5, 8, 13])
def test_random_schedules_solo_parity_and_pool_recovery(pm, seed):
    ecfg, reqs, gaps = _spec_from_seed(seed)
    _check_schedule(pm, ecfg, reqs, gaps)


def test_tight_pool_serialized_long_requests(pm):
    """Pages force near-serial execution of page-hungry requests while
    short ones keep flowing — ordering pressure must not corrupt tokens
    or leak pages."""
    rng = np.random.default_rng(42)
    long_toks = [rng.integers(1, TINY.vocab, size=24).astype(np.int32)
                 for _ in range(3)]
    short_toks = [rng.integers(1, TINY.vocab, size=3).astype(np.int32)
                  for _ in range(3)]
    reqs = [(t, 4) for pair in zip(long_toks, short_toks) for t in pair]
    ecfg = EngineConfig(slots=3, max_seq=MAX_SEQ, chunk=4, page_size=8,
                        pages=5)      # one long (4 pages) + one short (1)
    _check_schedule(pm, ecfg, reqs, gaps=[1, 0, 2, 0, 0, 1])


def test_every_request_alone_equals_itself(pm):
    """Degenerate schedules (single request, every page size) recover the
    pool and match solo — the base case the batched properties build on."""
    toks = np.arange(1, 8, dtype=np.int32)
    for page_size in (None, 4, 16, 32):
        ecfg = EngineConfig(slots=2, max_seq=MAX_SEQ, chunk=4,
                            page_size=page_size)
        _check_schedule(pm, ecfg, [(toks, 5)], gaps=[0])


# ---------------------------------------------------------------------------
# seeded chaos property (PR 8): drive a FaultPlan-generated overload
# schedule — bursts, deadline storms, cancel storms, page-pressure spikes —
# through a fresh engine and assert the resilience invariants: every rid
# reaches a typed terminal status (no leaks, no hangs), every SURVIVING
# request is bit-identical to solo serving, every terminated request's
# partial tokens are an exact solo prefix, and the pool (slots AND pages)
# recovers fully.
# ---------------------------------------------------------------------------

from repro.fed.faults import FaultPlan                           # noqa: E402
from repro.fed.scenarios import engine_chaos_schedule            # noqa: E402
from repro.serve.engine import (DONE, PREEMPTED_RESUMED,         # noqa: E402
                                TERMINAL_STATUSES, Outcome)

#: chaos engine shapes: uniform, paged-lifetime, and paged-initial (the
#: preempting mode). Page math stays inside max_seq=32 for spiked
#: max_new=9: region next_pow2(6 + 12) = 32 → ≤ 8 pages of 4.
CHAOS_ECFGS = [
    EngineConfig(slots=2, max_seq=MAX_SEQ, chunk=4),
    EngineConfig(slots=2, max_seq=MAX_SEQ, chunk=4, page_size=4, pages=8),
    EngineConfig(slots=3, max_seq=MAX_SEQ, chunk=4, page_size=4, pages=8,
                 reserve="initial"),
    # speculative shapes (ISSUE 9 satellite): cancel/expire/preempt land
    # BETWEEN draft/verify rounds with uncommitted drafts physically
    # written into both KV pools — the checker's prefix assertion pins
    # that those drafts never surface in a terminal partial
    EngineConfig(slots=2, max_seq=MAX_SEQ, chunk=4, spec_k=3),
    EngineConfig(slots=3, max_seq=MAX_SEQ, chunk=4, page_size=4, pages=8,
                 reserve="initial", spec_k=2),
]


def _chaos_plan(seed: int) -> FaultPlan:
    return FaultPlan(seed=seed, burst_rate=0.3, burst_max=2, storm_rate=0.4,
                     storm_len=3, storm_deadline=3, cancel_rate=0.3,
                     spike_rate=0.25, spike_scale=3)


def _check_chaos(pm, plan: FaultPlan, ecfg: EngineConfig, ticks: int = 8):
    events = engine_chaos_schedule(plan, ticks=ticks, prompt_lens=(2, 6),
                                   max_new=3, vocab=TINY.vocab)
    by_tick = {}
    for e in events:
        by_tick.setdefault(e["tick"], []).append(e)
    eng = ServeEngine([pm], ecfg)
    meta, cancel_at, out = {}, {}, {}
    t, max_tick = 0, max(by_tick)
    while t <= max_tick or eng.busy:
        for e in by_tick.get(t, ()):
            rid = eng.submit(0, e["toks"], e["max_new"],
                             deadline=e["deadline"])
            meta[rid] = (e["toks"], e["max_new"])
            if e["cancel_after"] is not None:
                cancel_at.setdefault(t + e["cancel_after"], []).append(rid)
        for rid in cancel_at.pop(t, ()):
            eng.cancel(rid)          # terminal rids: deterministic no-op
        out.update(eng.step())
        t += 1
    out.update(eng.drain())

    # every submitted rid reached exactly one typed terminal — no leaks
    assert sorted(out) == sorted(meta)
    for rid, (toks, max_new) in meta.items():
        status = eng.status(rid)
        assert status in TERMINAL_STATUSES
        payload = out[rid]
        if isinstance(payload, Outcome):
            assert payload.status == status
            if payload.tokens is not None:   # terminated mid-decode:
                np.testing.assert_array_equal(  # an exact solo prefix
                    payload.tokens,
                    _solo(pm, toks, max_new)[:len(payload.tokens)])
        else:                                # survivor: bit-identical,
            assert status in (DONE, PREEMPTED_RESUMED)  # even if preempted
            np.testing.assert_array_equal(payload,
                                          _solo(pm, toks, max_new))

    # the pool recovers: slots, pages, carry all back to the initial state
    lane = eng._lanes[0]
    assert sorted(lane.free) == list(range(ecfg.slots))
    assert not lane.active and not lane.queue
    if ecfg.page_size:
        assert sorted(lane.pt.free) == \
            list(range(1, ecfg.resolved_pages + 1)), "page leak under chaos"
        assert not lane.pt._held and (lane.pt.table == 0).all()
    assert not eng.busy and not eng._events


@pytest.mark.parametrize("seed", [0, 1, 2, 7])
@pytest.mark.parametrize("shape", range(len(CHAOS_ECFGS)))
def test_chaos_schedules_recover_and_survivors_match_solo(pm, seed, shape):
    _check_chaos(pm, _chaos_plan(seed), CHAOS_ECFGS[shape])


# ---------------------------------------------------------------------------
# hypothesis-drawn schedules — same importorskip discipline as
# test_properties.py, but scoped to the hypothesis tests only so the
# fixed-seed drivers above still run in hypothesis-less containers
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import HealthCheck, given, settings
except ImportError:                                   # pragma: no cover
    st = None

if st is not None:
    @st.composite
    def schedules(draw):
        page_size = draw(st.sampled_from([0, 4, 8, 16]))
        slots = draw(st.integers(2, 3))
        chunk = draw(st.sampled_from([2, 4]))
        n_req = draw(st.integers(1, 6))
        reqs, max_need = [], 1
        for _ in range(n_req):
            max_new = draw(st.integers(1, 8))
            steps = -(-max_new // chunk) * chunk
            S = draw(st.integers(1, MAX_SEQ - steps))
            toks = np.asarray(draw(st.lists(st.integers(1, TINY.vocab - 1),
                                            min_size=S, max_size=S)),
                              np.int32)
            reqs.append((toks, max_new))
            if page_size:    # the engine's own page accounting, not a copy
                max_need = max(max_need, -(-region_len(S, max_new, chunk)
                                           // page_size))
        pages = 0
        if page_size and draw(st.booleans()):
            pages = max_need + draw(st.integers(0, max_need))
        gaps = [draw(st.integers(0, 2)) for _ in range(n_req)]
        ecfg = EngineConfig(slots=slots, max_seq=MAX_SEQ, chunk=chunk,
                            page_size=page_size or None, pages=pages)
        return ecfg, reqs, gaps

    @given(schedules())
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_schedule_property(pm, spec):
        ecfg, reqs, gaps = spec
        _check_schedule(pm, ecfg, reqs, gaps)

    @given(seed=st.integers(0, 2 ** 16), shape=st.integers(0, 2),
           storm_rate=st.sampled_from([0.0, 0.4, 1.0]),
           cancel_rate=st.sampled_from([0.0, 0.3, 0.8]))
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_chaos_property(pm, seed, shape, storm_rate, cancel_rate):
        plan = FaultPlan(seed=seed, burst_rate=0.3, burst_max=2,
                         storm_rate=storm_rate, storm_len=3,
                         storm_deadline=3, cancel_rate=cancel_rate,
                         spike_rate=0.25, spike_scale=3)
        _check_chaos(pm, plan, CHAOS_ECFGS[shape])
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_schedule_property():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_chaos_property():
        pass
