"""Property-based harvest invariants: any append sequence against an
``EvalBuffer`` ring must keep exactly the newest ``capacity`` entries in
chronological order at constant memory, and round-trip its checkpoint
state verbatim; ``HarvestStore`` must honour the empty-client semantics of
``as_federated_data`` on both the padded and unpadded paths and keep live
memory O(max_clients) under population-scale churned traffic.

Fixed-seed drivers always run (hypothesis-less containers included); the
hypothesis section behind the usual ``importorskip`` discipline draws the
same checker over random append sequences (CI bounds it via
``--hypothesis-seed=0``, see ci.yml)."""
import numpy as np
import pytest

from repro.fed.harvest import EvalBuffer, HarvestStore
from repro.fed.scenarios import PowerLawScenario

D_EMB = 4


def _check_ring(capacity: int, seq):
    """Append ``seq`` (a list of floats used as both payload and tag) and
    assert the ring properties: bounded length, constant bytes, newest
    ``capacity`` entries surviving in chronological order, and an exact
    state()/load_state() round-trip."""
    buf = EvalBuffer(D_EMB, capacity=capacity)
    bytes0 = buf.nbytes
    for i, v in enumerate(seq):
        buf.append(np.full(D_EMB, v, np.float32), i % 3, float(i % 2), v)
        assert len(buf) == min(i + 1, capacity)
        assert buf.nbytes == bytes0
    assert buf.total_seen == len(seq)

    want = seq[-min(len(seq), capacity):]       # survivors, oldest→newest
    data = buf.as_client_data()
    n = len(want)
    np.testing.assert_array_equal(data["cost"][:n],
                                  np.asarray(want, np.float32))
    np.testing.assert_array_equal(data["x"][:n, 0],
                                  np.asarray(want, np.float32))
    assert float(data["w"].sum()) == n

    # padded view: same rows, zero-weight tail
    padded = buf.as_client_data(pad_to=capacity + 3)
    np.testing.assert_array_equal(padded["cost"][:n], data["cost"][:n])
    assert float(padded["w"].sum()) == n
    np.testing.assert_array_equal(padded["w"][n:], 0.0)

    # checkpoint round-trip reproduces the ring VERBATIM (write head
    # included: appending after restore equals appending without the trip)
    clone = EvalBuffer(D_EMB, capacity=capacity)
    clone.load_state(buf.state())
    for b in (buf, clone):
        b.append(np.full(D_EMB, -1.0, np.float32), 0, 1.0, -1.0)
    np.testing.assert_array_equal(buf.as_client_data()["cost"],
                                  clone.as_client_data()["cost"])
    assert buf.total_seen == clone.total_seen


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 5, 8])
def test_ring_wraparound_fixed_seeds(seed):
    rng = np.random.default_rng(seed)
    capacity = int(rng.integers(1, 12))
    n = int(rng.integers(0, 4 * capacity + 1))
    seq = [float(v) for v in rng.integers(0, 1000, size=n)]
    _check_ring(capacity, seq)


def test_ring_exact_boundaries():
    """The off-by-one hot spots: exactly full, one over, one lap, and one
    past a lap."""
    for cap in (1, 2, 5):
        for n in (cap - 1, cap, cap + 1, 2 * cap, 2 * cap + 1):
            _check_ring(cap, [float(i) for i in range(max(n, 0))])


def test_load_state_shape_mismatch_raises():
    buf = EvalBuffer(D_EMB, capacity=8)
    other = EvalBuffer(D_EMB, capacity=4)
    with pytest.raises(ValueError, match="ring shape"):
        buf.load_state(other.state())


# ------------------------------------------- empty clients in the stack

def test_unpadded_stack_skips_empty_clients():
    """Unpadded path: a freshly registered, never-written client
    contributes NO row — it cannot dilute the federated average."""
    store = HarvestStore(D_EMB, capacity=8, clients=range(3))
    store.record(0, np.ones(D_EMB), 0, 1.0, 0.1)
    store.record(2, np.ones(D_EMB), 1, 0.0, 0.2)
    data = store.as_federated_data()
    assert data["x"].shape[0] == 2              # client 1 skipped
    np.testing.assert_array_equal(np.asarray(data["w"]).sum(axis=1),
                                  [1.0, 1.0])


def test_padded_stack_keeps_empty_clients_zero_weighted():
    """Padded path: the empty client stays as an all-zero row with w == 0
    — static client dimension, zero aggregation weight."""
    store = HarvestStore(D_EMB, capacity=8, clients=range(3))
    store.record(0, np.ones(D_EMB), 0, 1.0, 0.1)
    store.record(2, np.ones(D_EMB), 1, 0.0, 0.2)
    data = store.as_federated_data(pad_to=8)
    assert data["x"].shape == (3, 8, D_EMB)
    np.testing.assert_array_equal(np.asarray(data["w"]).sum(axis=1),
                                  [1.0, 0.0, 1.0])
    np.testing.assert_array_equal(np.asarray(data["x"])[1], 0.0)


def test_all_empty_store_raises():
    store = HarvestStore(D_EMB, capacity=8, clients=range(3))
    with pytest.raises(ValueError, match="no harvested samples"):
        store.as_federated_data()
    with pytest.raises(ValueError, match="no harvested samples"):
        store.as_federated_data(pad_to=8)


def test_cohort_subset_and_missing_ids():
    store = HarvestStore(D_EMB, capacity=8, clients=range(4))
    for c in range(4):
        store.record(c, np.full(D_EMB, c, np.float32), 0, 1.0, 0.1)
    sub = store.as_federated_data(pad_to=8, client_ids=[3, 1])
    assert sub["x"].shape[0] == 2               # sorted ids: [1, 3]
    np.testing.assert_array_equal(np.asarray(sub["x"])[:, 0, 0], [1.0, 3.0])
    with pytest.raises(ValueError, match="no live buffer"):
        store.as_federated_data(client_ids=[1, 99])


# --------------------------------------------- O(cohort) LRU eviction

def test_max_clients_lru_eviction():
    """The least-recently-WRITTEN client is evicted, not the oldest-
    registered: touching a client re-warms it."""
    store = HarvestStore(D_EMB, capacity=4, max_clients=2)
    store.record(0, np.zeros(D_EMB), 0, 1.0, 0.1)
    store.record(1, np.zeros(D_EMB), 0, 1.0, 0.1)
    store.record(0, np.zeros(D_EMB), 0, 1.0, 0.1)   # re-warm 0
    store.record(2, np.zeros(D_EMB), 0, 1.0, 0.1)   # evicts 1, not 0
    assert store.client_ids() == [0, 2]
    assert store.evicted_clients == 1


def test_power_law_traffic_keeps_harvest_o_cohort():
    """1k+ clients with Zipf traffic and churn: live buffers and bytes
    stay bounded by max_clients while arrivals span the population."""
    sc = PowerLawScenario(1200, zipf_a=1.1, churn=0.2,
                          queries_per_phase=300, phases=3, seed=0)
    np.testing.assert_array_equal(sc.events(1),
                                  PowerLawScenario(
                                      1200, zipf_a=1.1, churn=0.2,
                                      queries_per_phase=300, phases=3,
                                      seed=0).events(1))
    assert 1 < sc.coverage_clients(0.9) < 1200
    warm = sc.coverage_clients(0.5)     # a tight cohort-sized working set
    store = HarvestStore(D_EMB, capacity=8, max_clients=warm)
    seen = set()
    per_buf = EvalBuffer(D_EMB, capacity=8).nbytes
    for phase in range(3):
        for c in sc.events(phase):
            store.record(int(c), np.zeros(D_EMB, np.float32), 0, 1.0, 0.1)
            seen.add(int(c))
            assert store.nbytes <= warm * per_buf
    assert len(store.client_ids()) <= warm
    # churn moved the head: later phases surface clients phase 0 never saw
    assert len(seen) > len(store.client_ids())


def test_power_law_head_dominates_and_churns():
    sc = PowerLawScenario(800, zipf_a=1.2, churn=0.25,
                          queries_per_phase=400, phases=3, seed=1)
    ev = sc.events(0)
    assert len(np.unique(ev)) < len(ev) // 2     # Zipf concentration
    p0, p2 = sc.popularity(0), sc.popularity(2)
    assert abs(p0.sum() - 1.0) < 1e-12 and abs(p2.sum() - 1.0) < 1e-12
    assert not np.array_equal(np.argsort(p0), np.argsort(p2))  # churned


def test_power_law_validation():
    with pytest.raises(ValueError, match="n_clients"):
        PowerLawScenario(1)
    with pytest.raises(ValueError, match="zipf_a"):
        PowerLawScenario(10, zipf_a=0.0)
    with pytest.raises(ValueError, match="churn"):
        PowerLawScenario(10, churn=1.5)
    with pytest.raises(ValueError, match="coverage"):
        PowerLawScenario(10).coverage_clients(0.0)


# ---------------------------------------------------------------------------
# hypothesis-drawn append sequences — same importorskip discipline as
# test_engine_properties.py
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                                   # pragma: no cover
    st = None

if st is not None:
    @given(st.integers(1, 10),
           st.lists(st.floats(-1e3, 1e3, allow_nan=False), max_size=50))
    @settings(max_examples=50, deadline=None)
    def test_ring_property(capacity, seq):
        _check_ring(capacity, [float(v) for v in seq])
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_ring_property():
        pass
