"""Online federation runtime (repro.fed): harvest-from-serving, FedLoop
sync ≡ offline fit bit-for-bit, router hot-swap with zero retraces under
live traffic, mid-run model onboarding, bounded harvest memory, and §6.4
personalization composed with a FedLoop-produced router."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import routers
from repro.config import FedConfig, ModelConfig, RouterConfig
from repro.fed.harvest import EvalBuffer, HarvestStore
from repro.fed.loop import FedLoop, FedLoopConfig, personalize_client
from repro.models import init_params
from repro.serve import gateway
from repro.serve.engine import EngineConfig
from repro.serve.gateway import PoolModel, RoutedServer

TINY = ModelConfig(name="fedloop-tiny", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                   head_dim=16, dtype="float32")
D_EMB = 8
N_CLIENTS = 3
CAP = 32
RCFG = RouterConfig(d_emb=D_EMB, num_models=2, hidden=(16, 16), dropout=0.0,
                    k_local=3, k_global=4, mf_rank=4)
FCFG = FedConfig(num_clients=N_CLIENTS, participation=1.0, batch_size=16,
                 lr=3e-3)


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def make_server(family: str = "mlp"):
    params = init_params(jax.random.PRNGKey(0), TINY)
    pool = [PoolModel("m0", TINY, params, 0.1),
            PoolModel("m1", TINY, params, 0.5)]
    router = routers.make(family, RCFG).init(jax.random.PRNGKey(1))
    harvest = HarvestStore(D_EMB, capacity=CAP, clients=range(N_CLIENTS))
    return RoutedServer(pool, router, harvest=harvest,
                        engine_cfg=EngineConfig(slots=4, max_seq=32,
                                                chunk=4, page_size=8))


def drive_traffic(srv, loop, n, *, seed=0, max_new=4):
    """Deterministic routed traffic: submit, read the choice, report a
    deterministic outcome, advance the loop one chunk."""
    rng = np.random.default_rng(seed)
    for i in range(n):
        c = i % N_CLIENTS
        x = rng.normal(size=(D_EMB,)).astype(np.float32)
        rid = srv.submit("three word prompt", lam=0.5,
                         max_new_tokens=max_new, client_id=c, x=x)
        m = srv.routed_model(rid)
        srv.report_outcome(rid, float(rng.random() < 0.4 + 0.3 * m),
                           0.1 + 0.4 * m)
        loop.step()
    loop.drain()


@pytest.fixture()
def loop_setup():
    srv = make_server()
    loop = FedLoop(srv, FCFG, key=jax.random.PRNGKey(7),
                   cfg=FedLoopConfig(sync_every=10 ** 9, rounds_per_sync=3,
                                     min_samples=1))
    return srv, loop


# ----------------------------------------------------------------- harvest

def test_harvest_populates_client_buffers(loop_setup):
    srv, loop = loop_setup
    drive_traffic(srv, loop, 9)
    h = srv.harvest
    assert len(h) == 9 and h.client_ids() == [0, 1, 2]
    for c in range(N_CLIENTS):
        buf = h.buffer(c)
        assert len(buf) == 3
        data = buf.as_client_data()
        assert float(data["w"].sum()) == 3
        # clients only ever observe the models they were routed to
        assert set(np.unique(data["m"][:3]).tolist()) <= {0, 1}
    stacked = h.as_federated_data(pad_to=CAP)
    assert stacked["x"].shape == (N_CLIENTS, CAP, D_EMB)
    assert float(jnp.sum(stacked["w"])) == 9


def test_eval_buffer_is_bounded_ring():
    """Deque-style cap: sustained appends never grow host memory; the
    survivors are the newest entries in chronological order."""
    buf = EvalBuffer(D_EMB, capacity=8)
    bytes0 = buf.nbytes
    for i in range(100):
        buf.append(np.full(D_EMB, i, np.float32), i % 2, 1.0, float(i))
    assert len(buf) == 8 and buf.total_seen == 100
    assert buf.nbytes == bytes0
    data = buf.as_client_data()
    np.testing.assert_array_equal(data["cost"], np.arange(92, 100))


def test_harvest_memory_bounded_under_sustained_traffic(loop_setup,
                                                        monkeypatch):
    """Serve far more traffic than the buffers hold: harvest bytes stay
    flat and the pending-outcome map stays capped even when outcomes are
    never reported."""
    srv, loop = loop_setup
    drive_traffic(srv, loop, 6)
    bytes0 = srv.harvest.nbytes
    drive_traffic(srv, loop, 3 * CAP + 9, seed=1)
    assert srv.harvest.nbytes == bytes0
    for c in range(N_CLIENTS):
        assert len(srv.harvest.buffer(c)) == CAP
    monkeypatch.setattr(gateway, "PENDING_EVAL_CAP", 5)
    for i in range(12):  # submit without ever reporting an outcome
        srv.submit("three word prompt", lam=0.5, max_new_tokens=4,
                   client_id=0, x=np.zeros(D_EMB, np.float32))
    assert len(srv._pending_evals) <= 5
    srv.drain()


def test_report_outcome_unknown_rid_raises(loop_setup):
    """Unknown / already-reported / evicted rids raise a ValueError that
    names the rid and says why it has no pending evaluation."""
    srv, loop = loop_setup
    with pytest.raises(ValueError, match="12345.*never harvest-registered"):
        srv.report_outcome(12345, 1.0)
    with pytest.raises(ValueError, match="12345.*never harvest-registered"):
        srv.routed_model(12345)
    # double-report: the second call says the outcome already arrived
    rid = srv.submit("three word prompt", lam=0.5, max_new_tokens=4,
                     client_id=0, x=np.zeros(D_EMB, np.float32))
    srv.report_outcome(rid, 1.0)
    with pytest.raises(ValueError, match=f"{rid}.*already reported"):
        srv.report_outcome(rid, 1.0)
    srv.drain()


def test_unknown_rid_names_pending_cap_eviction(loop_setup, monkeypatch):
    """A rid pushed out by PENDING_EVAL_CAP gets an error naming the cap,
    not a generic unknown-rid message."""
    srv, _ = loop_setup
    monkeypatch.setattr(gateway, "PENDING_EVAL_CAP", 3)
    rids = [srv.submit("three word prompt", lam=0.5, max_new_tokens=4,
                       client_id=0, x=np.zeros(D_EMB, np.float32))
            for _ in range(6)]
    with pytest.raises(ValueError, match="evicted by the pending-eval cap"):
        srv.report_outcome(rids[0], 1.0)
    with pytest.raises(ValueError, match="evicted by the pending-eval cap"):
        srv.routed_model(rids[1])
    srv.report_outcome(rids[-1], 1.0)          # survivors still report fine
    srv.drain()


# ----------------------------------------------- sync ≡ offline fit exactly

def test_fedloop_sync_reproduces_offline_fit(loop_setup):
    """A FedLoop sync over deterministically harvested buffers must be
    EXACTLY routers.fit_federated on the same stacked data, same init,
    same key — the online path adds scheduling, not math."""
    srv, loop = loop_setup
    drive_traffic(srv, loop, 15)
    data = srv.harvest.as_federated_data(pad_to=CAP)
    pre = routers.make("mlp", RCFG, state=srv.router.state)
    v0 = srv.router_version
    loop.sync(key=jax.random.PRNGKey(42))
    offline, _ = routers.fit_federated(pre, data, FCFG,
                                       key=jax.random.PRNGKey(42),
                                       rounds=loop.cfg.rounds_per_sync)
    _trees_equal(srv.router.state, offline.state)
    assert srv.router_version == v0 + 1
    assert loop.history[-1]["version"] == srv.router_version


def test_sync_with_aggregator_reproduces_offline(loop_setup):
    """The loop's aggregator knob reaches the fit: secure-agg syncs equal
    the offline secure-agg fit bit-for-bit."""
    from repro.fed.aggregators import SecureAggAggregator
    srv, _ = loop_setup
    agg = SecureAggAggregator(scale=5.0)
    loop = FedLoop(srv, FCFG, key=jax.random.PRNGKey(7), aggregator=agg,
                   cfg=FedLoopConfig(sync_every=10 ** 9, rounds_per_sync=2,
                                     min_samples=1))
    drive_traffic(srv, loop, 9)
    data = srv.harvest.as_federated_data(pad_to=CAP)
    pre = routers.make("mlp", RCFG, state=srv.router.state)
    loop.sync(key=jax.random.PRNGKey(5))
    offline, _ = routers.fit_federated(pre, data, FCFG,
                                       key=jax.random.PRNGKey(5), rounds=2,
                                       aggregator=agg)
    _trees_equal(srv.router.state, offline.state)


def test_empty_harvest_never_syncs(loop_setup):
    srv, loop = loop_setup
    assert loop.maybe_sync() is None           # min_samples gate
    with pytest.raises(ValueError, match="empty harvest"):
        loop.sync()


# ------------------------------------------------------- hot swap: retraces

def test_hot_swap_zero_retraces_under_traffic(loop_setup):
    """Swapping refit router state under live traffic must not retrace the
    route program or any engine decode/prefill program (same-shape state
    enters the cached jit as a traced argument) — TRACE_LOG-pinned."""
    srv, loop = loop_setup
    drive_traffic(srv, loop, 8)                # warm every program + sync fit
    loop.sync(key=jax.random.PRNGKey(3))
    drive_traffic(srv, loop, 4, seed=2)        # warm post-swap shapes too
    gateway.reset_trace_log()
    n0 = len(gateway.TRACE_LOG)
    v0 = srv.router_version
    loop.sync(key=jax.random.PRNGKey(4))       # hot swap #2
    drive_traffic(srv, loop, 6, seed=3)        # same buckets, new router
    loop.sync(key=jax.random.PRNGKey(5))       # and once more mid-stream
    drive_traffic(srv, loop, 6, seed=4)
    assert len(gateway.TRACE_LOG) == n0, \
        f"hot swap retraced: {list(gateway.TRACE_LOG)[n0:]}"
    assert srv.router_version == v0 + 2


@pytest.mark.parametrize("family", ["mf", "elo"])
def test_hot_swap_zero_retraces_zoo_families(family):
    """The new zoo families honor the same hot-swap contract as mlp: every
    fit of a given (config, M) produces a state with identical pytree
    structure and shapes, so FedLoop syncs swap under the cached route jit
    with ZERO retraces — TRACE_LOG-pinned. (mf cold-starts from random
    factors, elo from its jittered prior state.)"""
    srv = make_server(family)
    loop = FedLoop(srv, FCFG, key=jax.random.PRNGKey(7),
                   cfg=FedLoopConfig(sync_every=10 ** 9, rounds_per_sync=2,
                                     min_samples=1))
    drive_traffic(srv, loop, 9)                # warm every program
    loop.sync(key=jax.random.PRNGKey(3))       # first fit replaces cold start
    drive_traffic(srv, loop, 4, seed=2)        # warm post-swap shapes too
    gateway.reset_trace_log()
    n0 = len(gateway.TRACE_LOG)
    v0 = srv.router_version
    loop.sync(key=jax.random.PRNGKey(4))
    drive_traffic(srv, loop, 6, seed=3)
    loop.sync(key=jax.random.PRNGKey(5))
    drive_traffic(srv, loop, 6, seed=4)
    assert len(gateway.TRACE_LOG) == n0, \
        f"{family} hot swap retraced: {list(gateway.TRACE_LOG)[n0:]}"
    assert srv.router_version == v0 + 2


def test_swap_preserves_in_flight_decode(loop_setup):
    """A request already decoding when the router is swapped finishes with
    the same tokens as without any swap (the swap touches routing state
    only, never the engine's KV pools or programs)."""
    srv, loop = loop_setup
    drive_traffic(srv, loop, 6)                # warm + harvest
    toks = np.arange(1, 6, dtype=np.int32)
    base_rid = srv.engine.submit(0, toks, 8)
    baseline = srv.engine.drain([base_rid])[base_rid]

    rid = srv.engine.submit(0, toks, 8)
    srv.step()                                 # half the chunks decoded
    loop.sync(key=jax.random.PRNGKey(9))       # swap mid-decode
    out = srv.engine.drain([rid])[rid]
    np.testing.assert_array_equal(out, baseline)


def test_swap_rejects_structural_change(loop_setup):
    srv, _ = loop_setup
    bigger = routers.make("mlp", RCFG, num_models=3).init(
        jax.random.PRNGKey(2))
    with pytest.raises(ValueError, match="not a hot swap"):
        srv.swap_router_state(bigger.state)


# ------------------------------------------------------------- onboarding

def test_onboard_model_mid_run(loop_setup):
    """A new PoolModel joins mid-run: head columns trained on calibration
    evals, pool extended, router expanded (one route retrace is expected —
    the head shape changed), and the loop keeps syncing with post-onboard
    harvest that covers the new model."""
    srv, loop = loop_setup
    drive_traffic(srv, loop, 9)
    rng = np.random.default_rng(5)
    calib = {"x": rng.normal(size=(40, D_EMB)).astype(np.float32),
             "m": np.full(40, 2, np.int32),
             "acc": (rng.random(40) < 0.8).astype(np.float32),
             "cost": np.full(40, 0.05, np.float32),
             "w": np.ones(40, np.float32)}
    pm = PoolModel("m2", TINY, srv.pool[0].params, 0.05)
    loop.onboard_model(pm, calib, key=jax.random.PRNGKey(11), steps=5)
    assert len(srv.pool) == 3 and srv.router.num_models == 3
    assert srv.engine.pool is srv.pool         # engine sees the new model

    # the cheap new model with high calibration accuracy should now win
    # cost-sensitive routing for at least some queries
    x = rng.normal(size=(16, D_EMB)).astype(np.float32)
    choice = srv._route_x(x, lam=2.0)
    assert choice.shape == (16,) and choice.max() <= 2

    # serving + harvesting + syncing continue across the expansion
    drive_traffic(srv, loop, 9, seed=6)
    loop.sync(key=jax.random.PRNGKey(12))
    assert srv.router.num_models == 3


def test_add_model_validates_router_m(loop_setup):
    srv, _ = loop_setup
    pm = PoolModel("m2", TINY, srv.pool[0].params, 0.05)
    with pytest.raises(ValueError, match="onboard the router first"):
        srv.add_model(pm, srv.router)          # still M=2


# -------------------------------------------------------- personalization

def test_personalization_composes_with_fedloop_router(loop_setup):
    """§6.4 over the runtime: mix the FedLoop-produced federated router
    with a client-local fit on that client's own EvalBuffer."""
    srv, loop = loop_setup
    drive_traffic(srv, loop, 18)
    loop.sync(key=jax.random.PRNGKey(21))
    data_0 = srv.harvest.buffer(0).as_client_data()
    local, _ = routers.fit_local(routers.make("mlp", RCFG), data_0, FCFG,
                                 key=jax.random.PRNGKey(22), steps=30)
    mixed_fn, (w_a, w_c) = personalize_client(srv.router, local, data_0)
    assert w_a.shape == (2,) and w_c.shape == (2,)
    assert np.all((np.asarray(w_a) >= 0) & (np.asarray(w_a) <= 1))
    x = np.asarray(data_0["x"][:5])
    A, C = mixed_fn(x)
    assert A.shape == (5, 2) and C.shape == (5, 2)
    Af, Cf = srv.router.predict(x)
    Al, Cl = local.predict(x)
    # the mixture lies between the two estimators, per model
    lo = np.minimum(np.asarray(Af), np.asarray(Al))
    hi = np.maximum(np.asarray(Af), np.asarray(Al))
    assert np.all(np.asarray(A) >= lo - 1e-6)
    assert np.all(np.asarray(A) <= hi + 1e-6)
    # a model this client never logged mixes entirely from the fed side
    unlogged = sorted({0, 1} - set(np.asarray(data_0["m"])
                                   [np.asarray(data_0["w"]) > 0].tolist()))
    for m in unlogged:
        assert float(w_a[m]) == 0.0


# ------------------------------------------------------------ end-to-end

def test_online_scenario_smoke():
    """Tiny end-to-end drift scenario with mid-run onboarding: the full
    serve → harvest → federate → hot-swap loop runs deterministically and
    reports sane metrics (the online-vs-frozen AUC floor itself is
    enforced on the bigger CI bench, BENCH_fedloop.smoke.json)."""
    from repro.fed.scenarios import ScenarioConfig, run_online_vs_frozen
    cfg = ScenarioConfig(n_clients=4, n_tasks=4, n_models=2, d_emb=16,
                         n_queries=400, queries_per_phase=24, phases=2,
                         test_queries=24, seed=0)
    m = run_online_vs_frozen(cfg, onboard_phase=1, local_steps=60,
                             capacity=64)
    assert len(m["auc_online"]) == 2 and len(m["auc_frozen_local"]) == 2
    assert all(0.0 <= a <= 1.0 for a in m["auc_online"])
    assert all(0.0 <= a <= 1.0 for a in m["auc_frozen_local"])
    assert m["syncs"] >= 1
    assert m["num_models_final"] == 3          # the onboarded model joined
    assert m["harvested_samples"] > 0
