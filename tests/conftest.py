import os

# Tests run on the single real CPU device (the dry-run forces 512 devices in
# its own process — never globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
