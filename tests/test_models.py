"""Model-substrate correctness: decode ≡ forward, caches, MoE modes, SSD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (decode_step, forward, init_decode_cache,
                          init_params)
from repro.models.moe import init_moe, moe_forward
from repro.serve.kv_cache import extend_cache

DECODE_ARCHS = ["qwen2-1.5b", "yi-6b", "mamba2-370m",
                "jamba-1.5-large-398b", "phi3.5-moe-42b-a6.6b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full, _ = forward(params, cfg, tokens=toks, q_chunk=8)
    cache = init_decode_cache(cfg, B, S)
    for t in range(S):
        lg, cache = decode_step(params, cache, cfg, tokens=toks[:, t:t + 1],
                                pos=t)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]), rtol=2e-4,
                                   atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-370m",
                                  "jamba-1.5-large-398b"])
def test_prefill_cache_continues_decode(arch):
    """forward(return_cache) + decode_step(S) ≡ forward over S+1 tokens."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    ref, _ = forward(params, cfg, tokens=toks, q_chunk=8)
    last, _, cache = forward(params, cfg, tokens=toks[:, :S], q_chunk=8,
                             logits_last_only=True, return_cache=True)
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(ref[:, S - 1]),
                               rtol=2e-4, atol=2e-4)
    cache = extend_cache(cache, S + 4)
    lg, _ = decode_step(params, cache, cfg, tokens=toks[:, S:S + 1], pos=S)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref[:, S]),
                               rtol=2e-4, atol=2e-4)


def test_rolling_window_equals_full_when_window_covers():
    cfg = get_config("yi-6b").reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab)
    c_full = init_decode_cache(cfg, 1, 12)
    c_roll = init_decode_cache(cfg, 1, 16)
    for t in range(12):
        l1, c_full = decode_step(params, c_full, cfg, tokens=toks[:, t:t + 1],
                                 pos=t)
        l2, c_roll = decode_step(params, c_roll, cfg, tokens=toks[:, t:t + 1],
                                 pos=t, rolling=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_rolling_window_truncates_context():
    """With W < S the window must actually change the logits (old context
    evicted) but still run without error."""
    cfg = get_config("yi-6b").reduced()
    key = jax.random.PRNGKey(4)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (1, 24), 0, cfg.vocab)
    c_roll = init_decode_cache(cfg, 1, 8)
    c_full = init_decode_cache(cfg, 1, 24)
    for t in range(24):
        l_roll, c_roll = decode_step(params, c_roll, cfg,
                                     tokens=toks[:, t:t + 1], pos=t,
                                     rolling=True)
        l_full, c_full = decode_step(params, c_full, cfg,
                                     tokens=toks[:, t:t + 1], pos=t)
    assert float(jnp.max(jnp.abs(l_roll - l_full))) > 1e-6


def test_moe_capacity_matches_dense_without_drops():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    yd, aux_d = moe_forward(p, x, cfg, mode="dense")
    yc, aux_c = moe_forward(p, x, cfg, mode="capacity", capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(float(aux_d), float(aux_c), rtol=1e-5)


def test_moe_capacity_drops_under_low_capacity():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    yd, _ = moe_forward(p, x, cfg, mode="dense")
    yc, _ = moe_forward(p, x, cfg, mode="capacity", capacity_factor=0.25)
    # dropping must change some outputs (and zero some tokens' expert mix)
    assert float(jnp.max(jnp.abs(yd - yc))) > 1e-6
    assert bool(jnp.all(jnp.isfinite(yc)))


def test_ssd_chunk_invariance():
    """Chunked SSD must be invariant to the chunk size."""
    from repro.models.ssm import init_mamba, mamba_forward
    import dataclasses
    cfg = get_config("mamba2-370m").reduced()
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    outs = []
    for chunk in (8, 16, 64):
        c2 = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                              chunk=chunk))
        outs.append(mamba_forward(p, x, c2))
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-4)


def test_qchunk_invariance():
    cfg = get_config("qwen3-8b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    l1, _ = forward(params, cfg, tokens=toks, q_chunk=4)
    l2, _ = forward(params, cfg, tokens=toks, q_chunk=32)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4,
                               atol=2e-4)


def test_attention_flat_layout_matches_grouped():
    """The §Perf 'flat' (uneven-head-shardable) layout must be numerically
    identical to the grouped GQA layout."""
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    B, S, Hq, Hkv, hd = 2, 64, 8, 2, 16
    q = jax.random.normal(kq, (B, S, Hq, hd))
    k = jax.random.normal(kk, (B, S, Hkv, hd))
    v = jax.random.normal(kv, (B, S, Hkv, hd))
    for causal, window in [(True, None), (True, 16), (False, None)]:
        o1 = chunked_attention(q, k, v, causal=causal, window=window,
                               q_chunk=16, layout="grouped")
        o2 = chunked_attention(q, k, v, causal=causal, window=window,
                               q_chunk=16, layout="flat")
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-5, atol=2e-5)
