"""End-to-end behaviour tests for the paper's system (deliverable c).

These replicate the paper's headline findings at test scale:
  * federated > client-local on the global test frontier (Fig. 2),
  * federated ≈ centralized (Fig. 9 / App. D.1),
  * the routed-serving gateway selects cheaper models as λ grows (§3),
  * the distributed (shard_map) federated driver runs and reports AUC.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.core import policy
from repro.data.partition import client_slice, federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus

RCFG = RouterConfig(d_emb=24, num_models=7, hidden=(64, 64), k_local=6,
                    k_global=8)
FCFG = FedConfig(num_clients=6, rounds=12, batch_size=64, seed=3)


@pytest.fixture(scope="module")
def split():
    corpus = make_eval_corpus(jax.random.PRNGKey(0), n_queries=3000,
                              n_tasks=6, n_models=7, d_emb=24)
    return federated_split(jax.random.PRNGKey(1), corpus, FCFG)


@pytest.fixture(scope="module")
def fed_mlp(split):
    router, hist = routers.fit_federated(routers.make("mlp", RCFG),
                                         split["train"], FCFG,
                                         key=jax.random.PRNGKey(2))
    return router, hist


def _auc(router_or_pred, tg):
    pred = (router_or_pred.predict
            if isinstance(router_or_pred, routers.Router) else router_or_pred)
    *_, auc = policy.eval_router(pred, tg["x"], tg["acc_table"],
                                 tg["cost_table"])
    return auc


def test_federated_mlp_beats_local_global(split):
    """Fig. 2 at test scale. Deflaked: the fixture's rounds=12 fed fit is
    undertrained (margin ≈ −0.04 for EVERY fed seed — not a flake of the
    fed key), and sampling 3 locals happened to pick the two strongest
    clients. The converged comparison — rounds=100, full participation,
    fed AUC averaged over a small fixed seed set, locals averaged over
    ALL clients — gives a stable +0.04 margin (worst single fed seed
    +0.035), so the paper's +0.02 gap asserts reliably."""
    import dataclasses
    tg = split["test_global"]
    fcfg = dataclasses.replace(FCFG, rounds=100, participation=1.0)
    aucs_fed = []
    for s in (2, 7):
        router, _ = routers.fit_federated(routers.make("mlp", RCFG),
                                          split["train"], fcfg,
                                          key=jax.random.PRNGKey(s))
        aucs_fed.append(_auc(router, tg))
    aucs_loc = []
    for i in range(FCFG.num_clients):
        r_i, _ = routers.fit_local(routers.make("mlp", RCFG),
                                   client_slice(split["train"], i), FCFG,
                                   key=jax.random.PRNGKey(10 + i),
                                   steps=150)
        aucs_loc.append(_auc(r_i, tg))
    assert np.mean(aucs_fed) > np.mean(aucs_loc) + 0.02


def test_federated_kmeans_beats_local_global(split):
    tg = split["test_global"]
    r_fed, _ = routers.fit_federated(routers.make("kmeans", RCFG),
                                     split["train"], FCFG,
                                     key=jax.random.PRNGKey(0))
    auc_fed = _auc(r_fed, tg)
    aucs_loc = []
    for i in range(3):
        r_i, _ = routers.fit_local(routers.make("kmeans", RCFG),
                                   client_slice(split["train"], i), FCFG,
                                   key=jax.random.PRNGKey(20 + i))
        aucs_loc.append(_auc(r_i, tg))
    assert auc_fed > np.mean(aucs_loc) + 0.02


def test_federated_close_to_centralized(split, fed_mlp):
    router, _ = fed_mlp
    tg = split["test_global"]
    auc_fed = _auc(router, tg)
    pooled = flatten_clients(split["train"])
    r_cen, _ = routers.fit_local(routers.make("mlp", RCFG), pooled, FCFG,
                                 key=jax.random.PRNGKey(4),
                                 steps=FCFG.rounds * 12)
    auc_cen = _auc(r_cen, tg)
    assert abs(auc_fed - auc_cen) < 0.08  # Fig. 9: on par


def test_gateway_routes_cheaper_with_higher_lambda():
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.gateway import PoolModel, RoutedServer
    pool = []
    for i, arch in enumerate(["qwen2-1.5b", "yi-6b"]):
        cfg = get_config(arch).reduced()
        pool.append(PoolModel(arch, cfg,
                              init_params(jax.random.PRNGKey(i), cfg),
                              cost_per_token=0.1 * (i + 1) ** 2))
    prompts = ["write a poem about the sea", "solve this integral now",
               "summarize the meeting notes", "prove the theorem carefully"]
    # one-cluster K-means router: every query gets the same estimates —
    # strong model (idx 1) better but 9× pricier
    router = routers.make(
        "kmeans", RouterConfig(d_emb=64, num_models=2),
        state={"centroids": jnp.zeros((1, 64)),
               "A": jnp.array([[0.6, 0.9]]),
               "C": jnp.array([[0.1, 0.9]]),
               "n": jnp.ones((1, 2))})
    srv = RoutedServer(pool, router)
    lo = srv.generate(prompts, lam=0.0, max_new_tokens=2)
    hi = srv.generate(prompts, lam=5.0, max_new_tokens=2)
    assert hi["total_cost"] < lo["total_cost"]
    assert {r["model"] for r in lo["results"]} == {"yi-6b"}
    assert {r["model"] for r in hi["results"]} == {"qwen2-1.5b"}


def test_distributed_fed_driver_runs():
    """shard_map federated driver in a subprocess with fake devices."""
    code = (
        "import os; os.environ['XLA_FLAGS']="
        "'--xla_force_host_platform_device_count=4';"
        "import sys; sys.argv=['x','--clients','8','--rounds','2',"
        "'--queries','800'];"
        "from repro.launch import fed_train; fed_train.main()")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420,
                         # pin to cpu: the fake-device XLA flag only applies
                         # to the host platform, and auto-detect can burn
                         # minutes probing an accelerator backend
                         env={**os.environ, "PYTHONPATH": "src",
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "AUC" in out.stdout


def test_encoder_stub_deterministic_and_semantic():
    """Enc(·) is frozen (process-independent) and groups shared-token
    prompts closer than disjoint ones."""
    from repro.data.encoder import encode
    a = encode(["prove the theorem", "prove the lemma"], 32)
    b = encode(["prove the theorem", "write a poem"], 32)
    np.testing.assert_array_equal(a[0], b[0])  # deterministic
    sim_related = float(a[0] @ a[1])
    sim_unrelated = float(b[0] @ b[1])
    assert sim_related > sim_unrelated
