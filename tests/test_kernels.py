"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Each kernel is swept over shapes and dtypes and asserted allclose against
its ref.py oracle (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.kmeans_assign import (kmeans_assign_pallas,
                                         kmeans_assign_reduce_pallas)
from repro.kernels.router_utility import router_utility_pallas


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    # Interpret-mode pallas_call programs (and the token-parity decode
    # rollouts below) compile large XLA graphs; drop the executables when
    # the module finishes so the full-suite process doesn't carry them.
    yield
    jax.clear_caches()


@pytest.mark.parametrize("n,d,K", [(64, 8, 3), (513, 77, 13), (1000, 128, 20),
                                   (256, 768, 15), (37, 33, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign(n, d, K, dtype):
    kx, kc = jax.random.split(jax.random.PRNGKey(n + d))
    x = jax.random.normal(kx, (n, d), dtype)
    c = jax.random.normal(kc, (K, d), dtype)
    got = kmeans_assign_pallas(x, c, interpret=True)
    want = ref.kmeans_assign_ref(x, c)
    # ties can differ between argmin orders at low precision — allow equal dist
    neq = np.asarray(got != want)
    if neq.any():
        xf, cf = np.asarray(x, np.float32), np.asarray(c, np.float32)
        d2 = ((xf[:, None] - cf[None]) ** 2).sum(-1)
        rows = np.where(neq)[0]
        assert np.allclose(d2[rows, np.asarray(got)[rows]],
                           d2[rows, np.asarray(want)[rows]], rtol=1e-3,
                           atol=1e-3)


@pytest.mark.parametrize("n,d,K", [(65, 1000, 7), (33, 1536, 5),
                                   (257, 999, 13)])
def test_kmeans_assign_wide_d_boundary(n, d, K):
    """Wide-d boundary: below ``block_d`` (default 2048) both kmeans
    kernels still run their original single-pass paths and must match the
    oracle exactly — pinned here so the d-tiling dispatch can never perturb
    the narrow/medium regime it leaves alone."""
    kx, kc, kw = jax.random.split(jax.random.PRNGKey(n), 3)
    x = jax.random.normal(kx, (n, d))
    c = jax.random.normal(kc, (K, d))
    w = jax.random.uniform(kw, (n,))
    np.testing.assert_array_equal(
        np.asarray(kmeans_assign_pallas(x, c, interpret=True)),
        np.asarray(ref.kmeans_assign_ref(x, c)))
    a_got, s_got, n_got = kmeans_assign_reduce_pallas(x, c, w,
                                                      interpret=True)
    a_ref, s_ref, n_ref = ref.kmeans_assign_reduce_ref(x, c, w)
    np.testing.assert_array_equal(np.asarray(a_got), np.asarray(a_ref))
    # wide-d sums accumulate n terms per coordinate — scale the tolerance
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(n_got), np.asarray(n_ref),
                               rtol=1e-5, atol=1e-5)


def _assert_assign_equiv(x, c, got, want):
    """Tiled accumulation reorders float sums, so an argmin may legally
    flip between equidistant (to rounding) centroids; anything else is a
    real mismatch."""
    got, want = np.asarray(got), np.asarray(want)
    neq = got != want
    if neq.any():
        xf, cf = np.asarray(x, np.float32), np.asarray(c, np.float32)
        d2 = ((xf[:, None] - cf[None]) ** 2).sum(-1)
        rows = np.where(neq)[0]
        assert np.allclose(d2[rows, got[rows]], d2[rows, want[rows]],
                           rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d,K,bd", [(65, 300, 7, 128), (33, 1536, 5, 512),
                                      (257, 999, 13, 256),
                                      (100, 4096, 40, 2048)])
def test_kmeans_assign_block_d_tiled(n, d, K, bd):
    """d wider than ``block_d`` runs the d-tile accumulation loop (VMEM
    scratch holds the x·μᵀ and ‖μ‖² partials; the argmin merge waits for
    the last d tile) — same assignment as the oracle up to float ties,
    including non-pow2 d with padding and the block_k × block_d combined
    grid."""
    kx, kc, kw = jax.random.split(jax.random.PRNGKey(n), 3)
    x = jax.random.normal(kx, (n, d))
    c = jax.random.normal(kc, (K, d))
    w = jax.random.uniform(kw, (n,))
    got = kmeans_assign_pallas(x, c, block_n=64, block_d=bd, interpret=True)
    _assert_assign_equiv(x, c, got, ref.kmeans_assign_ref(x, c))

    a_got, s_got, n_got = kmeans_assign_reduce_pallas(
        x, c, w, block_n=64, block_d=bd, interpret=True)
    # reduce must be self-consistent with the kernel's own assignment
    # (ties may legally route a point to an equidistant cluster)
    np.testing.assert_array_equal(np.asarray(a_got), np.asarray(got))
    onehot = jax.nn.one_hot(a_got, K, dtype=jnp.float32) * w[:, None]
    np.testing.assert_allclose(np.asarray(s_got),
                               np.asarray(onehot.T @ x), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(n_got),
                               np.asarray(onehot.sum(0)), rtol=1e-5,
                               atol=1e-5)


def test_kmeans_assign_block_d_shape_independence():
    """The assignment must not depend on the d tiling (up to exact-tie
    flips, checked by distance)."""
    kx, kc = jax.random.split(jax.random.PRNGKey(17))
    x = jax.random.normal(kx, (70, 900))
    c = jax.random.normal(kc, (9, 900))
    base = kmeans_assign_pallas(x, c, block_n=64, interpret=True)
    for bd in (128, 256, 512):
        got = kmeans_assign_pallas(x, c, block_n=64, block_d=bd,
                                   interpret=True)
        _assert_assign_equiv(x, c, got, base)


def test_attn_decode_step_kernel_dispatch(monkeypatch):
    """REPRO_KERNELS=pallas routes the uniform decode step (and therefore
    the engine's uniform decode scan) through the flash-decoding kernel —
    interpret mode on CPU — matching the jnp path for scalar and per-slot
    positions, with identical cache writes."""
    from repro.config import ModelConfig
    from repro.models import attention as A
    cfg = ModelConfig(name="dispatch-tiny", arch_type="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab=97, head_dim=16, dtype="float32")
    p = A.init_attn(jax.random.PRNGKey(0), cfg)
    B, W = 3, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(ks[0], (B, 1, cfg.d_model))
    cache = {"k": jax.random.normal(ks[1], (B, 1, W, 16)),
             "v": jax.random.normal(ks[2], (B, 1, W, 16))}
    for pos in (jnp.int32(5), jnp.array([3, 17, 31], jnp.int32)):
        monkeypatch.delenv("REPRO_KERNELS", raising=False)
        o_ref, c_ref_ = A.attn_decode_step(p, x, cache, pos, cfg,
                                           rolling=False)
        monkeypatch.setenv("REPRO_KERNELS", "pallas")
        o_k, c_k = A.attn_decode_step(p, x, cache, pos, cfg, rolling=False)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_k),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(c_ref_["k"]),
                                      np.asarray(c_k["k"]))


def test_kmeans_assign_large_k_tiled():
    """Centroid tables bigger than one block run the block_k tile loop and
    still match the oracle exactly (strict-< merge keeps first-tie order)."""
    kx, kc = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(kx, (200, 24))
    c = jax.random.normal(kc, (1000, 24))
    want = ref.kmeans_assign_ref(x, c)
    for bk in (128, 256, 512):
        got = kmeans_assign_pallas(x, c, block_k=bk, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,d,K", [(64, 8, 3), (513, 77, 13), (256, 128, 20),
                                   (100, 40, 130)])
def test_kmeans_assign_reduce(n, d, K):
    """Fused assign-reduce kernel == jnp oracle: same argmin, same
    weighted per-cluster coordinate sums and counts."""
    kx, kc, kw = jax.random.split(jax.random.PRNGKey(n + d), 3)
    x = jax.random.normal(kx, (n, d))
    c = jax.random.normal(kc, (K, d))
    w = jax.random.uniform(kw, (n,))
    a_ref, s_ref, n_ref = ref.kmeans_assign_reduce_ref(x, c, w)
    a_got, s_got, n_got = kmeans_assign_reduce_pallas(x, c, w,
                                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(a_got), np.asarray(a_ref))
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(n_got), np.asarray(n_ref),
                               rtol=1e-5, atol=1e-5)


def test_kmeans_assign_reduce_large_k_tiled():
    """K in the thousands runs the two-phase block_k centroid-tile loop
    (tiled argmin merge, then tiled one-hot reduction) and still matches
    the whole-table oracle: exact argmin (strict-< keeps first-tie order)
    and allclose sums/counts."""
    kx, kc, kw = jax.random.split(jax.random.PRNGKey(11), 3)
    x = jax.random.normal(kx, (300, 24))
    c = jax.random.normal(kc, (2000, 24))
    w = jax.random.uniform(kw, (300,))
    a_ref, s_ref, n_ref = ref.kmeans_assign_reduce_ref(x, c, w)
    for bk in (128, 512, 1024):
        a_got, s_got, n_got = kmeans_assign_reduce_pallas(
            x, c, w, block_k=bk, interpret=True)
        np.testing.assert_array_equal(np.asarray(a_got), np.asarray(a_ref))
        np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(n_got), np.asarray(n_ref),
                                   rtol=1e-5, atol=1e-5)


def test_kmeans_assign_reduce_masks_padding():
    """Zero-weight (padded) rows must not leak into sums/counts, and the
    reduction must agree with a manual per-cluster sum."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(37, 9)),
                    jnp.float32)
    c = x[:5]
    w = jnp.where(jnp.arange(37) < 30, 1.0, 0.0)
    assign, sums, cnts = ref.kmeans_assign_reduce_ref(x, c, w)
    assert float(jnp.sum(cnts)) == pytest.approx(30.0)
    manual = np.zeros((5, 9), np.float32)
    for i in range(30):
        manual[int(assign[i])] += np.asarray(x[i])
    np.testing.assert_allclose(np.asarray(sums), manual, rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("n,dh,M", [(17, 64, 3), (300, 512, 11), (256, 512, 14),
                                    (1024, 128, 40)])
@pytest.mark.parametrize("lam", [0.0, 0.5, 10.0])
def test_router_utility(n, dh, M, lam):
    keys = jax.random.split(jax.random.PRNGKey(n + M), 5)
    h = jax.random.normal(keys[0], (n, dh))
    aw = jax.random.normal(keys[1], (dh, M)) * 0.05
    ab = jax.random.normal(keys[2], (M,)) * 0.1
    cw = jax.random.normal(keys[3], (dh, M)) * 0.05
    cb = jax.random.normal(keys[4], (M,)) * 0.1
    c1, b1 = ref.router_utility_ref(h, aw, ab, cw, cb, lam)
    c2, b2 = router_utility_pallas(h, aw, ab, cw, cb, lam, interpret=True)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), rtol=2e-5,
                               atol=2e-5)
    # argmax may differ only on numerical ties
    neq = np.asarray(c1 != c2)
    assert neq.mean() < 0.01


@pytest.mark.parametrize("B,S,H,hd", [(1, 128, 2, 64), (2, 256, 4, 64),
                                      (2, 512, 2, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, hd, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_k=128, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol)


def test_flash_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (2, 256, 2, 64)) for kk in ks)
    outs = [flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                   block_k=bk, interpret=True)
            for bq, bk in [(32, 32), (64, 128), (256, 64), (128, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_ops_dispatch_ref_default_on_cpu():
    from repro.kernels import ops
    x = jnp.zeros((4, 8))
    c = jnp.zeros((2, 8))
    assert ops.kmeans_assign(x, c).shape == (4,)


@pytest.mark.parametrize("B,Hkv,g,S,hd", [(1, 2, 4, 256, 64), (2, 4, 1, 512, 128),
                                          (2, 1, 8, 1024, 64)])
@pytest.mark.parametrize("n_valid_frac", [0.3, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, Hkv, g, S, hd, n_valid_frac, dtype):
    from repro.kernels.decode_attention import decode_attention_pallas
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(ks[0], (B, Hkv, g, hd), dtype)
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
    nv = max(1, int(S * n_valid_frac))
    want = ref.decode_attention_ref(q, kc, vc, nv)
    got = decode_attention_pallas(q, kc, vc, nv, block_s=128, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol)


def test_decode_attention_per_batch_n_valid():
    """A (B,) n_valid vector gives every batch row (continuous-batching
    pool slot) its own validity bound — equal to the scalar kernel run
    per-row."""
    from repro.kernels.decode_attention import decode_attention_pallas
    B, Hkv, g, S, hd = 3, 2, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Hkv, g, hd))
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd))
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd))
    nv = jnp.array([3, 40, 64], jnp.int32)
    got = decode_attention_pallas(q, kc, vc, nv, block_s=32, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, nv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)
    for b in range(B):
        row = decode_attention_pallas(q[b:b + 1], kc[b:b + 1], vc[b:b + 1],
                                      int(nv[b]), block_s=32, interpret=True)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(row[0]),
                                   rtol=2e-5, atol=2e-5)


def test_decode_attention_ragged_validity_including_empty_rows():
    """Ragged per-slot validity with fully-invalid rows (n_valid = 0 — a
    drained pool slot): valid rows match the per-row scalar runs, empty
    rows emit exactly 0 in both kernel and oracle (no uniform-softmax
    garbage average)."""
    from repro.kernels.decode_attention import decode_attention_pallas
    B, Hkv, g, S, hd = 4, 2, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, Hkv, g, hd))
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd))
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd))
    nv = jnp.array([0, 1, 37, 64], jnp.int32)
    got = decode_attention_pallas(q, kc, vc, nv, block_s=32, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, nv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)
    assert np.all(np.asarray(got)[0] == 0.0)
    assert np.all(np.asarray(want)[0] == 0.0)
    for b in range(1, B):
        row = decode_attention_pallas(q[b:b + 1], kc[b:b + 1], vc[b:b + 1],
                                      int(nv[b]), block_s=32, interpret=True)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(row[0]),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Hkv,g,ps,npg,P", [(2, 2, 2, 8, 4, 12),
                                              (3, 1, 4, 16, 2, 5),
                                              (1, 2, 1, 32, 3, 4)])
def test_paged_decode_attention_matches_oracle(B, Hkv, g, ps, npg, P):
    """Scalar-prefetch paged kernel == gather oracle over random page
    tables (trash-page entries included via short validity bounds)."""
    from repro.kernels.decode_attention import paged_decode_attention_pallas
    hd = 32
    ks = jax.random.split(jax.random.PRNGKey(B * ps + npg), 3)
    q = jax.random.normal(ks[0], (B, Hkv, g, hd))
    kp = jax.random.normal(ks[1], (P, Hkv, ps, hd))
    vp = jax.random.normal(ks[2], (P, Hkv, ps, hd))
    rng = np.random.default_rng(0)
    pt = jnp.asarray(rng.integers(0, P, size=(B, npg)), jnp.int32)
    nv = jnp.asarray(rng.integers(0, npg * ps + 1, size=(B,)), jnp.int32)
    got = paged_decode_attention_pallas(q, kp, vp, pt, nv, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, pt, nv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("B,Hkv,g,ps,npg,P", [(2, 2, 2, 8, 4, 12),
                                              (3, 1, 4, 16, 2, 5),
                                              (4, 2, 1, 8, 3, 6)])
def test_paged_seg_matches_gather_oracle(B, Hkv, g, ps, npg, P):
    """The copy-free segment-summed CPU formulation == the gather oracle
    over random tables — including duplicate page entries (counted with
    multiplicity on both sides) and fully-invalid rows (exact zeros)."""
    hd = 32
    ks = jax.random.split(jax.random.PRNGKey(B * ps + P), 3)
    q = jax.random.normal(ks[0], (B, Hkv, g, hd))
    kp = jax.random.normal(ks[1], (P, Hkv, ps, hd))
    vp = jax.random.normal(ks[2], (P, Hkv, ps, hd))
    rng = np.random.default_rng(1)
    pt = jnp.asarray(rng.integers(0, P, size=(B, npg)), jnp.int32)
    nv = jnp.asarray(rng.integers(0, npg * ps + 1, size=(B,)), jnp.int32)
    nv = nv.at[0].set(0)                        # pin one fully-invalid row
    got = ref.paged_decode_attention_seg_ref(q, kp, vp, pt, nv)
    want = ref.paged_decode_attention_ref(q, kp, vp, pt, nv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-6)
    assert np.all(np.asarray(got)[0] == 0.0)
    # a table with every row naming the SAME page twice still agrees
    pt_dup = jnp.tile(pt[:, :1], (1, npg))
    np.testing.assert_allclose(
        np.asarray(ref.paged_decode_attention_seg_ref(q, kp, vp, pt_dup, nv)),
        np.asarray(ref.paged_decode_attention_ref(q, kp, vp, pt_dup, nv)),
        rtol=2e-5, atol=2e-6)


def test_ops_paged_cpu_fallback_is_segment_summed(monkeypatch):
    """kops.paged_decode_attention's non-Pallas path dispatches to the
    seg formulation and stays within float noise of the gather oracle."""
    from repro.kernels import ops as kops
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    B, Hkv, g, ps, npg, P, hd = 2, 2, 2, 8, 3, 5, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, Hkv, g, hd))
    kp = jax.random.normal(ks[1], (P, Hkv, ps, hd))
    vp = jax.random.normal(ks[2], (P, Hkv, ps, hd))
    pt = jnp.asarray([[0, 1, 2], [3, 4, 0]], jnp.int32)
    nv = jnp.asarray([17, 24], jnp.int32)
    got = kops.paged_decode_attention(q, kp, vp, pt, nv)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.paged_decode_attention_seg_ref(q, kp, vp, pt, nv)))
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(ref.paged_decode_attention_ref(q, kp, vp, pt, nv)),
        rtol=2e-5, atol=2e-6)


def test_paged_decode_attention_equals_contiguous():
    """A paged pool whose table lays pages out contiguously must equal the
    contiguous kernel on the equivalent (B, Hkv, S, hd) cache — paging is
    an addressing change, not a math change."""
    from repro.kernels.decode_attention import (decode_attention_pallas,
                                                paged_decode_attention_pallas)
    B, Hkv, g, ps, npg, hd = 2, 2, 2, 16, 4, 32
    S = ps * npg
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, Hkv, g, hd))
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd))
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd))
    # pool rows = each batch row's pages, in order
    kp = jnp.moveaxis(kc.reshape(B, Hkv, npg, ps, hd), 2, 1) \
            .reshape(B * npg, Hkv, ps, hd)
    vp = jnp.moveaxis(vc.reshape(B, Hkv, npg, ps, hd), 2, 1) \
            .reshape(B * npg, Hkv, ps, hd)
    pt = jnp.arange(B * npg, dtype=jnp.int32).reshape(B, npg)
    nv = jnp.array([23, 64], jnp.int32)
    got = paged_decode_attention_pallas(q, kp, vp, pt, nv, interpret=True)
    want = decode_attention_pallas(q, kc, vc, nv, block_s=ps, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_decode_attention_matches_model_decode():
    """Kernel semantics == attn_decode_step inner math (head-major cache)."""
    from repro.kernels.decode_attention import decode_attention_pallas
    B, Hkv, g, S, hd = 2, 2, 3, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, Hkv, g, hd))
    kc = jax.random.normal(ks[1], (B, Hkv, S, hd))
    vc = jax.random.normal(ks[2], (B, Hkv, S, hd))
    nv = 40
    got = decode_attention_pallas(q, kc, vc, nv, block_s=32, interpret=True)
    # manual grouped einsum (as in models/attention.attn_decode_step)
    s = jnp.einsum("bhgd,bhkd->bhgk", q, kc) * hd ** -0.5
    s = jnp.where(jnp.arange(S)[None, None, None, :] < nv, s, -1e30)
    want = jnp.einsum("bhgk,bhkd->bhgd", jax.nn.softmax(s, -1), vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_decode_kernel_token_parity_uniform(monkeypatch, dtype):
    """Greedy TOKENS through the uniform decode path must be identical
    whether attention runs the Pallas flash-decoding kernel (interpret
    mode here) or the jnp reference einsum — the kernels share the jnp
    path's dtype discipline (cache-dtype dots, f32 accumulation, probs
    downcast before the V dot), so score/weight quantization matches and
    bf16 near-ties cannot split the argmax across the dispatch boundary.
    Values still differ in the last ulps (online softmax normalizes once
    at the end); the serving contract is about tokens, so that is what
    this pins."""
    from repro.config import ModelConfig
    from repro.models import init_params, model as mdl
    cfg = ModelConfig(name=f"ktok-{dtype}", arch_type="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab=97, head_dim=16, dtype=dtype)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, W, steps = 3, 32, 12
    pos0 = np.array([3, 9, 17], np.int32)
    tok0 = np.array([5, 41, 88], np.int32)

    def rollout(impl):
        monkeypatch.setenv("REPRO_KERNELS", impl)
        cache = mdl.init_decode_cache(cfg, B, W)
        # make prior positions attention-valid with deterministic junk
        cache = jax.tree.map(
            lambda a: jax.random.normal(jax.random.PRNGKey(1), a.shape,
                                        a.dtype) * 0.3, cache)
        tok, pos = jnp.asarray(tok0), jnp.asarray(pos0)
        seq = []
        for _ in range(steps):
            logits, cache = mdl.decode_step(params, cache, cfg,
                                            tokens=tok[:, None], pos=pos)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            pos = pos + 1
            seq.append(np.asarray(tok))
        return np.stack(seq, 1)

    np.testing.assert_array_equal(rollout("ref"), rollout("pallas"))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_decode_kernel_token_parity_paged(monkeypatch, dtype):
    """Paged twin of test_decode_kernel_token_parity_uniform: the
    scalar-prefetch paged kernel and the jnp gather path must emit the
    same greedy tokens on f32 AND bf16 pools."""
    from repro.config import ModelConfig
    from repro.models import init_params, model as mdl
    cfg = ModelConfig(name=f"ktokp-{dtype}", arch_type="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab=97, head_dim=16, dtype=dtype)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, ps, npg, P, steps = 2, 8, 4, 9, 10
    pt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    pos0 = np.array([4, 11], np.int32)
    tok0 = np.array([7, 61], np.int32)

    def rollout(impl):
        monkeypatch.setenv("REPRO_KERNELS", impl)
        cache = mdl.init_paged_cache(cfg, P, ps)
        cache = jax.tree.map(
            lambda a: jax.random.normal(jax.random.PRNGKey(2), a.shape,
                                        a.dtype) * 0.3, cache)
        tok, pos = jnp.asarray(tok0), jnp.asarray(pos0)
        seq = []
        for _ in range(steps):
            logits, cache = mdl.decode_step_paged(
                params, cache, cfg, tokens=tok[:, None], page_table=pt,
                pos=pos)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            pos = pos + 1
            seq.append(np.asarray(tok))
        return np.stack(seq, 1)

    np.testing.assert_array_equal(rollout("ref"), rollout("pallas"))
