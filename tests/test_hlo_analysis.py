"""HLO cost-parser unit tests on hand-written HLO snippets."""
from repro.launch.hlo_analysis import HloCosts, _shape_bytes

HLO = """\
%loop_body (param.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
}
%loop_cond (param.2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %lim = s32[] constant(7)
  ROOT %cmp = pred[] compare(%i2, %lim), direction=LT
}
ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %w2 = f32[16,32]{1,0} constant({...})
  %dot.2 = f32[8,32]{1,0} dot(%arg, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %init = (s32[], f32[8,16]) tuple(%c0, %arg)
  %wh = (s32[], f32[8,16]) while(%init), condition=%loop_cond, body=%loop_body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]") == 8 * 16 * 4
    assert _shape_bytes("bf16[2,3]{1,0}") == 12
    assert _shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert _shape_bytes("pred[5]") == 5


def test_loop_trip_multiplier():
    hc = HloCosts(HLO)
    t = hc.totals()
    # dot inside loop: 2*8*16*16 = 4096 flops × trip 7; dot.2: 2*8*32*16
    assert t["flops"] == 7 * 4096 + 2 * 8 * 32 * 16
    # all-reduce inside loop: 8*16*4 bytes × 7
    assert t["collectives"]["all-reduce"] == 7 * 8 * 16 * 4


def test_entry_detected():
    hc = HloCosts(HLO)
    assert hc.entry == "main"
