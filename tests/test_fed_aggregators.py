"""Pluggable Aggregator strategies (repro.fed.aggregators): the refactored
fit must be bit-for-bit the pre-refactor FedAvg on every cached path,
secure-agg masking must cancel (bit-identically at scale=0, to float
rounding at scale>0), and the legacy dp_sigma sugar must equal the explicit
DP strategy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.core import federated as F
from repro.core import secure_agg as SA
from repro.data.partition import federated_split
from repro.data.synthetic import make_eval_corpus
from repro.fed.aggregators import (Aggregator, FedAvgAggregator,
                                   GaussianDPAggregator, SecureAggAggregator)

RCFG = RouterConfig(d_emb=16, num_models=5, hidden=(32, 32))
FCFG = FedConfig(num_clients=4, rounds=3, batch_size=32, seed=1)


@pytest.fixture(scope="module")
def split():
    corpus = make_eval_corpus(jax.random.PRNGKey(0), n_queries=600,
                              n_tasks=4, n_models=5, d_emb=16)
    return federated_split(jax.random.PRNGKey(1), corpus, FCFG)


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _max_diff(a, b):
    return max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------- refactor is bit-for-bit

def test_default_fit_equals_explicit_fedavg_aggregator(split):
    """aggregator=None and FedAvgAggregator() must be the same scan-fused
    fit bit-for-bit — the Aggregator refactor cannot move the default."""
    p0, h0 = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG)
    p1, h1 = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                      aggregator=FedAvgAggregator())
    _trees_equal(p0, p1)
    assert h0["loss"] == h1["loss"]


def test_aggregator_rides_scan_and_loop_paths(split):
    """An explicit strategy rides both the scan-fused and per-round fit
    paths with the same key and round schedule. A single round compiles
    bit-identically in both contexts; across a multi-round fit XLA may
    fuse the N² mask arithmetic differently inside the scan body than in
    the standalone round jit, so the guarantee for mask-heavy strategies
    is to-rounding (the DEFAULT FedAvg path stays bit-for-bit — pinned in
    test_perf_paths)."""
    agg = SecureAggAggregator(scale=5.0)
    p_scan, h_scan = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG,
                              FCFG, aggregator=agg)
    p_loop, h_loop = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG,
                              FCFG, aggregator=agg, eval_fn=lambda p: None)
    assert _max_diff(p_scan, p_loop) < 1e-5
    np.testing.assert_allclose(h_scan["loss"], h_loop["loss"], rtol=1e-5)


def test_unified_api_forwards_aggregator(split):
    """routers.fit_federated(..., aggregator=) reaches the fit path."""
    r, _ = routers.fit_federated(
        routers.make("mlp", RCFG), split["train"], FCFG,
        key=jax.random.PRNGKey(2), aggregator=FedAvgAggregator())
    legacy, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG)
    _trees_equal(r.state, legacy)


def test_unhashable_custom_aggregator_still_fits(split):
    """A custom (unhashable) strategy can't ride the lru-cached compiled
    fits — it must still train through the fresh-jit branch, and a plain
    pass-through strategy must equal the default bit-for-bit."""
    class PassThrough(Aggregator):
        __hash__ = None                 # explicitly unhashable

        def __call__(self, client_params, wts, key):
            return FedAvgAggregator()(client_params, wts, key)

    agg = PassThrough()
    with pytest.raises(TypeError):
        hash(agg)
    p, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                    aggregator=agg)
    p0, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG)
    _trees_equal(p, p0)


# -------------------------------------------------------------- secure agg

def test_secure_agg_scale0_bit_identical_to_fedavg(split):
    """When the masks cancel exactly (scale=0 → exact-zero masks folded
    through the identical tensordot), the masked fit IS the plain fit."""
    p0, h0 = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG)
    p1, h1 = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                      aggregator=SecureAggAggregator(scale=0.0))
    _trees_equal(p0, p1)
    assert h0["loss"] == h1["loss"]


def test_secure_agg_masks_cancel_to_rounding(split):
    """With real masks (scale ≫ parameter magnitudes) the pairwise masks
    must cancel in the server sum down to float rounding — the whole fit
    stays within ~1e-4 of plain FedAvg while no client's unmasked update
    ever reaches the server."""
    p0, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG)
    p1, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                     aggregator=SecureAggAggregator(scale=10.0))
    assert 0.0 < _max_diff(p0, p1) < 1e-4


def test_secure_agg_single_round_masking(split):
    """One aggregation in isolation: the strategy's masked tensordot must
    match plain FedAvg to rounding for any participant subset, including a
    partially active round (masks are gated by the participant set — a
    dropped client's pair masks are never applied)."""
    key = jax.random.PRNGKey(0)
    N = 4
    cp = {"w": jax.random.normal(key, (N, 6, 3)),
          "b": jax.random.normal(jax.random.fold_in(key, 1), (N, 3))}
    for wts in (jnp.array([3.0, 1.0, 2.0, 4.0]),
                jnp.array([3.0, 0.0, 2.0, 0.0])):      # partial round
        plain = FedAvgAggregator()(cp, wts, key)
        masked = SecureAggAggregator(scale=20.0)(cp, wts, key)
        assert _max_diff(plain, masked) < 1e-4


def test_secure_agg_core_simulation_consistency():
    """The strategy reuses core/secure_agg's pair-key/mask machinery: the
    classic mask_update → secure_aggregate roundtrip must agree with the
    unmasked weighted mean (mask cancellation in the reference sim)."""
    key = jax.random.PRNGKey(3)
    updates = [jax.random.normal(jax.random.fold_in(key, i), (5, 2))
               for i in range(3)]
    wts = [1.0, 2.0, 3.0]
    masked = [SA.mask_update(key, i, 3, updates[i], wts[i], scale=10.0)
              for i in range(3)]
    agg = SA.secure_aggregate(masked, sum(wts))
    want = sum(w * u for w, u in zip(wts, updates)) / sum(wts)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------- dp

def test_dp_sigma_sugar_equals_explicit_strategy(split):
    """fedavg(dp_sigma=σ) must be bit-for-bit
    fedavg(aggregator=GaussianDPAggregator(σ)) — the legacy knob is now
    sugar over the strategy, same noise keys and all."""
    p0, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                     dp_sigma=0.3)
    p1, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                     aggregator=GaussianDPAggregator(sigma=0.3))
    _trees_equal(p0, p1)


def test_dp_sigma_auto_wraps_explicit_aggregator(split):
    """dp_sigma>0 alongside aggregator= must not silently drop the
    privacy noise: the fit auto-composes GaussianDP over the given
    strategy (bit-for-bit the explicit composition)."""
    inner = SecureAggAggregator(scale=2.0)
    p0, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                     aggregator=inner, dp_sigma=0.1)
    p1, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                     aggregator=GaussianDPAggregator(sigma=0.1,
                                                     inner=inner))
    _trees_equal(p0, p1)
    p2, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                     aggregator=inner)
    assert _max_diff(p0, p2) > 1e-4        # the noise really was applied


def test_dp_composes_over_secure_agg(split):
    """Central-DP noise over masked aggregation (the paper's privacy
    stack): trains to finite params, and differs from the noiseless
    masked fit (the noise is real)."""
    agg = GaussianDPAggregator(sigma=0.05, inner=SecureAggAggregator())
    p, h = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                    aggregator=agg)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(p))
    assert np.isfinite(h["loss"]).all()
    p_nless, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                          aggregator=SecureAggAggregator())
    assert _max_diff(p, p_nless) > 1e-4
