"""Unified ``repro.routers`` API: registry round-trip, bit-for-bit parity
of ``fit_federated``/``fit_local`` with the legacy family-specific entry
points on a fixed seed, save/load round-trips, and the gateway's
construction-time pool validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.core import federated as F
from repro.core import kmeans_router as KR
from repro.core import mlp_router as R
from repro.data.partition import client_slice, federated_split, flatten_clients
from repro.data.synthetic import make_eval_corpus

RCFG = RouterConfig(d_emb=16, num_models=5, hidden=(32, 32), k_local=4,
                    k_global=6, mf_rank=8)
FCFG = FedConfig(num_clients=4, rounds=3, batch_size=32, seed=1)
# every registered family — new zoo members are picked up automatically
ALL_FAMILIES = sorted(routers.available())


@pytest.fixture(scope="module")
def split():
    corpus = make_eval_corpus(jax.random.PRNGKey(0), n_queries=900,
                              n_tasks=4, n_models=5, d_emb=16)
    return federated_split(jax.random.PRNGKey(1), corpus, FCFG)


@pytest.fixture(scope="module")
def fed_mlp(split):
    router, hist = routers.fit_federated(routers.make("mlp", RCFG),
                                         split["train"], FCFG,
                                         key=jax.random.PRNGKey(2))
    return router, hist


@pytest.fixture(scope="module")
def fed_km(split):
    router, _ = routers.fit_federated(routers.make("kmeans", RCFG),
                                      split["train"], FCFG,
                                      key=jax.random.PRNGKey(3))
    return router


@pytest.fixture(scope="module", params=ALL_FAMILIES)
def fed_any(request, split):
    """One federated fit per registered family — everything asserted on
    this fixture holds for future zoo additions automatically."""
    router, hist = routers.fit_federated(
        routers.make(request.param, RCFG), split["train"], FCFG,
        key=jax.random.fold_in(jax.random.PRNGKey(2),
                               ALL_FAMILIES.index(request.param)))
    return router, hist


@pytest.fixture(scope="module")
def fed_mf(split):
    router, _ = routers.fit_federated(routers.make("mf", RCFG),
                                      split["train"], FCFG,
                                      key=jax.random.PRNGKey(4))
    return router


@pytest.fixture(scope="module")
def fed_elo(split):
    router, _ = routers.fit_federated(routers.make("elo", RCFG),
                                      split["train"], FCFG,
                                      key=jax.random.PRNGKey(5))
    return router


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -------------------------------------------------------------------- registry

def test_registry_lists_all_families():
    assert set(routers.available()) >= {"mlp", "kmeans", "mf", "elo"}


def test_make_unknown_family_raises():
    """A typo'd family name must fail with a ValueError that NAMES every
    registered family — the error is the discovery surface."""
    with pytest.raises(ValueError, match="unknown router family") as ei:
        routers.make("transformer", RCFG)
    for name in routers.available():
        assert name in str(ei.value)


def test_make_builds_registered_classes():
    assert isinstance(routers.make("mlp", RCFG), routers.MLPRouter)
    assert isinstance(routers.make("kmeans", RCFG), routers.KMeansRouter)
    assert isinstance(routers.make("mf", RCFG), routers.MFRouter)
    assert isinstance(routers.make("elo", RCFG), routers.EloRouter)
    assert routers.make("mlp", RCFG).parametric
    assert routers.make("mf", RCFG).parametric
    assert not routers.make("kmeans", RCFG).parametric
    assert not routers.make("elo", RCFG).parametric


# ------------------------------------------------------------- legacy parity

def test_fit_federated_mlp_matches_legacy_fedavg(split, fed_mlp):
    """Unified path ≡ core.federated.fedavg bit-for-bit on a fixed seed."""
    router, hist = fed_mlp
    legacy, lhist = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG,
                             FCFG)
    _trees_equal(router.state, legacy)
    assert hist["loss"] == lhist["loss"]


def test_fit_federated_kmeans_matches_legacy(split, fed_km):
    legacy = KR.fed_kmeans_router(jax.random.PRNGKey(3), split["train"],
                                  RCFG)
    _trees_equal(fed_km.state, legacy)


def test_fit_local_matches_legacy(split):
    di = client_slice(split["train"], 0)
    r_mlp, _ = routers.fit_local(routers.make("mlp", RCFG), di, FCFG,
                                 key=jax.random.PRNGKey(11), steps=25)
    legacy_mlp, _ = F.sgd_train(jax.random.PRNGKey(11), di, RCFG, FCFG,
                                steps=25)
    _trees_equal(r_mlp.state, legacy_mlp)

    r_km, _ = routers.fit_local(routers.make("kmeans", RCFG), di, FCFG,
                                key=jax.random.PRNGKey(12))
    legacy_km = KR.local_kmeans_router(jax.random.PRNGKey(12), di, RCFG)
    _trees_equal(r_km.state, legacy_km)


def test_predict_matches_legacy_apply(split, fed_mlp, fed_km):
    x = split["test_global"]["x"][:13]
    router, _ = fed_mlp
    A, C = router.predict(x)
    A_l, C_l = R.apply_mlp_router(router.state, x)
    np.testing.assert_array_equal(np.asarray(A), np.asarray(A_l))
    A, C = fed_km.predict(x)
    A_l, C_l = KR.predict(fed_km.state, x)
    np.testing.assert_array_equal(np.asarray(A), np.asarray(A_l))


# ---------------------------------------------------- unified route contract

@pytest.mark.parametrize("lam", [0.0, 0.5, 100.0])
def test_route_matches_predict_argmax(split, fed_mlp, fed_km, lam):
    """Each family's fused hot path must agree with predict + argmax."""
    x = split["test_global"]["x"][:17]
    for router in (fed_mlp[0], fed_km):
        A, C = router.predict(x)
        want = jnp.argmax(A - lam * C, axis=-1)
        np.testing.assert_array_equal(np.asarray(router.route(x, lam)),
                                      np.asarray(want))


def test_history_contract(split, fed_mlp):
    _, hist = fed_mlp
    assert set(hist) >= {"loss", "eval"}
    assert len(hist["loss"]) == FCFG.rounds
    _, khist = routers.fit_federated(
        routers.make("kmeans", RCFG), split["train"], FCFG,
        key=jax.random.PRNGKey(3),
        eval_fn=lambda r: r.num_models)
    assert khist["loss"] == [] and khist["eval"] == [5]


def test_num_models_override_honored_by_fit(split):
    """make(..., num_models=) must shape the fitted router even when the
    fit entry point does the initialization."""
    r, _ = routers.fit_federated(routers.make("mlp", RCFG, num_models=3),
                                 split["train"], FCFG,
                                 key=jax.random.PRNGKey(2), rounds=1)
    assert r.num_models == 3
    rl, _ = routers.fit_local(routers.make("mlp", RCFG, num_models=3),
                              client_slice(split["train"], 0), FCFG,
                              key=jax.random.PRNGKey(4), steps=3)
    assert rl.num_models == 3
    rk, _ = routers.fit_federated(routers.make("kmeans", RCFG,
                                               num_models=3),
                                  split["train"], FCFG,
                                  key=jax.random.PRNGKey(3))
    assert rk.num_models == 3


def test_fit_federated_mesh_contract(split):
    """The shard_map path honors eval_fn per round and names unsupported
    family kwargs instead of failing deep inside."""
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("clients",))
    seen = []
    r, hist = routers.fit_federated(
        routers.make("mlp", RCFG), split["train"], FCFG,
        key=jax.random.PRNGKey(2), rounds=2, mesh=mesh,
        eval_fn=lambda rt: seen.append(rt.num_models) or len(seen))
    assert r.num_models == 5
    assert len(hist["loss"]) == 2 and hist["eval"] == [1, 2]
    # hashable knobs (dp_sigma, aggregator, cohort) ride the mesh; the
    # pytree-carrying ones are named and rejected instead of silently
    # pinning the sharded round to one compiled fit.
    with pytest.raises(ValueError, match="mesh path supports only"):
        routers.fit_federated(routers.make("mlp", RCFG), split["train"],
                              FCFG, key=jax.random.PRNGKey(2), mesh=mesh,
                              freeze={"w": True})


def test_mesh_path_local_epochs_consistent_with_inprocess(split):
    """Both fit paths budget scan length as ⌈D_max/B⌉·local_epochs, and in
    both the active step count is gated per client at ⌈D_i/B⌉ inside
    client_update — so local_epochs must not change the mesh-path result,
    exactly as it does not change the in-process result."""
    import dataclasses
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("clients",))
    outs = []
    for le in (1, 2):
        fcfg = dataclasses.replace(FCFG, local_epochs=le)
        r, _ = routers.fit_federated(routers.make("mlp", RCFG),
                                     split["train"], fcfg,
                                     key=jax.random.PRNGKey(2), rounds=1,
                                     mesh=mesh)
        outs.append(r.state)
    _trees_equal(outs[0], outs[1])


def test_kmeans_rejects_unsupported_fit_options(split):
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("clients",))
    # the kmeans mesh path exists now, but only for the plain protocol —
    # combining it with client_mask names the conflict instead of
    # silently dropping one.
    with pytest.raises(ValueError, match="kmeans mesh path supports only"):
        routers.fit_federated(routers.make("kmeans", RCFG), split["train"],
                              FCFG, key=jax.random.PRNGKey(3), mesh=mesh,
                              client_mask=np.ones(3, np.float32))
    with pytest.raises(ValueError, match="unsupported options: dp_sigma"):
        routers.fit_federated(routers.make("kmeans", RCFG), split["train"],
                              FCFG, key=jax.random.PRNGKey(3), dp_sigma=0.1)


def test_gateway_rejects_d_emb_mismatch(fed_mlp):
    from repro.serve.gateway import RoutedServer
    with pytest.raises(ValueError, match="does not match the router"):
        RoutedServer(_dummy_pool(5), fed_mlp[0], d_emb=64)
    srv = RoutedServer(_dummy_pool(5), fed_mlp[0], d_emb=RCFG.d_emb)
    assert srv.d_emb == RCFG.d_emb


def test_incomplete_family_fails_at_instantiation():
    class HalfBaked(routers.Router):
        def init(self, key):
            return self

        def predict(self, x):
            return x, x

        def onboard_model(self, calib, **kw):
            return self

        def onboard_clients(self, data_new, **kw):
            return self

        def _state_num_models(self):
            return 0
        # no _fit_federated / _fit_local

    with pytest.raises(TypeError, match="abstract"):
        HalfBaked(RCFG)


def test_uninitialized_router_raises(split):
    r = routers.make("mlp", RCFG)
    with pytest.raises(ValueError, match="no state"):
        r.predict(split["test_global"]["x"][:2])
    with pytest.raises(NotImplementedError, match="nonparametric"):
        routers.make("kmeans", RCFG).loss({})


# --------------------------------------- every-registry-name contract suite

def test_fit_federated_dispatch_every_family(fed_any, split):
    """fit_federated works for every registered name and the result is a
    usable router: sane predictions and a fused route that agrees with
    predict + argmax."""
    router, hist = fed_any
    assert router.num_models == RCFG.num_models
    assert set(hist) >= {"loss", "eval"}
    x = split["test_global"]["x"][:19]
    A, C = router.predict(x)
    assert A.shape == (19, RCFG.num_models) and C.shape == A.shape
    assert bool(jnp.all((A >= 0) & (A <= 1)))
    for lam in (0.0, 0.7):
        want = jnp.argmax(A - lam * C, axis=-1)
        np.testing.assert_array_equal(np.asarray(router.route(x, lam)),
                                      np.asarray(want))


def test_fit_local_dispatch_every_family(fed_any, split):
    name = fed_any[0].name
    kw = {"steps": 8} if routers.get(name).parametric else {}
    r, hist = routers.fit_local(routers.make(name, RCFG),
                                client_slice(split["train"], 0), FCFG,
                                key=jax.random.PRNGKey(31), **kw)
    assert r.num_models == RCFG.num_models and "loss" in hist
    A, _ = r.predict(split["test_global"]["x"][:3])
    assert A.shape == (3, RCFG.num_models)


def test_save_load_round_trip(tmp_path, fed_any, split):
    x = split["test_global"]["x"][:5]
    router = fed_any[0]
    path = tmp_path / f"{router.name}.msgpack"
    router.save(path)
    restored = routers.load(path, RCFG)
    assert type(restored) is type(router)
    _trees_equal(router.state, restored.state)
    A0, C0 = router.predict(x)
    A1, C1 = restored.predict(x)
    np.testing.assert_array_equal(np.asarray(A0), np.asarray(A1))
    np.testing.assert_array_equal(np.asarray(C0), np.asarray(C1))


def test_with_state_round_trip_every_family(fed_any):
    """with_state / make(state=) rebuild an equivalent router (value
    semantics over the same pytree)."""
    router = fed_any[0]
    rebuilt = routers.make(router.name, RCFG, state=router.state)
    _trees_equal(router.state, rebuilt.state)
    assert rebuilt.num_models == router.num_models


# ---------------------------------------------------------------- onboarding

def test_onboard_model_via_interface(split, fed_mlp, fed_km):
    x = split["test_global"]["x"][:50]
    calib = {"x": x, "acc": jnp.full(50, 0.7), "cost": jnp.full(50, 0.3),
             "w": jnp.ones(50)}
    km6 = fed_km.onboard_model(calib)
    assert km6.num_models == fed_km.num_models + 1

    mlp_calib = flatten_clients(split["train"])
    mlp_calib = dict(mlp_calib)
    mlp_calib["m"] = jnp.where(mlp_calib["m"] == 0, 5, mlp_calib["m"])
    mlp6 = fed_mlp[0].onboard_model(mlp_calib, key=jax.random.PRNGKey(5),
                                    fcfg=FCFG, n_new=1, steps=10)
    assert mlp6.num_models == 6
    # the original router is untouched (value semantics)
    assert fed_mlp[0].num_models == 5


def test_onboard_clients_via_interface(split, fed_km):
    km2 = fed_km.onboard_clients(split["train"])
    assert float(jnp.sum(km2.state["n"])) == pytest.approx(
        2 * float(jnp.sum(fed_km.state["n"])), rel=1e-6)


# ----------------------------------------------- gateway pool validation

def _dummy_pool(n):
    from repro.serve.gateway import PoolModel
    return [PoolModel(f"m{i}", None, {}, 0.1) for i in range(n)]


def test_gateway_rejects_pool_size_mismatch(fed_mlp):
    from repro.serve.gateway import RoutedServer
    with pytest.raises(ValueError, match="M=5 .* pool has 3"):
        RoutedServer(_dummy_pool(3), fed_mlp[0])


def test_gateway_rejects_non_router(fed_mlp):
    from repro.serve.gateway import RoutedServer
    with pytest.raises(TypeError, match="routers.Router"):
        RoutedServer(_dummy_pool(5), fed_mlp[0].state)
    with pytest.raises(ValueError, match="no fitted state"):
        RoutedServer(_dummy_pool(5), routers.make("mlp", RCFG))


# ------------------------------------------- distill default-weight fix

def test_distill_weight_default_matches_explicit(split):
    """client_update's distill regularizer: the hoisted all-ones fallback
    must match an explicit w on unpadded data, and the reported first-step
    loss must equal the manual loss + β·distill computation."""
    di = client_slice(split["train"], 0)
    keep = np.where(np.asarray(di["w"]) > 0)[0]
    di = jax.tree.map(lambda a: a[keep], di)  # unpadded: w == 1 everywhere
    theta0 = R.init_mlp_router(jax.random.PRNGKey(0), RCFG)
    params = R.init_mlp_router(jax.random.PRNGKey(1), RCFG)

    explicit = F._distill_loss(params, theta0, di["x"], di["w"])
    fallback = F._distill_loss(params, theta0, di["x"],
                               jnp.ones(di["x"].shape[0]))
    np.testing.assert_allclose(np.asarray(explicit), np.asarray(fallback),
                               rtol=1e-6)

    beta = 0.7
    opt = F._make_opt(FCFG, "sgd")
    _, loss = F.client_update(params, di, jax.random.PRNGKey(2), RCFG, FCFG,
                              opt, max_steps=1, full_batch=True,
                              distill=(theta0, beta))
    manual = R.router_loss(params, di, RCFG) + beta * explicit
    np.testing.assert_allclose(float(loss), float(manual), rtol=1e-5)


# -------------------------------------------------- matrix-factorization zoo

def test_mf_fit_matches_direct_fedavg_with_mf_loss(split):
    """The mf family is plain core.federated.fedavg under its loss hook —
    same init convention, same key, bit-for-bit."""
    from repro.core import mf_router as MF
    key = jax.random.PRNGKey(40)
    router, hist = routers.fit_federated(routers.make("mf", RCFG),
                                         split["train"], FCFG, key=key)
    _, k_init = jax.random.split(key)
    init = MF.init_mf_router(k_init, RCFG)
    legacy, lhist = F.fedavg(key, split["train"], RCFG, FCFG, init=init,
                             loss_fn=MF.mf_loss)
    _trees_equal(router.state, legacy)
    assert hist["loss"] == lhist["loss"]


def test_mf_fit_with_aggregator_strategies(split):
    """The mf family rides the SAME aggregation strategies as mlp:
    secure-agg masks cancel at scale=0 (bit-identical to plain FedAvg),
    Gaussian DP perturbs the fit."""
    from repro.fed.aggregators import (GaussianDPAggregator,
                                       SecureAggAggregator)
    key = jax.random.PRNGKey(41)
    plain, _ = routers.fit_federated(routers.make("mf", RCFG),
                                     split["train"], FCFG, key=key)
    sa, _ = routers.fit_federated(routers.make("mf", RCFG), split["train"],
                                  FCFG, key=key,
                                  aggregator=SecureAggAggregator(scale=0.0))
    _trees_equal(plain.state, sa.state)
    dp, _ = routers.fit_federated(
        routers.make("mf", RCFG), split["train"], FCFG, key=key,
        aggregator=GaussianDPAggregator(sigma=0.3))
    diffs = [float(np.abs(np.asarray(a) - np.asarray(b)).max())
             for a, b in zip(jax.tree.leaves(plain.state),
                             jax.tree.leaves(dp.state))]
    assert max(diffs) > 0.0


def test_mf_onboard_model_trains_only_new_columns(split, fed_mf):
    router = fed_mf
    calib = dict(flatten_clients(split["train"]))
    calib["m"] = jnp.where(calib["m"] == 0, 5, calib["m"])
    r6 = router.onboard_model(calib, key=jax.random.PRNGKey(6), fcfg=FCFG,
                              n_new=1, steps=5)
    assert r6.num_models == 6 and router.num_models == 5
    # frozen base: projection + existing factor columns are untouched
    _trees_equal(router.state["proj"], r6.state["proj"])
    for leaf in ("acc_w", "cost_w"):
        np.testing.assert_array_equal(
            np.asarray(router.state["heads"][leaf]),
            np.asarray(r6.state["heads"][leaf][..., :5]))


# ------------------------------------------------------------ elo/Elo zoo

def test_elo_fit_is_one_shot(split):
    """Alg. 2 contract: no training rounds — rounds= is ignored, the loss
    history is empty, and eval_fn runs exactly once on the fitted router."""
    key = jax.random.PRNGKey(50)
    seen = []
    r1, h1 = routers.fit_federated(routers.make("elo", RCFG),
                                   split["train"], FCFG, key=key, rounds=1,
                                   eval_fn=lambda r: seen.append(1) or 7)
    r9, h9 = routers.fit_federated(routers.make("elo", RCFG),
                                   split["train"], FCFG, key=key, rounds=9)
    _trees_equal(r1.state, r9.state)
    assert h1["loss"] == [] and h1["eval"] == [7] and seen == [1]
    with pytest.raises(ValueError, match="unsupported"):
        routers.fit_federated(routers.make("elo", RCFG), split["train"],
                              FCFG, key=key, dp_sigma=0.1)


def test_elo_cold_start_state_is_hot_swappable(split, fed_elo):
    """init(key) must produce a SERVABLE state with the same pytree
    structure and shapes as a real fit — the FedLoop cold-start + first
    hot-swap contract."""
    fitted = fed_elo
    cold = routers.make("elo", RCFG).init(jax.random.PRNGKey(8))
    assert (jax.tree.structure(cold.state)
            == jax.tree.structure(fitted.state))
    for a, b in zip(jax.tree.leaves(cold.state),
                    jax.tree.leaves(fitted.state)):
        assert np.shape(a) == np.shape(b)
    x = split["test_global"]["x"][:9]
    assert cold.route(x, 0.5).shape == (9,)
    # the jittered prior must not collapse all cold traffic onto model 0
    wide = split["test_global"]["x"][:200]
    assert len(np.unique(np.asarray(cold.route(wide, 0.5)))) > 1


def test_elo_onboard_clients_is_exact_sum_merge(split, fed_elo):
    fitted = fed_elo
    again = fitted.onboard_clients(split["train"])
    np.testing.assert_allclose(np.asarray(again.state["n"]),
                               2 * np.asarray(fitted.state["n"]), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(again.state["anchors"]),
                                  np.asarray(fitted.state["anchors"]))


def test_elo_onboard_model_appends_rating_column(split, fed_elo):
    fitted = fed_elo
    x = split["test_global"]["x"][:60]
    calib = {"x": x, "acc": jnp.full(60, 0.9), "cost": jnp.full(60, 0.05),
             "w": jnp.ones(60)}
    r6 = fitted.onboard_model(calib)
    assert r6.num_models == 6 and fitted.num_models == 5
    # a cheap, strong new model must win cost-sensitive routing somewhere
    assert int((np.asarray(r6.route(x, 2.0)) == 5).sum()) > 0
