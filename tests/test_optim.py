"""Optimizer + schedule + checkpoint unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optim import SGD, AdamW, cosine_schedule, global_norm


def _quad_problem():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2
    return params, loss


def test_adamw_converges_on_quadratic():
    params, loss = _quad_problem()
    opt = AdamW(lr=0.1)
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-3


def test_sgd_matches_manual_step():
    params, loss = _quad_problem()
    opt = SGD(lr=0.1)
    state = opt.init(params)
    g = jax.grad(loss)(params)
    new, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.asarray(params["w"] - 0.1 * g["w"]),
                               rtol=1e-6)


def test_adamw_weight_decay_decoupled():
    """wd must shrink weights even at zero gradient."""
    params = {"w": jnp.ones(3)}
    opt = AdamW(lr=0.1, weight_decay=0.5)
    state = opt.init(params)
    g = {"w": jnp.zeros(3)}
    new, _ = opt.update(g, state, params)
    assert float(new["w"][0]) < 1.0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) <= float(lr(50)) <= 1.0
    assert float(lr(100)) >= 0.1 - 1e-6


def test_clip_is_noop_below_threshold():
    from repro.train.optim import clip_by_global_norm
    tree = {"a": jnp.array([0.1, 0.1])}
    out = clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]),
                               rtol=1e-6)
    assert float(global_norm(tree)) < 10.0
