"""Cross-silo mesh execution: the sharded federated fit, the sharded
serve engine, and the mesh-aware FedLoop must be BIT-FOR-BIT the
single-device paths on a fixed key — across mesh shapes — with donation
audited and zero retraces once warm. Subprocesses force the device count
(XLA_FLAGS must be set before jax initializes — never in this process).
"""
import os
import subprocess
import sys

ENV = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}


def _run(code: str, devices: int = 8, timeout: int = 560):
    full = (f"import os; os.environ['XLA_FLAGS']="
            f"'--xla_force_host_platform_device_count={devices}';" + code)
    out = subprocess.run([sys.executable, "-c", full], capture_output=True,
                         text=True, timeout=timeout, env=ENV)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    return out.stdout


_FIT_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
import repro.sharding as shd
from repro.config import FedConfig, RouterConfig
from repro.core import federated as F

def slab(N, D, d, M, seed=0):
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, D + 1, size=N)
    return {"x": rng.normal(size=(N, D, d)).astype(np.float32),
            "m": rng.integers(0, M, size=(N, D)).astype(np.int32),
            "acc": (rng.random((N, D)) < 0.5).astype(np.float32),
            "cost": rng.random((N, D)).astype(np.float32),
            "w": (np.arange(D)[None] < counts[:, None]).astype(np.float32)}

def maxdiff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

N, D, d, M = 8, 8, 8, 3
rcfg = RouterConfig(d_emb=d, num_models=M, hidden=(16,))
fcfg = FedConfig(num_clients=N, batch_size=4, lr=1e-2)
data = slab(N, D, d, M)
key = jax.random.PRNGKey(0)
"""


def test_fit_parity_across_mesh_shapes():
    """Plain FedAvg: mesh shapes {1, 2, 4} reproduce the in-process fit
    bit-for-bit — params AND per-round loss history. The degenerate
    1-client-per-device shape (8 devices, 8 clients) is parity only to
    float tolerance: XLA lowers the per-device batch-of-1 client_update
    through a different dot-reduction order than the vmapped batch."""
    out = _run(_FIT_PRELUDE + """
ref, ref_hist = F.fedavg(key, data, rcfg, fcfg, rounds=3)
for n_dev in (1, 2, 4, 8):
    mesh = shd.client_mesh(n_dev)
    dsh = shd.shard_clients(data, mesh)
    got, hist = F.fedavg(key, dsh, rcfg, fcfg, rounds=3, mesh=mesh)
    if n_dev < 8:
        assert maxdiff(ref, got) == 0.0, n_dev
        np.testing.assert_array_equal(ref_hist["loss"], hist["loss"])
    else:
        assert maxdiff(ref, got) < 1e-5, n_dev
        np.testing.assert_allclose(ref_hist["loss"], hist["loss"],
                                   atol=1e-5)
print("FIT_PARITY_OK")
""")
    assert "FIT_PARITY_OK" in out


def test_fit_parity_aggregators_and_cohort():
    """Every Aggregator strategy — including the sort-based and mask-based
    ones (trimmed-mean, median, secure-agg, norm-clip, buffered-async
    with staleness) — and cohort sampling run on the mesh bit-for-bit the
    in-process round, because the mesh round gathers the full update
    stack in global client order and aggregates replicated."""
    out = _run(_FIT_PRELUDE + """
from repro.fed.aggregators import (BufferedAsyncAggregator,
                                   MedianAggregator, NormClipAggregator,
                                   SecureAggAggregator,
                                   TrimmedMeanAggregator)
N4 = 4
data4 = slab(N4, 4, d, M, seed=1)
fcfg4 = FedConfig(num_clients=N4, batch_size=4, lr=1e-2)
mesh = shd.client_mesh(2)
d4 = shd.shard_clients(data4, mesh)
cases = [dict(aggregator=TrimmedMeanAggregator(trim_frac=0.25)),
         dict(aggregator=MedianAggregator()),
         dict(aggregator=SecureAggAggregator(scale=0.1)),
         dict(aggregator=NormClipAggregator(clip=0.5)),
         dict(aggregator=BufferedAsyncAggregator(staleness_alpha=0.5),
              staleness=np.arange(N4, dtype=np.float32)),
         dict(dp_sigma=1e-3)]
for kw in cases:
    ref, rh = F.fedavg(key, data4, rcfg, fcfg4, rounds=2, **kw)
    got, gh = F.fedavg(key, d4, rcfg, fcfg4, rounds=2, mesh=mesh, **kw)
    assert maxdiff(ref, got) == 0.0, kw
    # params are bit-for-bit; the loss DIAGNOSTIC is psum-reduced on the
    # mesh, so its float summation order may differ by rounding.
    np.testing.assert_allclose(rh["loss"], gh["loss"], atol=1e-6)
# cohort sampling: the masked-psum cohort exchange is bit-for-bit as long
# as each device trains >= 2 cohort clients (1-per-device hits the same
# batch-of-1 dot lowering as the degenerate full fit).
dsh8 = shd.shard_clients(data, mesh)
ref, _ = F.fedavg(key, data, rcfg, fcfg, rounds=2, cohort=4)
got, _ = F.fedavg(key, dsh8, rcfg, fcfg, rounds=2, cohort=4, mesh=mesh)
assert maxdiff(ref, got) == 0.0
print("AGG_PARITY_OK")
""", timeout=560)
    assert "AGG_PARITY_OK" in out


def test_fit_families_parity_on_mesh():
    """The mf (loss_fn) and kmeans (one-shot protocol) families ride the
    mesh bit-for-bit through the unified fit entry point."""
    out = _run(_FIT_PRELUDE + """
from repro import routers
rcfg_f = RouterConfig(d_emb=d, num_models=M, hidden=(16,), mf_rank=4,
                      k_local=2, k_global=3)
mesh = shd.client_mesh(4)
dsh = shd.shard_clients(data, mesh)
for family in ("mf", "kmeans"):
    r = routers.make(family, rcfg_f)
    r = r.init(jax.random.PRNGKey(1)) if family == "mf" else r
    ref, _ = routers.fit_federated(r, data, fcfg, key=key, rounds=2)
    got, _ = routers.fit_federated(r, dsh, fcfg, key=key, rounds=2,
                                   mesh=mesh)
    assert maxdiff(ref.state, got.state) == 0.0, family
print("FAMILY_PARITY_OK")
""")
    assert "FAMILY_PARITY_OK" in out


def test_mesh_fit_zero_retrace_and_cohort_redraws():
    """The compiled mesh fit is built once: repeat fits — including fresh
    cohort draws from different keys — append nothing to FIT_TRACE_LOG."""
    out = _run(_FIT_PRELUDE + """
mesh = shd.client_mesh(4)
dsh = shd.shard_clients(data, mesh)
F.fedavg(key, dsh, rcfg, fcfg, rounds=2, cohort=4, mesh=mesh)
n0 = len(F.FIT_TRACE_LOG)
for s in range(3):
    F.fedavg(jax.random.PRNGKey(s + 1), dsh, rcfg, fcfg, rounds=2,
             cohort=4, mesh=mesh)
assert len(F.FIT_TRACE_LOG) == n0, F.FIT_TRACE_LOG
print("RETRACE_OK")
""")
    assert "RETRACE_OK" in out


def test_mesh_fit_donation_audit():
    """Memory contract of the mesh fit, in bytes. (1) The compiled fit
    sees the slab SHARDED: per-device argument bytes are ~slab/n_dev, and
    temp memory never materializes a full second copy of the slab.
    (2) ``donate_data=True`` consumes the sharded slab — its buffers are
    deleted after the fit and total ``jax.live_arrays()`` bytes drop by
    the slab, so a per-sync harvest stack doesn't linger until GC."""
    out = _run(_FIT_PRELUDE + """
from repro.core import mlp_router as R
live = lambda: sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in jax.live_arrays())
Nb, Db = 16, 64
big = slab(Nb, Db, d, M, seed=2)
fcfgb = FedConfig(num_clients=Nb, batch_size=16, lr=1e-2)
mesh = shd.client_mesh(4)
dsh = shd.shard_clients(jax.tree.map(jnp.asarray, big), mesh)
slab_bytes = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                 for a in jax.tree.leaves(dsh))

fit = F._scan_fit_cached(rcfg, fcfgb, "adamw", 4, False, 0.0, None, None,
                         None, mesh, 2, True)
ma = fit.lower(R.init_mlp_router(key=key, cfg=rcfg), key,
               dsh).compile().memory_analysis()
assert ma.argument_size_in_bytes < slab_bytes // 2, (
    ma.argument_size_in_bytes, slab_bytes)
assert ma.temp_size_in_bytes < slab_bytes, (
    ma.temp_size_in_bytes, slab_bytes)

base = live()
params, _ = F.fedavg(key, dsh, rcfg, fcfgb, rounds=2, mesh=mesh,
                     donate_data=True)
jax.block_until_ready(params)
assert all(a.is_deleted() for a in jax.tree.leaves(dsh))
after = live()
assert after <= base - slab_bytes // 2, (base, after, slab_bytes)
print("DONATION_OK")
""")
    assert "DONATION_OK" in out


_ENGINE_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
import repro.sharding as shd
from repro import routers
from repro.config import ModelConfig, RouterConfig
from repro.models import init_params
from repro.serve import gateway
from repro.serve.engine import EngineConfig, TRACE_LOG

TINY = ModelConfig(name="tiny-dense-mesh", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                   head_dim=16)

def make_server(mesh, ecfg):
    router = routers.make(
        "kmeans", RouterConfig(d_emb=16, num_models=1),
        state={"centroids": jnp.zeros((1, 16)),
               "A": jnp.array([[0.9]]), "C": jnp.array([[0.1]]),
               "n": jnp.ones((1, 1))})
    pool = [gateway.PoolModel("tiny", TINY,
                              init_params(jax.random.PRNGKey(0), TINY),
                              0.1)]
    return gateway.RoutedServer(pool, router, engine_cfg=ecfg, mesh=mesh)

PROMPTS = ["the quick brown fox", "jumps over", "a lazy dog today ok",
           "one two three", "counting to five now", "zig zag", "rome as"]
MAXN = [5, 3, 8, 6, 4, 7, 5]

def run(server):
    rids = [server.submit(p, lam=0.5, max_new_tokens=m)
            for p, m in zip(PROMPTS, MAXN)]
    done = server.drain()
    return [done[r].tolist() for r in rids]
"""


def test_engine_token_parity_sharded_vs_solo():
    """Slot-parallel ("data") and mixed ("data","heads") meshes emit
    tokens bit-identical to the solo engine on uniform AND paged pools,
    and a warm mesh engine decodes with zero retraces."""
    out = _run(_ENGINE_PRELUDE + """
for page_size in (None, 16):
    ecfg = EngineConfig(slots=8, max_seq=64, chunk=4, page_size=page_size)
    solo = run(make_server(None, ecfg))
    for mk in (lambda: shd.data_mesh(2), lambda: shd.data_mesh(8),
               lambda: shd.make_mesh({"data": 2, "heads": 1})):
        assert run(make_server(mk(), ecfg)) == solo, (page_size, mk)
srv = make_server(shd.data_mesh(8),
                  EngineConfig(slots=8, max_seq=64, chunk=4))
run(srv)
n0 = len(TRACE_LOG)
run(srv)
assert len(TRACE_LOG) == n0
print("ENGINE_PARITY_OK")
""")
    assert "ENGINE_PARITY_OK" in out


def test_engine_spec_decode_on_mesh():
    """Speculative decode (draft pools + verify) on a sharded engine stays
    bit-identical to the solo speculative engine."""
    out = _run(_ENGINE_PRELUDE + """
ecfg = EngineConfig(slots=4, max_seq=64, chunk=4, page_size=None, spec_k=3)
solo = run(make_server(None, ecfg))
assert run(make_server(shd.data_mesh(2), ecfg)) == solo
print("SPEC_PARITY_OK")
""", devices=2)
    assert "SPEC_PARITY_OK" in out


def test_fedloop_mesh_sync_and_checkpoint():
    """FedLoopConfig(mesh=...): the mesh sync is bit-for-bit the solo
    sync; save() under a live mesh restores into a loop on a DIFFERENT
    mesh shape (state checkpoints as host arrays, placement is per-fit)."""
    out = _run("""
import pathlib, tempfile
import jax, jax.numpy as jnp, numpy as np
import repro.sharding as shd
from repro import routers
from repro.config import FedConfig, ModelConfig, RouterConfig
from repro.fed.harvest import HarvestStore
from repro.fed.loop import FedLoop, FedLoopConfig
from repro.models import init_params
from repro.serve.engine import EngineConfig
from repro.serve.gateway import PoolModel, RoutedServer

TINY = ModelConfig(name="fedloop-tiny", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                   head_dim=16, dtype="float32")
D_EMB, N_CLIENTS, CAP = 8, 3, 32
RCFG = RouterConfig(d_emb=D_EMB, num_models=2, hidden=(16, 16),
                    dropout=0.0)
FCFG = FedConfig(num_clients=N_CLIENTS, participation=1.0, batch_size=16,
                 lr=3e-3)

def make_loop(mesh, engine_mesh=None):
    params = init_params(jax.random.PRNGKey(0), TINY)
    pool = [PoolModel("m0", TINY, params, 0.1),
            PoolModel("m1", TINY, params, 0.5)]
    router = routers.make("mlp", RCFG).init(jax.random.PRNGKey(1))
    harvest = HarvestStore(D_EMB, capacity=CAP, clients=range(N_CLIENTS))
    srv = RoutedServer(pool, router, harvest=harvest,
                       engine_cfg=EngineConfig(slots=4, max_seq=32,
                                               chunk=4, page_size=8),
                       mesh=engine_mesh)
    return srv, FedLoop(srv, FCFG, key=jax.random.PRNGKey(7),
                        cfg=FedLoopConfig(sync_every=10**9,
                                          rounds_per_sync=2,
                                          min_samples=1, mesh=mesh))

def drive(srv, loop, n):
    rng = np.random.default_rng(0)
    for i in range(n):
        x = rng.normal(size=(D_EMB,)).astype(np.float32)
        rid = srv.submit("three word prompt", lam=0.5, max_new_tokens=4,
                         client_id=i % N_CLIENTS, x=x)
        m = srv.routed_model(rid)
        srv.report_outcome(rid, float(rng.random() < 0.4 + 0.3 * m),
                           0.1 + 0.4 * m)
        loop.step()
    loop.drain()

srv_m, loop_m = make_loop(shd.client_mesh(3),
                          engine_mesh=shd.data_mesh(2))
drive(srv_m, loop_m, 9)
loop_m.sync()
srv_s, loop_s = make_loop(None)
drive(srv_s, loop_s, 9)
loop_s.sync()
for a, b in zip(jax.tree.leaves(loop_m.server.router.state),
                jax.tree.leaves(loop_s.server.router.state)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

p = pathlib.Path(tempfile.mkdtemp()) / "loop.ckpt"
loop_m.save(p)
srv_r, loop_r = make_loop(shd.client_mesh(1))
loop_r.restore(p)
for a, b in zip(jax.tree.leaves(loop_m.server.router.state),
                jax.tree.leaves(loop_r.server.router.state)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
drive(srv_r, loop_r, 3)      # the restored loop syncs on ITS mesh shape
loop_r.sync()
print("FEDLOOP_MESH_OK")
""", devices=6)
    assert "FEDLOOP_MESH_OK" in out


def test_mesh_validation_errors():
    """Ragged stacks, non-dividing cohorts, and pytree-knob requests fail
    with actionable errors instead of silently falling back; padding via
    pad_client_axis makes a ragged stack mesh-eligible."""
    out = _run(_FIT_PRELUDE + """
mesh = shd.client_mesh(4)
rag = slab(6, D, d, M, seed=3)
try:
    shd.shard_clients(rag, mesh)
    raise SystemExit("ragged stack placed")
except ValueError as e:
    assert "pad_client_axis" in str(e)
padded, stal = F.pad_client_axis(rag, 4, np.ones((6,), np.float32))
assert padded["x"].shape[0] == 8 and stal.shape[0] == 8
assert float(padded["w"][6:].sum()) == 0.0
dsh = shd.shard_clients(padded, mesh)
fcfg8 = FedConfig(num_clients=8, batch_size=4, lr=1e-2)
F.fedavg(key, dsh, rcfg, fcfg8, rounds=1, mesh=mesh)
try:
    F.fedavg(key, dsh, rcfg, fcfg8, rounds=1, mesh=mesh, cohort=2)
    raise SystemExit("cohort=2 on a 4-device mesh fit")
except ValueError as e:
    assert "cohort" in str(e)
try:
    F.fedavg(key, dsh, rcfg, fcfg8, rounds=1, mesh=mesh,
             freeze={"layers": True})
    raise SystemExit("freeze on the mesh path fit")
except ValueError as e:
    assert "mesh path supports only" in str(e)
print("VALIDATION_OK")
""")
    assert "VALIDATION_OK" in out
