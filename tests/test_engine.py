"""Continuous-batching engine: requests admitted at arbitrary chunk
boundaries into shared slot pools must produce tokens bit-identical to the
single-request scan path, reuse freed slots without leaking state between
occupants, and compile nothing once the (config, bucket) programs are warm.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import routers
from repro.config import ModelConfig, RouterConfig
from repro.serve import gateway
from repro.serve.engine import EngineConfig, ServeEngine

TINY = ModelConfig(name="tiny-dense-eng", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                   head_dim=16)
ECFG = EngineConfig(slots=2, max_seq=32, chunk=4)   # tiny: forces slot reuse


def _make_server(ecfg=ECFG):
    from repro.models import init_params
    router = routers.make(
        "kmeans", RouterConfig(d_emb=16, num_models=1),
        state={"centroids": jnp.zeros((1, 16)),
               "A": jnp.array([[0.9]]), "C": jnp.array([[0.1]]),
               "n": jnp.ones((1, 1))})
    pool = [gateway.PoolModel("tiny", TINY,
                              init_params(jax.random.PRNGKey(0), TINY), 0.1)]
    return gateway.RoutedServer(pool, router, engine_cfg=ecfg)


@pytest.fixture(scope="module")
def server():
    return _make_server()


PROMPTS = ["the quick brown fox", "jumps over", "a lazy dog today ok fine",
           "one two three", "counting up to five now", "zig zag",
           "when in rome do as"]


def _solo(server, prompt, max_new):
    """Reference: the request served alone on the per-request scan path."""
    out = server.generate([prompt], lam=0.5, max_new_tokens=max_new,
                          engine=False)
    return out["results"][0]["tokens"]


def test_interleaved_admissions_token_parity(server):
    """More requests than slots, different lengths (max_new % chunk != 0
    included): requests join mid-flight as slots free up, and every one
    matches its single-request reference bit-for-bit."""
    max_news = [5, 3, 8, 6, 4, 7, 5]
    rids = [server.submit(p, lam=0.5, max_new_tokens=m)
            for p, m in zip(PROMPTS, max_news)]
    done = server.drain()
    assert sorted(done) == sorted(rids)
    for p, m, rid in zip(PROMPTS, max_news, rids):
        assert done[rid].tolist() == _solo(server, p, m), p


def test_step_makes_incremental_progress(server):
    """step() emits chunk tokens per busy lane; requests shorter than one
    chunk finish on the first step, longer ones keep their slot."""
    r_short = server.submit("alpha beta", max_new_tokens=2)
    r_long = server.submit("gamma delta epsilon", max_new_tokens=12)
    finished = dict(server.step())
    assert r_short in finished and len(finished[r_short]) == 2
    assert r_long not in finished
    done = server.drain()
    assert done[r_long].tolist() == _solo(server, "gamma delta epsilon", 12)


def test_slot_reuse_and_free(server):
    """Slots recycle: after drain every lane is fully free again, and a
    slot's next occupant never sees the previous occupant's cache (the
    validity frontier masks it) — parity on reused slots proves it."""
    for wave in range(3):                      # 3 waves through 2 slots
        rids = {server.submit(p, lam=0.5, max_new_tokens=4): p
                for p in PROMPTS[:4]}
        done = server.drain()
        for rid, p in rids.items():
            assert done[rid].tolist() == _solo(server, p, 4), (wave, p)
    for lane in server.engine._lanes.values():
        assert sorted(lane.free) == list(range(ECFG.slots))
        assert not lane.active and not lane.queue


def test_selective_drain_keeps_other_results(server):
    ra = server.submit("first stream", max_new_tokens=3)
    rb = server.submit("second stream", max_new_tokens=3)
    got = server.engine.drain([rb])
    assert set(got) == {rb}
    rest = server.drain()
    assert ra in rest and rb not in rest
    with pytest.raises(KeyError):
        server.engine.drain([10 ** 9])


def test_warm_engine_compiles_nothing(server):
    """After the buckets are warm, interleaved traffic with new prompts,
    lengths, λ and admission orders must not trace anything."""
    for p, m in zip(PROMPTS, [5, 3, 8, 6, 4, 7, 5]):   # warm all buckets
        server.submit(p, lam=0.5, max_new_tokens=m)
    server.drain()
    gateway.reset_trace_log()   # far from maxlen — a len() change is real
    n0 = len(gateway.TRACE_LOG)
    rids = [server.submit(p, lam=1.5, max_new_tokens=m) for p, m in
            zip(["x y z w", "q r", "a b c d e f", "hello there you"],
                [4, 8, 5, 6])]
    done = server.drain()
    assert len(gateway.TRACE_LOG) == n0, \
        f"unexpected retrace: {list(gateway.TRACE_LOG)[n0:]}"
    assert sorted(done) == sorted(rids)


def test_trace_log_bounded():
    """TRACE_LOG is a bounded deque (long-running servers don't leak) with
    an explicit reset helper."""
    assert gateway.TRACE_LOG.maxlen is not None
    before = list(gateway.TRACE_LOG)
    for i in range(gateway.TRACE_LOG.maxlen + 10):
        gateway.TRACE_LOG.append(("filler", i))
    assert len(gateway.TRACE_LOG) == gateway.TRACE_LOG.maxlen
    gateway.reset_trace_log()
    assert len(gateway.TRACE_LOG) == 0
    gateway.TRACE_LOG.extend(before)           # restore for other tests


def test_ssm_arch_rejected_with_fallback_hint():
    from repro.config import SSMConfig
    ssm_cfg = ModelConfig(name="tiny-ssm-eng", arch_type="ssm", n_layers=2,
                          d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                          vocab=97, head_dim=16,
                          ssm=SSMConfig(d_state=16, head_dim=32))
    # submit must reject on arch alone — params never touched
    pm = gateway.PoolModel("ssm", ssm_cfg, {}, 0.1)
    eng = ServeEngine([pm], ECFG)
    with pytest.raises(TypeError, match="falls back"):
        eng.submit(0, np.array([1, 2, 3], np.int32), 4)


def test_prompt_too_long_for_slot_rejected(server):
    with pytest.raises(ValueError, match="max_seq"):
        server.engine.submit(0, np.arange(1, 30, dtype=np.int32), 8)


def test_fits_accounts_for_pow2_prefill_bucket():
    """A prompt whose pow2 prefill bucket exceeds max_seq must be rejected
    cleanly even when raw prompt + decode would fit (non-pow2 max_seq)."""
    srv = _make_server(EngineConfig(slots=2, max_seq=48, chunk=8))
    assert not srv.engine.fits(33, 8)          # bucket 64 > 48
    assert srv.engine.fits(32, 8)              # 32 + 8 = 40 <= 48
    with pytest.raises(ValueError, match="pow2 bucket"):
        srv.engine.submit(0, np.arange(1, 34, dtype=np.int32), 8)


def test_generate_falls_back_for_oversize_prompt(server):
    """generate() must serve a prompt that exceeds a slot region on the
    per-call path instead of raising — same tokens as engine=False."""
    long_prompt = " ".join(f"w{i}" for i in range(30))   # bucket 32 > 32-4
    out = server.generate([long_prompt, "short one"], lam=0.5,
                          max_new_tokens=4)
    ref = server.generate([long_prompt], lam=0.5, max_new_tokens=4,
                          engine=False)
    assert out["results"][0]["tokens"] == ref["results"][0]["tokens"]
    assert len(out["results"][1]["tokens"]) == 4


def test_done_buffer_bounded():
    """A server that consumes step() results and never drains must not
    accumulate finished requests beyond EngineConfig.done_buffer."""
    srv = _make_server(EngineConfig(slots=2, max_seq=32, chunk=4,
                                    done_buffer=3))
    for i in range(8):
        srv.submit(f"request number {i}", max_new_tokens=2)
    while srv.engine.busy:
        srv.step()
    assert len(srv.engine._done) <= 3


def test_streaming_drain_survives_done_buffer_eviction():
    """The README streaming pattern (submit N, then drain()) must return
    every request even when N exceeds done_buffer."""
    srv = _make_server(EngineConfig(slots=2, max_seq=32, chunk=4,
                                    done_buffer=3))
    rids = [srv.submit(f"stream prompt number {i}", max_new_tokens=4)
            for i in range(8)]
    out = srv.drain()
    assert sorted(out) == sorted(rids)
    assert all(len(v) == 4 for v in out.values())


def test_drain_survives_done_buffer_eviction():
    """drain(rids) / generate() must deliver every request of a batch
    larger than done_buffer — wanted rids are captured as they finish,
    not recovered from the evicting buffer."""
    srv = _make_server(EngineConfig(slots=2, max_seq=32, chunk=4,
                                    done_buffer=3))
    prompts = [f"batch prompt number {i}" for i in range(7)]
    out = srv.generate(prompts, lam=0.5, max_new_tokens=4)
    for p, r in zip(prompts, out["results"]):
        assert r["tokens"] == _solo(srv, p, 4), p


# ---------------------------------------------------------------------------
# Paged pool (EngineConfig.page_size — the default engine regime)
# ---------------------------------------------------------------------------


def test_uniform_and_paged_engines_same_tokens():
    """The paged pool is a memory-layout change only: same prompts through
    a paged and a uniform engine produce identical tokens (both already
    bit-match solo serving; this pins them to each other directly)."""
    paged = _make_server(EngineConfig(slots=2, max_seq=32, chunk=4,
                                      page_size=8))
    uniform = _make_server(EngineConfig(slots=2, max_seq=32, chunk=4,
                                        page_size=None))
    for srv in (paged, uniform):
        assert srv.engine.ecfg.page_size == (8 if srv is paged else None)
    outs = []
    for srv in (paged, uniform):
        rids = [srv.submit(p, lam=0.5, max_new_tokens=m)
                for p, m in zip(PROMPTS[:4], [5, 3, 8, 6])]
        done = srv.drain()
        outs.append([done[r].tolist() for r in rids])
    assert outs[0] == outs[1]


def test_paged_pages_recycle_and_pool_restores(server):
    """After drain every page is back on the free list exactly once and
    the table maps everything to the trash page — no leak, no double
    free. (ECFG's default page_size makes the module server paged.)"""
    for _ in range(2):
        for p in PROMPTS[:4]:
            server.submit(p, lam=0.5, max_new_tokens=4)
        server.drain()
    for lane in server.engine._lanes.values():
        assert lane.paged
        assert sorted(lane.pt.free) == \
            list(range(1, server.engine.ecfg.resolved_pages + 1))
        assert not lane.pt._held and (lane.pt.table == 0).all()


def test_submit_rejects_request_larger_than_page_pool():
    """A request whose page need exceeds the whole pool can never be
    admitted and must be rejected at submit (distinct from the max_seq
    bound — region fits, pages don't)."""
    srv = _make_server(EngineConfig(slots=2, max_seq=64, chunk=4,
                                    page_size=16, pages=2))
    assert not srv.engine.fits(33, 8)        # bucket 64 → 4 pages > 2
    with pytest.raises(ValueError, match="page pool"):
        srv.engine.submit(0, np.arange(1, 34, dtype=np.int32), 8)


def test_paged_pool_fewer_bytes_same_concurrency():
    """The acceptance metric in miniature: with pages sized for a
    short-request mix, the paged pool holds the same number of in-flight
    requests in strictly fewer KV bytes than uniform max_seq slots."""
    short = [f"q {i}" for i in range(8)]
    paged = _make_server(EngineConfig(slots=8, max_seq=64, chunk=4,
                                      page_size=16, pages=16))
    uniform = _make_server(EngineConfig(slots=8, max_seq=64, chunk=4,
                                        page_size=None))
    outs = []
    for srv in (paged, uniform):
        rids = [srv.submit(p, lam=0.5, max_new_tokens=4) for p in short]
        done = srv.engine.drain(rids)
        assert srv.engine.peak_active == 8   # both fully concurrent
        outs.append([done[r].tolist() for r in rids])
    assert outs[0] == outs[1]
    assert paged.engine.kv_pool_bytes() < uniform.engine.kv_pool_bytes()


def test_admission_latency_instrumented(server):
    """Every admission appends its queue wait (submit → prefill) to the
    bounded admission_lat deque — the bench_paged p99 source."""
    n0 = len(server.engine.admission_lat)
    for p in PROMPTS[:3]:
        server.submit(p, lam=0.5, max_new_tokens=4)
    server.drain()
    lat = list(server.engine.admission_lat)[n0:]
    assert len(lat) == 3 and all(v >= 0.0 for v in lat)


def test_paged_decode_zero_retrace_mixed_page_counts():
    """Satellite: warm paged-decode steps trigger ZERO retraces across
    batches whose rows hold different page counts — the (slots, max_pages)
    table shape is static, so 1-page and 5-page requests share one
    compiled chunk program. The whole schedule (coalesced admissions
    included) is replayed identically after warmup and must not add one
    TRACE_LOG entry."""
    srv = _make_server(EngineConfig(slots=4, max_seq=32, chunk=4,
                                    page_size=4))

    def schedule():
        mixed = [("tiny", 4),                               # 1-2 pages
                 (" ".join(f"w{i}" for i in range(20)), 4),  # many pages
                 ("a b c", 8),
                 (" ".join(f"v{i}" for i in range(14)), 4)]
        rids = [srv.submit(p, lam=0.5, max_new_tokens=m) for p, m in mixed]
        return srv.engine.drain(rids)

    schedule()                                  # warm every program
    gateway.reset_trace_log()
    n0 = len(gateway.TRACE_LOG)
    out = schedule()                            # identical replay
    assert len(out) == 4
    assert len(gateway.TRACE_LOG) == n0, \
        f"paged retrace: {list(gateway.TRACE_LOG)[n0:]}"
