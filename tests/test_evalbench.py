"""RouterBench-style evaluation harness (repro.evalbench): AIQ metric
properties, seed-deterministic robustness scenarios, the adversarial
routing-flip budget discipline, and the offline federated-vs-client-local
benchmark contract (the CI floor itself runs on BENCH_routerbench.smoke.json
via benchmarks/perf_suite.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import routers
from repro.config import FedConfig, RouterConfig
from repro.data.partition import federated_split
from repro.evalbench.harness import (SCENARIOS, eval_scenarios,
                                     offline_routerbench)
from repro.evalbench.metrics import aiq, reference_points, sweep
from repro.evalbench.perturb import adversarial_queries, paraphrase_drift
from repro.evalbench.pools import make_pool_corpus, pool_table

RCFG = RouterConfig(d_emb=12, num_models=4, hidden=(16, 16), dropout=0.0,
                    k_local=3, k_global=4, mf_rank=6)
FCFG = FedConfig(num_clients=3, rounds=2, batch_size=32, lr=3e-3, seed=0)


@pytest.fixture(scope="module")
def corpus():
    return make_pool_corpus(jax.random.PRNGKey(0), n_models=4,
                            n_queries=500, n_tasks=3, d_emb=12)


@pytest.fixture(scope="module")
def split(corpus):
    return federated_split(jax.random.PRNGKey(1), corpus, FCFG)


@pytest.fixture(scope="module")
def fitted(split):
    """A one-shot fit (fast, deterministic) to probe the scenarios with."""
    r, _ = routers.fit_federated(routers.make("kmeans", RCFG),
                                 split["train"], FCFG,
                                 key=jax.random.PRNGKey(2))
    return r


# ------------------------------------------------------------------ metrics

def test_pool_table_accounts_for_every_query(corpus):
    table = pool_table(corpus)
    assert len(table) == 4
    assert sum(row["wins"] for row in table) == 500
    assert all(0.0 <= row["mean_acc"] <= 1.0 for row in table)


def test_reference_points_scale_and_ordering(split):
    ref = reference_points(split["test_global"])
    for k in ("zero_router_aiq", "best_single_aiq", "random_aiq",
              "oracle_aiq"):
        assert 0.0 <= ref[k] <= 1.0
    # the oracle routes per query with the true tables — nothing beats it
    assert ref["oracle_aiq"] >= ref["best_single_aiq"] - 1e-9
    assert ref["oracle_aiq"] >= ref["random_aiq"] - 1e-9
    assert len(ref["models"]) == 4


def test_sweep_scores_router_between_floor_and_oracle(split, fitted):
    test = split["test_global"]
    res = sweep(fitted.predict, test)
    ref = reference_points(test)
    assert 0.0 <= res["aiq"] <= ref["oracle_aiq"] + 1e-9
    assert len(res["costs"]) == len(res["accs"])


def test_aiq_of_single_point_is_its_accuracy():
    assert aiq(np.array([0.4]), np.array([0.8])) == pytest.approx(0.8)


# ---------------------------------------------------------------- scenarios

def test_paraphrase_drift_is_seeded_and_scaled(split):
    x = split["test_global"]["x"][:32]
    a = paraphrase_drift(jax.random.PRNGKey(3), x, 0.25)
    b = paraphrase_drift(jax.random.PRNGKey(3), x, 0.25)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = paraphrase_drift(jax.random.PRNGKey(4), x, 0.25)
    assert float(np.abs(np.asarray(a) - np.asarray(c)).max()) > 0
    clean = paraphrase_drift(jax.random.PRNGKey(3), x, 0.0)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(x))


def test_adversarial_queries_flip_within_budget(split, fitted):
    x = np.asarray(split["test_global"]["x"][:64])
    budget, lam = 0.35, 0.5
    x_adv, info = adversarial_queries(fitted, x, lam, budget=budget)
    assert x_adv.shape == x.shape and x_adv.dtype == np.float32
    m0 = np.asarray(fitted.route(x, lam))
    m1 = np.asarray(fitted.route(x_adv, lam))
    changed = np.any(x_adv != x.astype(np.float32), axis=1)
    # every perturbed query flips the decision, within the norm budget
    assert np.all(m0[changed] != m1[changed])
    rel = (np.linalg.norm(x_adv - x, axis=1)
           / np.maximum(np.linalg.norm(x, axis=1), 1e-12))
    assert np.all(rel[changed] <= budget + 1e-6)
    assert info["flip_rate"] == pytest.approx(changed.mean())
    # deterministic: the attack only uses the router's decision boundary
    x_adv2, info2 = adversarial_queries(fitted, x, lam, budget=budget)
    np.testing.assert_array_equal(x_adv, x_adv2)
    assert info == info2


def test_eval_scenarios_shape(split, fitted):
    res = eval_scenarios(fitted, split["test_global"],
                         jax.random.PRNGKey(5))
    assert set(res) == set(SCENARIOS)
    for sc in SCENARIOS:
        assert 0.0 <= res[sc]["aiq"] <= 1.0
    assert "flip_rate" in res["adversarial"]


# ------------------------------------------------------------------ harness

def test_offline_routerbench_contract(corpus):
    """Structure + determinism of the offline benchmark on a tiny run (the
    federated ≥ client-local floor is enforced on the CI-sized smoke
    bench, not this micro config)."""
    res = offline_routerbench(jax.random.PRNGKey(7), rcfg=RCFG, fcfg=FCFG,
                              families=("kmeans", "elo"), corpus=corpus,
                              local_steps=5)
    assert res["n_models"] == 4 and res["n_clients"] == 3
    assert set(res["families"]) == {"kmeans", "elo"}
    for fam in res["families"].values():
        assert fam["clients_fit"] >= 1
        for side in ("federated", "client_local"):
            assert set(fam[side]) == set(SCENARIOS)
            for sc in SCENARIOS:
                assert 0.0 <= fam[side][sc]["aiq"] <= 1.0
    res2 = offline_routerbench(jax.random.PRNGKey(7), rcfg=RCFG, fcfg=FCFG,
                               families=("kmeans", "elo"), corpus=corpus,
                               local_steps=5)
    assert res == res2
