"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates a REDUCED variant (≤2 layers,
d_model ≤ 512, ≤4 experts) and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs. Decode-capable archs also
run one decode step.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, get_config, list_archs
from repro.models import (decode_step, forward, init_decode_cache,
                          init_params, loss_fn)
from repro.train.optim import AdamW

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=32):
    kb, kl = jax.random.split(key)
    labels = jax.random.randint(kl, (B, S), 0, cfg.vocab)
    if cfg.frontend is not None:
        return {"embeds": jax.random.normal(kb, (B, S, cfg.d_model)),
                "labels": labels}
    return {"tokens": jax.random.randint(kb, (B, S), 0, cfg.vocab),
            "labels": labels}


def test_all_archs_registered():
    assert len(REGISTRY) == 10
    kinds = {c.arch_type for c in REGISTRY.values()}
    assert kinds == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_limits(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= max(2, cfg.hybrid_attn_period or 2)
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), q_chunk=16)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    opt = AdamW(lr=1e-3, clip_norm=1.0)
    opt_state = opt.init(params)
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch, q_chunk=16)
    new_params, _ = opt.update(grads, opt_state, params)
    assert jnp.isfinite(loss)
    moved = jax.tree.reduce(
        lambda a, kv: a + float(jnp.sum(jnp.abs(kv.astype(jnp.float32)))),
        jax.tree.map(lambda a, b: a.astype(jnp.float32)
                     - b.astype(jnp.float32), new_params, params), 0.0)
    assert moved > 0.0  # the step actually updated the weights
    for g in jax.tree.leaves(grads):
        assert not bool(jnp.isnan(g).any())


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).supports_decode])
def test_decode_step_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    cache = init_decode_cache(cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, new_cache = decode_step(params, cache, cfg, tokens=tok, pos=0)
    assert logits.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_encoder_only_has_no_decode():
    cfg = get_config("hubert-xlarge")
    assert not cfg.supports_decode
    assert not cfg.causal


@pytest.mark.parametrize("arch", [a for a in ARCHS if get_config(a).moe])
def test_moe_archs_capacity_mode_smoke(arch):
    """MoE archs also run under the capacity dispatch (§Perf H1 mode)."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), q_chunk=16,
                          moe_mode="capacity")
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
