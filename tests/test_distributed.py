"""Distribution-layer tests run in subprocesses with fake devices
(XLA_FLAGS must be set before jax initializes — never in this process)."""
import os
import subprocess
import sys

import pytest

# pin the CPU platform: the image carries a libtpu, and platform
# auto-detect burns minutes probing the TPU backend in every subprocess
# (the fake-device XLA_FLAGS only applies to the CPU platform anyway)
ENV = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}


def _run(code: str, devices: int = 8, timeout: int = 420):
    full = (f"import os; os.environ['XLA_FLAGS']="
            f"'--xla_force_host_platform_device_count={devices}';" + code)
    out = subprocess.run([sys.executable, "-c", full], capture_output=True,
                         text=True, timeout=timeout, env=ENV)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    return out.stdout


def test_moe_capacity_shard_map_matches_dense():
    """Expert-parallel shard_map dispatch ≡ dense dispatch (high capacity)
    on a 2×2 ("data","model") mesh."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro import sharding as shd
from repro.models.moe import init_moe, moe_forward

cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()   # 4 experts
mesh = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
rules = {"tokens": ("data",), "experts": "model", "batch": ("data",)}
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
y_dense, aux_d = moe_forward(p, x, cfg, mode="dense")
with mesh, shd.use_rules(mesh, rules):
    y_cap, aux_c = jax.jit(lambda p, x: moe_forward(
        p, x, cfg, mode="capacity", capacity_factor=8.0))(p, x)
err = float(jnp.max(jnp.abs(y_dense - y_cap)))
print("ERR", err, float(aux_d), float(aux_c))
assert err < 1e-3, err
assert abs(float(aux_d) - float(aux_c)) < 1e-4
""", devices=4)
    assert "ERR" in out


def test_dryrun_single_combo():
    """launch/dryrun lowers + compiles a real combo on the 16×16 mesh."""
    out = _run("""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'
from repro.launch.dryrun import run_combo
rec = run_combo('mamba2-370m', 'long_500k', multi_pod=False, verbose=False)
assert rec['status'] == 'ok', rec
print('DRYRUN_OK', rec['dominant'], rec['compile_s'])
""", devices=512, timeout=560)
    assert "DRYRUN_OK" in out


def test_make_production_mesh_shapes():
    out = _run("""
import jax
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert dict(m1.shape) == {"data": 16, "model": 16}
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
print("MESH_OK")
""", devices=512, timeout=240)
    assert "MESH_OK" in out
