"""Hypothesis property-based tests on system invariants."""
# ruff: noqa: E402
import pytest

pytest.importorskip("hypothesis")

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import personalization as P
from repro.core import policy
from repro.train.optim import clip_by_global_norm, global_norm

FINITE = {"allow_nan": False, "allow_infinity": False}


@st.composite
def tables(draw, max_q=12, max_m=6):
    q = draw(st.integers(2, max_q))
    m = draw(st.integers(2, max_m))
    A = draw(hnp.arrays(np.float32, (q, m),
                        elements=st.floats(0, 1, width=32)))
    C = draw(hnp.arrays(np.float32, (q, m),
                        elements=st.floats(0, 1, width=32)))
    return jnp.asarray(A), jnp.asarray(C)


@given(tables())
@settings(max_examples=40, deadline=None)
def test_route_shift_invariance(tc):
    """Adding a per-query constant to every model's utility never changes
    the routing decision (argmax invariance) — up to float ties: queries
    whose top-2 utilities are within float tolerance are excluded (the
    shift can legitimately flip a bit-level tie)."""
    A, C = tc
    U = np.asarray(policy.utility(A, C, 0.7), np.float32)
    top2 = np.sort(U, axis=1)[:, -2:]
    clear = (top2[:, 1] - top2[:, 0]) > 1e-5
    m1 = np.asarray(policy.route(A, C, 0.7))
    m2 = np.asarray(policy.route(A + 0.25, C, 0.7))
    np.testing.assert_array_equal(m1[clear], m2[clear])


@given(tables())
@settings(max_examples=40, deadline=None)
def test_mean_cost_monotone_in_lambda(tc):
    """Sweeping λ up can only decrease the mean routed cost (frontier
    monotonicity — the basis of the paper's accuracy–cost curves)."""
    A, C = tc
    lams = [0.0, 0.1, 1.0, 10.0, 1000.0]
    costs = []
    for lam in lams:
        ch = policy.route(A, C, lam)
        costs.append(float(jnp.mean(
            jnp.take_along_axis(C, ch[:, None], axis=1))))
    assert all(costs[i] >= costs[i + 1] - 1e-6 for i in range(len(costs) - 1))


@given(tables())
@settings(max_examples=30, deadline=None)
def test_auc_bounded(tc):
    A, C = tc
    costs, accs = policy.frontier(A, C, A, C,
                                  lams=np.logspace(-2, 3, 20))
    auc = policy.frontier_auc(costs, accs)
    assert -1e-9 <= auc <= 1.0 + 1e-9


@given(hnp.arrays(np.float32, st.integers(1, 6).map(lambda m: (m,)),
                  elements=st.floats(0, 100, width=32)),
       hnp.arrays(np.float32, st.integers(1, 6).map(lambda m: (m,)),
                  elements=st.floats(0, 100, width=32)))
@settings(max_examples=40, deadline=None)
def test_mixture_weights_in_unit_interval(ef, el):
    m = min(len(ef), len(el))
    w = P.mixture_weights(jnp.asarray(ef[:m]), jnp.asarray(el[:m]))
    assert bool(jnp.all((w >= 0) & (w <= 1)))


@given(st.lists(hnp.arrays(np.float32, hnp.array_shapes(max_dims=3,
                                                        max_side=5),
                           elements=st.floats(-100, 100, width=32)),
                min_size=1, max_size=4),
       st.floats(0.01, 10.0))
@settings(max_examples=40, deadline=None)
def test_clip_by_global_norm(leaves, max_norm):
    tree = {f"p{i}": jnp.asarray(a) for i, a in enumerate(leaves)}
    clipped = clip_by_global_norm(tree, max_norm)
    gn = float(global_norm(clipped))
    assert gn <= max_norm * (1 + 1e-4) + 1e-6
    # direction preserved: clipped = s * original with one global scalar s
    orig_n = float(global_norm(tree))
    if orig_n > 0:
        s = gn / orig_n
        for k in tree:
            np.testing.assert_allclose(np.asarray(clipped[k]),
                                       np.asarray(tree[k]) * s, rtol=1e-3,
                                       atol=1e-4)


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 40), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_kmeans_assign_property(seed, n, k):
    key = jax.random.PRNGKey(seed)
    kx, kc = jax.random.split(key)
    X = jax.random.normal(kx, (n, 5))
    C = jax.random.normal(kc, (k, 5))
    from repro.kernels.ops import kmeans_assign
    a = np.asarray(kmeans_assign(X, C))
    d2 = np.asarray(jnp.sum((X[:, None] - C[None]) ** 2, -1))
    chosen = d2[np.arange(n), a]
    assert np.all(chosen <= d2.min(axis=1) + 1e-4)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip(seed):
    import tempfile
    from repro.train import checkpoint as ckpt
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (3, 4)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jax.random.normal(key, (2,)).astype(jnp.bfloat16)},
            "e": [jnp.ones(()), jnp.zeros((1, 2))],
            "scalar": 3, "name": "x"}
    with tempfile.NamedTemporaryFile(suffix=".msgpack") as f:
        ckpt.save(f.name, tree)
        back = ckpt.restore(f.name)
    assert back["scalar"] == 3 and back["name"] == "x"
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(back["a"]))
    assert back["b"]["d"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(tree["b"]["d"], np.float32),
        np.asarray(back["b"]["d"], np.float32))


@given(st.integers(0, 1000), st.integers(1, 4), st.integers(2, 5))
@settings(max_examples=15, deadline=None)
def test_moe_gate_weights_sum_to_one(seed, topk, experts):
    if topk > experts:
        topk = experts
    import dataclasses
    from repro.config import MoEConfig
    from repro.configs import get_config
    from repro.models.moe import _router_probs
    cfg = dataclasses.replace(
        get_config("phi3.5-moe-42b-a6.6b").reduced(),
        moe=MoEConfig(num_experts=experts, top_k=topk, d_expert=16))
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (7, cfg.d_model))
    p = {"router": jax.random.normal(key, (cfg.d_model, experts))}
    w, ids, probs = _router_probs(p, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert bool(jnp.all(ids < experts))
