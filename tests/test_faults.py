"""Fault-tolerant federation (ISSUE 7 acceptance suite): cohort sampling
with zero retraces, Byzantine-robust aggregation under corrupted clients,
deterministic fault injection, gateway failover, and FedLoop
checkpoint/resume continuing bit-identically after a kill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import routers
from repro.config import FedConfig, ModelConfig, RouterConfig
from repro.core import federated as F
from repro.core import policy
from repro.data.partition import federated_split
from repro.data.synthetic import make_eval_corpus
from repro.fed.aggregators import (BufferedAsyncAggregator, FedAvgAggregator,
                                   GaussianDPAggregator, MedianAggregator,
                                   NormClipAggregator, TrimmedMeanAggregator)
from repro.fed.faults import CorruptUpdates, FaultPlan
from repro.fed.harvest import HarvestStore
from repro.fed.loop import FedLoop, FedLoopConfig
from repro.models import init_params
from repro.serve.engine import EngineConfig
from repro.serve.gateway import PoolModel, RoutedServer

TINY = ModelConfig(name="faults-tiny", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                   head_dim=16, dtype="float32")
D_EMB = 8
N_CLIENTS = 3
RCFG = RouterConfig(d_emb=D_EMB, num_models=2, hidden=(16, 16), dropout=0.0)
FCFG = FedConfig(num_clients=N_CLIENTS, participation=1.0, batch_size=16,
                 lr=3e-3)


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _max_diff(a, b):
    return max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture(scope="module")
def split():
    fcfg = FedConfig(num_clients=8, participation=1.0, batch_size=32,
                     lr=3e-3)
    corpus = make_eval_corpus(jax.random.PRNGKey(0), n_queries=600,
                              n_tasks=5, n_models=6, d_emb=16)
    return federated_split(jax.random.PRNGKey(1), corpus, fcfg), fcfg


# ------------------------------------------------- acceptance 1: cohorts

def test_cohort_fit_zero_retraces_across_cohort_draws():
    """Per-round cohort sampling uses a static (C, ...) slab gathered
    inside the jit, so fits with different keys (different cohort draws
    every round) share ONE trace — pinned via FIT_TRACE_LOG."""
    # unique cfg so this test owns its compiled-fit cache entry
    rcfg = RouterConfig(d_emb=12, num_models=4, hidden=(24,), dropout=0.0)
    fcfg = FedConfig(num_clients=6, participation=1.0, batch_size=16,
                     rounds=3, lr=3e-3)
    corpus = make_eval_corpus(jax.random.PRNGKey(5), n_queries=200,
                              n_tasks=3, n_models=4, d_emb=12)
    data = federated_split(jax.random.PRNGKey(6), corpus, fcfg)["train"]

    F.reset_fit_trace_log()
    p0, _ = F.fedavg(jax.random.PRNGKey(0), data, rcfg, fcfg, cohort=3)
    traced = len(F.FIT_TRACE_LOG)
    assert traced >= 1
    for seed in (1, 2, 3):      # fresh cohort permutations every round
        F.fedavg(jax.random.PRNGKey(seed), data, rcfg, fcfg, cohort=3)
    assert len(F.FIT_TRACE_LOG) == traced, (
        "cohort sampling retraced the fit across cohort draws")
    # reproducible: same key, same cohorts, same params
    p1, _ = F.fedavg(jax.random.PRNGKey(0), data, rcfg, fcfg, cohort=3)
    _trees_equal(p0, p1)


def test_cohort_validation(split):
    data, fcfg = split
    with pytest.raises(ValueError, match="cohort"):
        F.fedavg(jax.random.PRNGKey(0), data["train"],
                 RouterConfig(d_emb=16, num_models=6), fcfg, cohort=0)
    with pytest.raises(ValueError, match="client_mask"):
        F.fedavg(jax.random.PRNGKey(0), data["train"],
                 RouterConfig(d_emb=16, num_models=6), fcfg, cohort=2,
                 client_mask=jnp.ones(8))


# ------------------------------------- acceptance 2: Byzantine robustness

def test_trimmed_mean_survives_sign_flip_while_fedavg_degrades(split):
    """25% sign-flip corrupted clients: the trimmed-mean fit stays within
    0.05 frontier AUC of its own clean fit while plain FedAvg loses at
    least 0.10 — same floors ci.yml enforces on the resilience bench."""
    data, fcfg = split
    rcfg = RouterConfig(d_emb=16, num_models=6, hidden=(32, 32),
                        dropout=0.0)
    plan = FaultPlan(seed=3, corrupt_frac=0.25)
    test = data["test_global"]

    def fit_auc(aggregator=None):
        kw = {} if aggregator is None else {"aggregator": aggregator}
        p, _ = F.fedavg(jax.random.PRNGKey(5), data["train"], rcfg, fcfg,
                        rounds=20, **kw)
        r = routers.make("mlp", rcfg, state=p)
        *_, auc = policy.eval_router(r.predict, test["x"],
                                     test["acc_table"], test["cost_table"])
        return float(auc)

    clean_fa = fit_auc()
    bad_fa = fit_auc(plan.corrupt_updates(8, mode="sign_flip"))
    clean_tm = fit_auc(TrimmedMeanAggregator(trim_frac=0.25))
    bad_tm = fit_auc(plan.corrupt_updates(
        8, inner=TrimmedMeanAggregator(trim_frac=0.25), mode="sign_flip"))
    assert clean_fa - bad_fa >= 0.10, (
        f"sign-flip no longer bites FedAvg: {clean_fa} -> {bad_fa}")
    assert clean_tm - bad_tm <= 0.05, (
        f"trimmed-mean lost robustness: {clean_tm} -> {bad_tm}")


def test_trimmed_mean_and_median_match_numpy_oracle():
    """Coordinate-wise trimmed mean / median over the ACTIVE clients only
    (inactive rows are excluded entirely, not averaged as zeros)."""
    key = jax.random.PRNGKey(0)
    N = 6
    cp = {"w": jax.random.normal(key, (N, 4, 3))}
    wts = jnp.array([1.0, 2.0, 0.0, 1.0, 1.0, 0.0])    # clients 2, 5 out
    act = np.asarray(wts) > 0
    rows = np.asarray(cp["w"])[act]                     # (4, 4, 3)

    got_med = MedianAggregator()(cp, wts, key)["w"]
    np.testing.assert_allclose(np.asarray(got_med),
                               np.median(rows, axis=0), rtol=1e-6)

    got_tm = TrimmedMeanAggregator(trim_frac=0.25)(cp, wts, key)["w"]
    srt = np.sort(rows, axis=0)                         # k = floor(.25*4)=1
    want = srt[1:-1].mean(axis=0)
    np.testing.assert_allclose(np.asarray(got_tm), want, rtol=1e-6)


def test_norm_clip_equals_fedavg_when_clip_is_loose():
    key = jax.random.PRNGKey(1)
    cp = {"w": jax.random.normal(key, (4, 5)) * 0.1}
    wts = jnp.array([1.0, 2.0, 3.0, 4.0])
    plain = FedAvgAggregator()(cp, wts, key)
    clipped = NormClipAggregator(clip=1e9)(cp, wts, key,
                                           prev={"w": jnp.zeros(5)})
    assert _max_diff(plain, clipped) < 1e-5


def test_norm_clip_bounds_the_step():
    """One Byzantine row with a huge delta: the aggregated step's norm is
    bounded by the clip (FedAvg's is not)."""
    prev = {"w": jnp.zeros(8)}
    cp = {"w": jnp.concatenate([jnp.ones((3, 8)) * 0.01,
                                jnp.ones((1, 8)) * 1e4])}
    wts = jnp.ones(4)
    key = jax.random.PRNGKey(2)
    clipped = NormClipAggregator(clip=0.1)(cp, wts, key, prev=prev)
    step = float(jnp.linalg.norm(clipped["w"]))
    assert step <= 0.1 + 1e-6
    plain = FedAvgAggregator()(cp, wts, key)
    assert float(jnp.linalg.norm(plain["w"])) > 1e3


def test_buffered_async_staleness_downweights():
    """Zero staleness ≡ FedAvg; a stale client's update is attenuated by
    (1 + s)^(-alpha) — the FedBuffer-style weighting."""
    key = jax.random.PRNGKey(3)
    prev = {"w": jnp.zeros(6)}
    cp = {"w": jnp.stack([jnp.ones(6), -jnp.ones(6)])}
    wts = jnp.ones(2)
    agg = BufferedAsyncAggregator(server_lr=1.0, staleness_alpha=1.0)
    fresh = agg(cp, wts, key, prev=prev, staleness=jnp.zeros(2))
    _trees_equal(fresh, FedAvgAggregator()(cp, wts, key))
    # client 1 three syncs stale: decay 1/4 -> normalized weights 4/5, 1/5
    stale = agg(cp, wts, key, prev=prev,
                staleness=jnp.array([0.0, 3.0]))
    np.testing.assert_allclose(np.asarray(stale["w"]),
                               np.full(6, 0.8 - 0.2), rtol=1e-6)


def test_staleness_requires_declaring_aggregator(split):
    data, fcfg = split
    with pytest.raises(ValueError, match="does not consume"):
        F.fedavg(jax.random.PRNGKey(0), data["train"],
                 RouterConfig(d_emb=16, num_models=6), fcfg,
                 staleness=jnp.zeros(8))


def test_dp_composes_over_robust_strategy(split):
    """GaussianDP forwards declared extras, so DP-over-trimmed-mean is a
    valid stack (noise really applied, extras really forwarded)."""
    data, fcfg = split
    rcfg = RouterConfig(d_emb=16, num_models=6)
    inner = TrimmedMeanAggregator(trim_frac=0.25)
    p, _ = F.fedavg(jax.random.PRNGKey(2), data["train"], rcfg, fcfg,
                    rounds=3,
                    aggregator=GaussianDPAggregator(sigma=0.05, inner=inner))
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(p))
    p0, _ = F.fedavg(jax.random.PRNGKey(2), data["train"], rcfg, fcfg,
                     rounds=3, aggregator=inner)
    assert _max_diff(p, p0) > 1e-5


# -------------------------------------- acceptance 3: fault determinism

def test_fault_plan_draws_are_deterministic_across_instances():
    a = FaultPlan(seed=9, dropout=0.3, delay_frac=0.5, corrupt_frac=0.25,
                  lose_outcomes=0.2, backend_fail=0.4)
    b = FaultPlan(seed=9, dropout=0.3, delay_frac=0.5, corrupt_frac=0.25,
                  lose_outcomes=0.2, backend_fail=0.4)
    assert [a.client_drops(c, r) for c in range(8) for r in range(5)] == \
        [b.client_drops(c, r) for c in range(8) for r in range(5)]
    np.testing.assert_array_equal(a.corrupted_clients(12),
                                  b.corrupted_clients(12))
    np.testing.assert_array_equal(a.staleness(12, 3), b.staleness(12, 3))
    assert [a.lose_outcome(r) for r in range(20)] == \
        [b.lose_outcome(r) for r in range(20)]
    assert [a.backend_fails(0, s, 0) for s in range(20)] == \
        [b.backend_fails(0, s, 0) for s in range(20)]
    assert a.corrupted_clients(12).sum() == 3       # floor(0.25 * 12)


def test_corrupt_updates_sign_flip_oracle():
    """sign_flip uploads prev - scale*(theta_i - prev) on masked rows
    only; the inner default FedAvg then averages what the server sees."""
    prev = {"w": jnp.ones(4)}
    cp = {"w": jnp.stack([jnp.full(4, 2.0), jnp.full(4, 3.0)])}
    wts = jnp.ones(2)
    agg = CorruptUpdates(mask=(True, False), mode="sign_flip", scale=2.0)
    out = agg(cp, wts, jax.random.PRNGKey(0), prev=prev)
    # row 0: 1 - 2*(2 - 1) = -1; row 1 untouched: 3 -> mean = 1.0
    np.testing.assert_allclose(np.asarray(out["w"]), np.full(4, 1.0),
                               rtol=1e-6)


def test_corrupt_updates_validation():
    prev = {"w": jnp.zeros(3)}
    cp = {"w": jnp.zeros((4, 3))}
    wts = jnp.ones(4)
    with pytest.raises(ValueError, match="mask covers 2 clients"):
        CorruptUpdates(mask=(True, False))(cp, wts, jax.random.PRNGKey(0),
                                           prev=prev)
    with pytest.raises(ValueError, match="corruption mode"):
        CorruptUpdates(mask=(True,) * 4, mode="gremlins")(
            cp, wts, jax.random.PRNGKey(0), prev=prev)


# ---------------------------------- acceptance 4: failover + checkpoint

def _make_server(fault_plan=None, **kw):
    params = init_params(jax.random.PRNGKey(0), TINY)
    pool = [PoolModel("m0", TINY, params, 0.1),
            PoolModel("m1", TINY, params, 0.5)]
    router = routers.make("mlp", RCFG).init(jax.random.PRNGKey(1))
    harvest = HarvestStore(D_EMB, capacity=32, clients=range(N_CLIENTS))
    return RoutedServer(pool, router, harvest=harvest,
                        engine_cfg=EngineConfig(slots=4, max_seq=32,
                                                chunk=4, page_size=8),
                        fault_plan=fault_plan, **kw)


def test_backend_failure_retried_rerouted_and_harvested():
    """A hard-down backend: the gateway retries, then re-routes to the
    next-best model by the router's own utility; the request completes and
    the HARVESTED outcome records the model that actually served it."""
    srv = _make_server(fault_plan=FaultPlan(seed=0, fail_models=(0,)),
                       max_retries=2)
    x = np.zeros(D_EMB, np.float32)
    # lam=0 routes purely by predicted accuracy; whatever the pick, model
    # 0 is down, so every request must land on model 1
    rid = srv.submit("three word prompt", lam=0.0, max_new_tokens=4,
                     client_id=0, x=x)
    assert srv.routed_model(rid) == 1
    assert srv.backend_failures >= 1
    assert (srv.failovers + srv.retries) >= 1
    srv.report_outcome(rid, 1.0, 0.5)
    srv.drain()
    data = srv.harvest.buffer(0).as_client_data()
    assert int(data["m"][0]) == 1       # realized model, not the pick
    assert float(data["w"].sum()) == 1


def test_all_backends_down_raises():
    srv = _make_server(fault_plan=FaultPlan(seed=0, fail_models=(0, 1)))
    with pytest.raises(RuntimeError, match="all 2 pool backends failed"):
        srv.submit("three word prompt", lam=0.0, max_new_tokens=4,
                   client_id=0, x=np.zeros(D_EMB, np.float32))


def test_transient_backend_failure_recovers_by_retry():
    """With a probabilistic per-attempt fault, retries of the SAME model
    can succeed — the plan draws per (model, seq, attempt)."""
    plan = FaultPlan(seed=1, backend_fail=0.5)
    srv = _make_server(fault_plan=plan, max_retries=4)
    for i in range(6):
        rid = srv.submit("three word prompt", lam=0.5, max_new_tokens=4,
                         client_id=i % N_CLIENTS,
                         x=np.zeros(D_EMB, np.float32))
        srv.report_outcome(rid, 1.0, 0.1)
    out = srv.drain()
    assert len(out) == 6
    assert srv.backend_failures > 0 and srv.retries > 0


def _drive_stateless(srv, loop, lo, hi):
    """Deterministic traffic where event i depends only on i — a killed
    run replays [lo, hi) identically after restore."""
    routes = []
    for i in range(lo, hi):
        x = np.sin(np.arange(D_EMB, dtype=np.float32) * (i + 1))
        rid = srv.submit("three word prompt", lam=0.5, max_new_tokens=4,
                         client_id=i % N_CLIENTS, x=x)
        m = srv.routed_model(rid)
        routes.append(m)
        u = np.random.default_rng(1_000_003 * i + m).random()
        srv.report_outcome(rid, float(u < 0.4 + 0.3 * m), 0.1 + 0.4 * m)
        loop.step()
    loop.drain()
    loop.sync()
    return routes


def _fresh_loop():
    srv = _make_server()
    cfg = FedLoopConfig(sync_every=10 ** 9, rounds_per_sync=3,
                        min_samples=1)
    return srv, FedLoop(srv, FCFG, key=jax.random.PRNGKey(7), cfg=cfg)


def test_killed_and_restored_loop_continues_bit_identically(tmp_path):
    """FedLoop.save() after phase 0, restore() into a fresh server, replay
    phase 1: router state, versions, history, harvest rings, PRNG key and
    the phase-1 routing decisions all match the uninterrupted twin."""
    srv_a, loop_a = _fresh_loop()
    _drive_stateless(srv_a, loop_a, 0, 9)
    routes_a = _drive_stateless(srv_a, loop_a, 9, 18)

    srv_b, loop_b = _fresh_loop()
    _drive_stateless(srv_b, loop_b, 0, 9)
    path = tmp_path / "loop.ckpt"
    loop_b.save(path)
    del srv_b, loop_b

    srv_c, loop_c = _fresh_loop()
    loop_c.restore(path)
    routes_c = _drive_stateless(srv_c, loop_c, 9, 18)

    assert routes_a == routes_c
    _trees_equal(srv_a.router.state, srv_c.router.state)
    assert srv_a.router_version == srv_c.router_version
    assert loop_a._syncs == loop_c._syncs
    np.testing.assert_array_equal(np.asarray(loop_a._key),
                                  np.asarray(loop_c._key))
    assert len(loop_a.history) == len(loop_c.history)
    for ha, hc in zip(loop_a.history, loop_c.history):
        assert ha["version"] == hc["version"]
        assert ha["samples"] == hc["samples"]
        np.testing.assert_array_equal(np.asarray(ha["loss"]),
                                      np.asarray(hc["loss"]))
    for c in srv_a.harvest.client_ids():
        sa = srv_a.harvest.buffer(c).state()
        sc = srv_c.harvest.buffer(c).state()
        for k in sa:
            np.testing.assert_array_equal(np.asarray(sa[k]),
                                          np.asarray(sc[k]))


def test_checkpoint_rejects_family_mismatch_and_busy_engine(tmp_path):
    srv, loop = _fresh_loop()
    _drive_stateless(srv, loop, 0, 3)
    path = tmp_path / "loop.ckpt"
    loop.save(path)

    srv.submit("three word prompt", lam=0.5, max_new_tokens=4,
               client_id=0, x=np.zeros(D_EMB, np.float32))
    with pytest.raises(ValueError, match="idle engine"):
        loop.save(tmp_path / "busy.ckpt")
    srv.drain()

    srv2, loop2 = _fresh_loop()
    srv2.router = routers.make("mf", RouterConfig(
        d_emb=D_EMB, num_models=2, mf_rank=4)).init(jax.random.PRNGKey(2))
    with pytest.raises(ValueError, match="mlp.*router"):
        loop2.restore(path)


def test_pending_evals_survive_checkpoint(tmp_path):
    """A submitted-but-unreported evaluation is host-side state: it must
    survive save/restore and still accept its report_outcome."""
    srv, loop = _fresh_loop()
    rid = srv.submit("three word prompt", lam=0.5, max_new_tokens=4,
                     client_id=1, x=np.ones(D_EMB, np.float32))
    srv.drain()
    path = tmp_path / "loop.ckpt"
    loop.save(path)

    srv2, loop2 = _fresh_loop()
    loop2.restore(path)
    srv2.report_outcome(rid, 1.0, 0.25)
    assert len(srv2.harvest.buffer(1)) == 1


# ---------------------------------------------------- loop: cohort + async

def test_fedloop_staleness_vector_tracks_silent_clients():
    """Under a BufferedAsync aggregator the loop passes per-client
    staleness: clients with fresh samples since the last sync are 0, a
    silent client's staleness grows by one per sync."""
    srv, _ = _fresh_loop()
    loop = FedLoop(srv, FCFG, key=jax.random.PRNGKey(7),
                   aggregator=BufferedAsyncAggregator(),
                   cfg=FedLoopConfig(sync_every=10 ** 9, rounds_per_sync=2,
                                     min_samples=1))
    _drive_stateless(srv, loop, 0, 6)       # all clients fresh, sync 1
    ids = srv.harvest.client_ids()
    # nobody has contributed since that sync: everyone is 1 sync stale
    np.testing.assert_array_equal(loop._staleness_vector(ids),
                                  np.ones(N_CLIENTS, np.float32))
    # only clients 0 and 1 get new traffic — they are fresh, 2 is not
    for i in (0, 1):
        x = np.cos(np.arange(D_EMB, dtype=np.float32) * (i + 1))
        rid = srv.submit("three word prompt", lam=0.5, max_new_tokens=4,
                         client_id=i, x=x)
        srv.report_outcome(rid, 1.0, 0.1)
    srv.drain()
    np.testing.assert_array_equal(loop._staleness_vector(ids),
                                  np.array([0, 0, 1], np.float32))
    # client 2 stays silent: its staleness grows by one per further sync
    loop.sync()
    np.testing.assert_array_equal(loop._staleness_vector(ids),
                                  np.array([1, 1, 2], np.float32))
    loop.sync()
    np.testing.assert_array_equal(loop._staleness_vector(ids),
                                  np.array([2, 2, 3], np.float32))


def test_fedloop_cohort_config_forwards_to_fit():
    """FedLoopConfig.cohort reaches the fit: a cohort-sampled sync still
    swaps a valid router and is reproducible from the loop seed."""
    def run():
        srv, loop = _fresh_loop()
        loop.cfg = FedLoopConfig(sync_every=10 ** 9, rounds_per_sync=3,
                                 min_samples=1, cohort=2)
        _drive_stateless(srv, loop, 0, 9)
        return srv.router.state, loop.version
    s1, v1 = run()
    s2, v2 = run()
    _trees_equal(s1, s2)
    assert v1 == v2 == 1
