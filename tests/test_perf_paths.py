"""Hot-path fusions must not change results: scan-fused FedAvg is
bit-for-bit the per-round loop (both fit paths), and the gateway's
bucketed scan decode returns the same tokens as the per-token loop with
zero recompilation once a (model, bucket) is warm."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import routers
from repro.config import FedConfig, ModelConfig, RouterConfig
from repro.core import federated as F
from repro.data.partition import federated_split
from repro.data.synthetic import make_eval_corpus

RCFG = RouterConfig(d_emb=16, num_models=5, hidden=(32, 32))
FCFG = FedConfig(num_clients=4, rounds=3, batch_size=32, seed=1)


@pytest.fixture(scope="module")
def split():
    corpus = make_eval_corpus(jax.random.PRNGKey(0), n_queries=600,
                              n_tasks=4, n_models=5, d_emb=16)
    return federated_split(jax.random.PRNGKey(1), corpus, FCFG)


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------- scan-fused fedavg

def test_scan_fused_fedavg_bit_for_bit(split):
    """eval_fn=None engages the lax.scan fit; a no-op eval_fn forces the
    per-round loop. Same key ⇒ identical params AND loss history."""
    p_scan, h_scan = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG,
                              FCFG)
    p_loop, h_loop = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG,
                              FCFG, eval_fn=lambda p: None)
    _trees_equal(p_scan, p_loop)
    assert h_scan["loss"] == h_loop["loss"]
    assert len(h_scan["loss"]) == FCFG.rounds and h_scan["eval"] == []


def test_scan_fused_fedavg_with_init_preserves_input(split):
    """A caller-provided init must not be donated away by the scan fit."""
    init = F.R.init_mlp_router(jax.random.PRNGKey(7), RCFG)
    ref_leaf = np.asarray(init["heads"]["acc_w"]).copy()
    p1, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                     init=init)
    # init buffers are still alive and unchanged after the fit
    np.testing.assert_array_equal(np.asarray(init["heads"]["acc_w"]),
                                  ref_leaf)
    p2, _ = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG, FCFG,
                     init=init)
    _trees_equal(p1, p2)


def test_chunked_eval_fit_bit_for_bit(split):
    """eval_every=E scans E rounds per eval sync — params and per-round
    losses must stay bit-for-bit the per-round loop, with one eval entry
    per chunk boundary (including the short tail chunk)."""
    evals = []

    def eval_fn(p):
        evals.append(float(jax.tree.leaves(p)[0].ravel()[0]))
        return evals[-1]

    p_loop, h_loop = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG,
                              FCFG, eval_fn=lambda p: None)
    p_chunk, h_chunk = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG,
                                FCFG, eval_fn=eval_fn, eval_every=2)
    _trees_equal(p_loop, p_chunk)
    assert h_chunk["loss"] == h_loop["loss"]
    # FCFG.rounds=3, E=2 → chunks of 2 and 1 → two eval entries
    assert len(h_chunk["eval"]) == 2 and h_chunk["eval"] == evals


def test_scan_fused_mesh_path_bit_for_bit(split):
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("clients",))
    r_scan, h_scan = routers.fit_federated(
        routers.make("mlp", RCFG), split["train"], FCFG,
        key=jax.random.PRNGKey(2), mesh=mesh)
    r_loop, h_loop = routers.fit_federated(
        routers.make("mlp", RCFG), split["train"], FCFG,
        key=jax.random.PRNGKey(2), mesh=mesh, eval_fn=lambda r: None)
    _trees_equal(r_scan.state, r_loop.state)
    assert h_scan["loss"] == h_loop["loss"]


def test_scan_fused_matches_unified_api(split):
    """repro.routers.fit_federated (scan path) ≡ legacy loop driver."""
    r, hist = routers.fit_federated(routers.make("mlp", RCFG),
                                    split["train"], FCFG,
                                    key=jax.random.PRNGKey(2))
    legacy, lhist = F.fedavg(jax.random.PRNGKey(2), split["train"], RCFG,
                             FCFG, eval_fn=lambda p: None)
    _trees_equal(r.state, legacy)
    assert hist["loss"] == lhist["loss"]


# ------------------------------------------------------ gateway decode cache

TINY = ModelConfig(name="tiny-dense", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=97,
                   head_dim=16)


@pytest.fixture(scope="module")
def server():
    from repro.models import init_params
    from repro.serve.gateway import PoolModel, RoutedServer
    router = routers.make(
        "kmeans", RouterConfig(d_emb=16, num_models=1),
        state={"centroids": jnp.zeros((1, 16)),
               "A": jnp.array([[0.9]]), "C": jnp.array([[0.1]]),
               "n": jnp.ones((1, 1))})
    pool = [PoolModel("tiny", TINY,
                      init_params(jax.random.PRNGKey(0), TINY), 0.1)]
    return RoutedServer(pool, router)


PROMPTS = ["the quick brown fox", "jumps over", "a lazy dog today ok fine"]


def test_engine_matches_single_request_path(server):
    """The default (engine) path serves each prompt as its own request —
    tokens must equal generating that prompt alone on the legacy scan
    path (no group-padding context leaks between prompts)."""
    eng = server.generate(PROMPTS, lam=0.5, max_new_tokens=5)
    for p, r in zip(PROMPTS, eng["results"]):
        solo = server.generate([p], lam=0.5, max_new_tokens=5,
                               engine=False)
        assert r["tokens"] == solo["results"][0]["tokens"]
        assert len(r["tokens"]) == 5


def test_scan_decode_matches_token_loop(server):
    """Legacy grouped path: the fused scan decode and the per-token loop
    must produce identical tokens for the same group-padded batch."""
    scan = server.generate(PROMPTS, lam=0.5, max_new_tokens=5,
                           engine=False)
    loop = server.generate(PROMPTS, lam=0.5, max_new_tokens=5,
                           engine=False, scan_decode=False)
    for a, b in zip(scan["results"], loop["results"]):
        assert a["tokens"] == b["tokens"]
        assert len(a["tokens"]) == 5


def test_warm_bucket_compiles_nothing(server):
    from repro.serve import gateway
    server.generate(PROMPTS, lam=0.5, max_new_tokens=5)         # warm
    baseline = server.generate(PROMPTS, lam=0.5, max_new_tokens=5)
    gateway.reset_trace_log()   # a bounded deque at maxlen would make the
    n0 = len(gateway.TRACE_LOG)  # length assertion below vacuous
    # same (B=3→4, S→8) bucket: different prompts, lengths and λ
    out = server.generate(["a b c d e f g", "x y", "one two three four"],
                          lam=1.5, max_new_tokens=5)
    repeat = server.generate(PROMPTS, lam=0.5, max_new_tokens=5)
    assert len(gateway.TRACE_LOG) == n0, \
        f"unexpected retrace: {list(gateway.TRACE_LOG)[n0:]}"
    assert all(r["tokens"] for r in out["results"])
    # determinism across repeated calls through the cached program
    for a, b in zip(baseline["results"], repeat["results"]):
        assert a["tokens"] == b["tokens"]


def test_route_cached_jit_stable(server):
    c1 = server.route(PROMPTS, 0.3)
    c2 = server.route(PROMPTS, 0.3)
    np.testing.assert_array_equal(c1, c2)
    assert c1.shape == (len(PROMPTS),)
